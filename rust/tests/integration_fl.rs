//! End-to-end FL integration tests: full rounds through the real wire
//! path (client encodes → server decodes → aggregate → broadcast),
//! protocol invariants, partial updates, bidirectional compression.

use fsfl::compression::SparsifyMode;
use fsfl::data::TaskKind;
use fsfl::fl::{Experiment, ExperimentConfig, Protocol};
use fsfl::model::Group;
use fsfl::runtime::Runtime;

fn artifacts_root() -> std::path::PathBuf {
    std::env::var("FSFL_ARTIFACTS")
        .map(Into::into)
        .unwrap_or_else(|_| std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts"))
}

/// PJRT runtime + tiny_cnn artifacts, or `None` (test skips) when the
/// build uses the null xla backend or `make artifacts` hasn't run.
fn runtime() -> Option<Runtime> {
    if !artifacts_root().join("tiny_cnn").join("manifest.tsv").exists() {
        eprintln!("skipping: no artifacts (run `make artifacts`)");
        return None;
    }
    match Runtime::cpu() {
        Ok(rt) => Some(rt),
        Err(e) => {
            eprintln!("skipping: {e}");
            None
        }
    }
}

fn quick(protocol: Protocol) -> ExperimentConfig {
    let mut cfg = ExperimentConfig::quick("tiny_cnn", TaskKind::CifarLike, protocol);
    cfg.artifacts_root = artifacts_root();
    cfg.rounds = 3;
    cfg.train_per_client = 48;
    cfg.val_per_client = 16;
    cfg.test_samples = 32;
    cfg
}

#[test]
fn fsfl_round_trip_keeps_replicas_in_sync() {
    let Some(rt) = runtime() else { return };
    let mut exp = Experiment::build(&rt, quick(Protocol::Fsfl)).unwrap();
    let log = exp.run().unwrap();
    assert_eq!(log.rounds.len(), 3);
    assert!(exp.replicas_in_sync(), "client replicas diverged from server");
    assert!(log.total_bytes(true) > 0);
    // every round transmits something and measures accuracy in [0,1]
    for r in &log.rounds {
        assert!(r.up_bytes > 0);
        assert!((0.0..=1.0).contains(&r.accuracy));
        assert!(r.update_sparsity > 0.0, "dynamic sparsification inert");
    }
}

#[test]
fn all_protocols_run_and_order_bytes_sanely() {
    let Some(rt) = runtime() else { return };
    let mut bytes = std::collections::HashMap::new();
    for protocol in Protocol::ALL {
        let mut cfg = quick(protocol);
        cfg.rounds = 2;
        cfg.sparsify = SparsifyMode::TopK { rate: 0.96 };
        let mut exp = Experiment::build(&rt, cfg).unwrap();
        let log = exp.run().unwrap();
        assert!(exp.replicas_in_sync(), "{:?} diverged", protocol);
        bytes.insert(protocol.name(), log.total_bytes(true));
    }
    // uncompressed FedAvg must dominate everything else by a wide margin
    let fedavg = bytes["FedAvg"];
    for (name, &b) in &bytes {
        if *name != "FedAvg" {
            assert!(
                b < fedavg / 4,
                "{name} used {b} bytes vs FedAvg {fedavg}"
            );
        }
    }
    // sparsified protocols beat quantization-only
    assert!(bytes["STC"] < bytes["FedAvg+DeepCABAC"]);
    assert!(bytes["Eqs.(2)+(3)"] < bytes["FedAvg+DeepCABAC"]);
}

#[test]
fn fedavg_transmits_exact_updates() {
    // With no codec the server must reconstruct the exact raw update:
    // after one round every replica equals server state bit-for-bit.
    let Some(rt) = runtime() else { return };
    let mut cfg = quick(Protocol::FedAvg);
    cfg.rounds = 1;
    let mut exp = Experiment::build(&rt, cfg).unwrap();
    let log = exp.run().unwrap();
    assert!(exp.replicas_in_sync());
    // raw f32 accounting: bytes == 4 * update params * clients
    let update_numel: usize = exp
        .server
        .params
        .manifest
        .update_indices()
        .iter()
        .map(|&i| exp.server.params.manifest.tensors[i].numel())
        .sum();
    assert_eq!(log.rounds[0].up_bytes, 4 * update_numel * 2);
}

#[test]
fn bidirectional_compresses_downstream() {
    let Some(rt) = runtime() else { return };
    let mut uni = quick(Protocol::Fsfl);
    uni.rounds = 2;
    let mut bi = quick(Protocol::Fsfl);
    bi.rounds = 2;
    bi.bidirectional = true;
    let mut exp_uni = Experiment::build(&rt, uni).unwrap();
    let log_uni = exp_uni.run().unwrap();
    let mut exp_bi = Experiment::build(&rt, bi).unwrap();
    let log_bi = exp_bi.run().unwrap();
    assert!(exp_bi.replicas_in_sync());
    let down_uni = log_uni.total_bytes(false) - log_uni.total_bytes(true);
    let down_bi = log_bi.total_bytes(false) - log_bi.total_bytes(true);
    assert!(
        down_bi < down_uni / 4,
        "bidirectional downstream {down_bi} vs raw {down_uni}"
    );
}

#[test]
fn partial_update_never_touches_frozen_tensors() {
    let Some(rt) = runtime() else { return };
    let mut cfg = ExperimentConfig::quick("vgg16_partial", TaskKind::XrayLike, Protocol::Fsfl);
    cfg.artifacts_root = artifacts_root();
    cfg.rounds = 2;
    cfg.train_per_client = 64;
    cfg.val_per_client = 32;
    cfg.test_samples = 32;
    let mut exp = Experiment::build(&rt, cfg).unwrap();
    let init = exp.server.params.clone();
    let frozen = init.manifest.group_indices(Group::Frozen);
    assert!(!frozen.is_empty(), "partial variant should freeze features");
    let log = exp.run().unwrap();
    for &i in &frozen {
        assert_eq!(
            exp.server.params.tensors[i], init.tensors[i],
            "frozen tensor {i} changed"
        );
    }
    // partial updates are much smaller than the full model
    let full_bytes = 4 * init.manifest.param_count;
    assert!(log.rounds[0].up_bytes < full_bytes / 4);
    // xray task reports a meaningful F1
    assert!(log.rounds.iter().all(|r| (0.0..=1.0).contains(&r.f1)));
}

#[test]
fn residuals_accumulate_learning_signal() {
    // With aggressive fixed sparsity, residuals must eventually push
    // update elements over the threshold: total transmitted magnitude
    // with residuals >= without, over enough rounds.
    let Some(rt) = runtime() else { return };
    let mut with = quick(Protocol::SparseOnly);
    with.rounds = 4;
    with.sparsify = SparsifyMode::TopK { rate: 0.99 };
    with.residuals_override = Some(true);
    let mut without = quick(Protocol::SparseOnly);
    without.rounds = 4;
    without.sparsify = SparsifyMode::TopK { rate: 0.99 };
    let mut e1 = Experiment::build(&rt, with).unwrap();
    let l1 = e1.run().unwrap();
    let mut e2 = Experiment::build(&rt, without).unwrap();
    let l2 = e2.run().unwrap();
    assert!(e1.replicas_in_sync() && e2.replicas_in_sync());
    // residual streams carry at least as many bytes (more surviving info)
    assert!(l1.total_bytes(true) >= l2.total_bytes(true));
}

#[test]
fn scale_training_moves_scale_factors_through_the_wire() {
    let Some(rt) = runtime() else { return };
    let mut cfg = quick(Protocol::Fsfl);
    cfg.rounds = 3;
    cfg.scale_epochs = 2;
    cfg.scale_lr = 5e-2;
    let mut exp = Experiment::build(&rt, cfg).unwrap();
    let log = exp.run().unwrap();
    let accepted: usize = log.rounds.iter().map(|r| r.scale_accepted).sum();
    if accepted > 0 {
        // server-side scales must have moved away from 1.0
        let scale_idx = exp.server.params.manifest.group_indices(Group::Scale);
        let moved = scale_idx.iter().any(|&i| {
            exp.server.params.tensors[i].iter().any(|&s| (s - 1.0).abs() > 1e-7)
        });
        assert!(moved, "scale updates accepted but server scales still 1.0");
    }
    assert!(exp.replicas_in_sync());
}

#[test]
fn partial_participation_still_syncs() {
    let Some(rt) = runtime() else { return };
    let mut cfg = quick(Protocol::Fsfl);
    cfg.clients = 4;
    cfg.participation = 0.5;
    cfg.rounds = 3;
    cfg.train_per_client = 32;
    let mut exp = Experiment::build(&rt, cfg).unwrap();
    let log = exp.run().unwrap();
    assert!(exp.replicas_in_sync());
    // only 2 of 4 clients upload per round
    for r in &log.rounds {
        assert_eq!(r.client_sparsity.len(), 2);
    }
}

#[test]
fn deterministic_given_seed() {
    let Some(rt) = runtime() else { return };
    let mk = || {
        let mut c = quick(Protocol::Fsfl);
        c.rounds = 2;
        c.seed = 42;
        c
    };
    let mut a = Experiment::build(&rt, mk()).unwrap();
    let la = a.run().unwrap();
    let mut b = Experiment::build(&rt, mk()).unwrap();
    let lb = b.run().unwrap();
    for (ra, rb) in la.rounds.iter().zip(&lb.rounds) {
        assert_eq!(ra.up_bytes, rb.up_bytes);
        assert_eq!(ra.accuracy, rb.accuracy);
    }
}
