//! Session-plane conformance and robustness suite (PJRT-free: the
//! whole plane runs on the deterministic `fl::synth` compute plane, so
//! every test here runs in CI on the vendored null XLA backend).
//!
//! 1. **Resume conformance** — for each transport in {mpsc, loopback,
//!    tcp} (× plain/bidirectional), a run crashed at round k and then
//!    resumed from its snapshot produces a final `RunLog` byte-identical
//!    to the uninterrupted run (the synthetic eval is a checksum of
//!    every aggregated broadcast, so metric equality pins the remaining
//!    bitstreams bit for bit).
//! 2. **Elastic membership** — shards leaving and replacements
//!    re-joining at round boundaries (state migrating over the wire
//!    `STATE` pair) leave the `RunLog` byte-identical to the
//!    static-membership run; the shard set also **resizes** N→M (grow
//!    2→3, shrink 3→1, combined churn, a resize straddling a
//!    crash/`--resume` boundary, and listener-admitted late joiners)
//!    with the same byte-identity guarantee.
//! 3. **Robustness** — a torn (kill-mid-write) snapshot is skipped in
//!    favor of the previous valid one; malformed client states are
//!    rejected before anything is mutated.
//! 4. **Real kill** — an `fsfl run --synth` child process is killed
//!    mid-run with SIGKILL and `fsfl run --resume` reproduces the
//!    uninterrupted run's CSV byte for byte.
//! 5. **Cold-state paging** — `resident_clients` is a pure memory knob:
//!    a minimal budget (1) must leave the `RunLog` rounds, the measured
//!    wire bytes and the emitted CSV byte-identical to the fully
//!    resident run (budget 0), including across a crash/`--resume`
//!    boundary. The stateful spill→rehydrate codec round-trip itself is
//!    pinned by the `session::pager` unit suite
//!    (`spill_and_rehydrate_round_trips_exactly`).

mod common;

use std::io::BufRead;
use std::path::PathBuf;
use std::process::{Command, Stdio};

use common::*;

use fsfl::coordinator::{self, ComputeSpec, ElasticPlan};
use fsfl::data::TaskKind;
use fsfl::fl::{
    Client, ExperimentConfig, LrSchedule, Protocol, ScheduleKind, SessionConfig, TransportKind,
};
use fsfl::model::ParamSet;
use fsfl::session::SessionStore;

/// A unique temp dir per test leg (removed on success; best effort).
/// CI points `FSFL_SESSION_TMP` at a known root so checkpoint dirs of
/// *failed* legs survive for the artifact upload.
fn tmp_dir(tag: &str) -> PathBuf {
    let root = std::env::var_os("FSFL_SESSION_TMP")
        .map(PathBuf::from)
        .unwrap_or_else(std::env::temp_dir);
    let _ = std::fs::create_dir_all(&root);
    let d = root.join(format!("fsfl_session_{}_{tag}", std::process::id()));
    let _ = std::fs::remove_dir_all(&d);
    std::fs::create_dir_all(&d).unwrap();
    d
}

fn scfg(transport: TransportKind, shards: usize) -> ExperimentConfig {
    let mut cfg = ExperimentConfig::quick("synth", TaskKind::CifarLike, Protocol::Fsfl);
    cfg.clients = 5;
    cfg.rounds = 6;
    cfg.participation = 0.6; // 3 of 5 participate per round
    cfg.seed = 77;
    cfg.compute_shards = shards;
    cfg.transport = transport;
    cfg
}

const TRANSPORTS: [TransportKind; 3] = [
    TransportKind::Mpsc,
    TransportKind::Loopback,
    TransportKind::Tcp,
];

// ---------------------------------------------------------------------------
// 1 · resume conformance
// ---------------------------------------------------------------------------

#[test]
fn crashed_run_resumes_byte_identical_across_transports() {
    let m = manifest();
    for transport in TRANSPORTS {
        for bidir in [false, true] {
            let tag = format!("{}{}", transport.name(), if bidir { "_bidir" } else { "" });
            // Reference: the uninterrupted run.
            let mut ref_cfg = scfg(transport, 2);
            ref_cfg.bidirectional = bidir;
            let reference =
                coordinator::run_experiment_synthetic(ref_cfg, m.clone(), |_| {}).unwrap();
            assert_eq!(reference.rounds.len(), 6);

            // Victim: checkpoint every round, injected crash after round 2.
            let dir = tmp_dir(&format!("resume_{tag}"));
            let mut cfg = scfg(transport, 2);
            cfg.bidirectional = bidir;
            cfg.session = Some(SessionConfig {
                dir: dir.clone(),
                every: 1,
                retain: SessionConfig::DEFAULT_RETAIN,
                crash_after: Some(2),
            });
            let err = coordinator::run_experiment_synthetic(cfg, m.clone(), |_| {}).unwrap_err();
            assert!(
                format!("{err:#}").contains("injected crash"),
                "{tag}: expected the injected crash, got: {err:#}"
            );

            // Resume from the newest snapshot and finish the run.
            let store = SessionStore::open(&dir).unwrap();
            let state = store.latest().unwrap().expect("snapshot written");
            assert_eq!(state.next_round, 3, "{tag}: crash after round 2");
            assert!(state.synthetic);
            assert_eq!(state.rounds.len(), 3);
            let resumed = coordinator::run_experiment_synthetic_session(
                state.cfg.clone(),
                m.clone(),
                ElasticPlan::default(),
                Some(state),
                |_| {},
            )
            .unwrap();
            assert_eq!(
                resumed.rounds, reference.rounds,
                "{tag}: resumed RunLog diverged from the uninterrupted run"
            );
            let _ = std::fs::remove_dir_all(&dir);
        }
    }
}

#[test]
fn resume_rejects_a_mismatched_config() {
    let m = manifest();
    let dir = tmp_dir("cfg_mismatch");
    let mut cfg = scfg(TransportKind::Loopback, 2);
    cfg.session = Some(SessionConfig {
        dir: dir.clone(),
        every: 1,
        retain: SessionConfig::DEFAULT_RETAIN,
        crash_after: Some(1),
    });
    let _ = coordinator::run_experiment_synthetic(cfg, m.clone(), |_| {}).unwrap_err();
    let state = SessionStore::open(&dir).unwrap().latest().unwrap().unwrap();
    let mut wrong = state.cfg.clone();
    wrong.seed ^= 1; // a different experiment
    let err = coordinator::run_experiment_synthetic_session(
        wrong,
        m.clone(),
        ElasticPlan::default(),
        Some(state),
        |_| {},
    )
    .unwrap_err();
    assert!(
        format!("{err:#}").contains("resume config"),
        "undescriptive: {err:#}"
    );
    let _ = std::fs::remove_dir_all(&dir);
}

// ---------------------------------------------------------------------------
// 2 · elastic membership
// ---------------------------------------------------------------------------

#[test]
fn shard_replacement_at_round_boundaries_is_byte_identical() {
    let m = manifest();
    for transport in TRANSPORTS {
        let reference =
            coordinator::run_experiment_synthetic(scfg(transport, 3), m.clone(), |_| {}).unwrap();
        // Shard 0 (the eval shard) leaves at round 1, shard 2 at round
        // 2, shard 1 at round 4 — each replaced by a fresh worker that
        // re-joins through INIT/READY and is rehydrated over the wire.
        let plan = ElasticPlan {
            replace: vec![(1, 0), (2, 2), (4, 1)],
            ..Default::default()
        };
        let log = coordinator::run_experiment_synthetic_session(
            scfg(transport, 3),
            m.clone(),
            plan,
            None,
            |_| {},
        )
        .unwrap();
        assert_eq!(
            log.rounds,
            reference.rounds,
            "{}: membership churn changed the RunLog",
            transport.name()
        );
        if transport.is_wire() {
            let churn = log.wire.expect("wire transports measure traffic");
            let still = reference.wire.expect("wire transports measure traffic");
            assert!(
                churn.total() > still.total(),
                "{}: re-join handshakes + state migration must show up in measured wire bytes",
                transport.name()
            );
        }
    }
}

#[test]
fn resizing_the_shard_set_is_byte_identical_across_transports() {
    let m = manifest();
    for transport in TRANSPORTS {
        let reference =
            coordinator::run_experiment_synthetic(scfg(transport, 2), m.clone(), |_| {}).unwrap();
        // Grow 2→3 before round 2, shrink 3→1 before round 4 — the
        // N→M→(smaller) churn script of the acceptance grid. Client
        // state (on the synth plane: the replica params + round
        // counters) migrates under the recomputed assignment both ways.
        let plan = ElasticPlan {
            resize: vec![(2, 3), (4, 1)],
            ..Default::default()
        };
        let log = coordinator::run_experiment_synthetic_session(
            scfg(transport, 2),
            m.clone(),
            plan,
            None,
            |_| {},
        )
        .unwrap();
        assert_eq!(
            log.rounds,
            reference.rounds,
            "{}: resizing changed the RunLog",
            transport.name()
        );
        if transport.is_wire() {
            let churn = log.wire.expect("wire transports measure traffic");
            let still = reference.wire.expect("wire transports measure traffic");
            assert!(
                churn.total() > still.total(),
                "{}: resize handshakes + state migration must show up in measured wire bytes",
                transport.name()
            );
        }
    }
}

#[test]
fn combined_replace_and_resize_churn_is_byte_identical() {
    let m = manifest();
    let reference =
        coordinator::run_experiment_synthetic(scfg(TransportKind::Tcp, 2), m.clone(), |_| {})
            .unwrap();
    // A full churn script: replace shard 1, grow 2→3 at the same
    // boundary, replace a grown shard, then shrink back 3→2 — ending at
    // the starting count (the N→M→N cycle).
    let plan = ElasticPlan {
        replace: vec![(1, 1), (3, 2)],
        resize: vec![(1, 3), (4, 2)],
    };
    let log = coordinator::run_experiment_synthetic_session(
        scfg(TransportKind::Tcp, 2),
        m.clone(),
        plan,
        None,
        |_| {},
    )
    .unwrap();
    assert_eq!(
        log.rounds, reference.rounds,
        "combined replace+resize churn changed the RunLog"
    );
}

#[test]
fn resize_across_a_crash_resume_boundary_is_byte_identical() {
    let m = manifest();
    for transport in TRANSPORTS {
        let tag = transport.name();
        let reference =
            coordinator::run_experiment_synthetic(scfg(transport, 2), m.clone(), |_| {}).unwrap();

        // Victim: grow 2→3 before round 2, checkpoint every round,
        // crash after round 3 — so the newest snapshot was taken by the
        // *post-resize* membership and records 3 shards.
        let dir = tmp_dir(&format!("resize_resume_{tag}"));
        let mut cfg = scfg(transport, 2);
        cfg.session = Some(SessionConfig {
            dir: dir.clone(),
            every: 1,
            retain: SessionConfig::DEFAULT_RETAIN,
            crash_after: Some(3),
        });
        let plan = ElasticPlan {
            resize: vec![(2, 3)],
            ..Default::default()
        };
        let err = coordinator::run_experiment_synthetic_session(
            cfg,
            m.clone(),
            plan,
            None,
            |_| {},
        )
        .unwrap_err();
        assert!(
            format!("{err:#}").contains("injected crash"),
            "{tag}: expected the injected crash, got: {err:#}"
        );

        // The snapshot carries the live (resized) assignment…
        let store = SessionStore::open(&dir).unwrap();
        let state = store.latest().unwrap().expect("snapshot written");
        assert_eq!(state.next_round, 4, "{tag}: crash after round 3");
        assert_eq!(
            state.shards, 3,
            "{tag}: snapshot must record the post-resize shard count"
        );
        // …and resume rebuilds exactly that membership (the config
        // still says compute_shards = 2) and finishes byte-identically.
        let resumed = coordinator::run_experiment_synthetic_session(
            state.cfg.clone(),
            m.clone(),
            ElasticPlan::default(),
            Some(state),
            |_| {},
        )
        .unwrap();
        assert_eq!(
            resumed.rounds, reference.rounds,
            "{tag}: resume across the resize diverged from the uninterrupted run"
        );
        let _ = std::fs::remove_dir_all(&dir);
    }
}

#[test]
fn serve_admits_late_joiners_from_its_listener_for_resize_and_replace() {
    use std::net::TcpListener;

    let m = manifest();
    let reference =
        coordinator::run_experiment_synthetic(scfg(TransportKind::Tcp, 2), m.clone(), |_| {})
            .unwrap();

    // The external-autoscaler shape: workers are launched *outside* the
    // coordinator and join through its TCP listener. 2 initial workers
    // + 1 for the grown slot + 1 for the replacement all connect up
    // front; the surplus wait in the accept backlog until their
    // membership boundary admits them.
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap();
    let workers: Vec<_> = (0..4)
        .map(|_| {
            std::thread::spawn(move || coordinator::join_shard(&addr.to_string()))
        })
        .collect();
    let plan = ElasticPlan {
        replace: vec![(3, 0)],
        resize: vec![(1, 3), (4, 2)],
    };
    let log = coordinator::serve_session(
        scfg(TransportKind::Tcp, 2),
        &listener,
        ComputeSpec::Synthetic { manifest: m.clone() },
        plan,
        None,
        || Ok(()),
        |_| {},
    )
    .unwrap();
    for w in workers {
        w.join().unwrap().unwrap();
    }
    assert_eq!(
        log.rounds, reference.rounds,
        "listener-admitted churn changed the RunLog"
    );
}

// ---------------------------------------------------------------------------
// 3 · robustness
// ---------------------------------------------------------------------------

#[test]
fn torn_snapshot_falls_back_to_previous_checkpoint_on_resume() {
    let m = manifest();
    let dir = tmp_dir("torn");
    let mut cfg = scfg(TransportKind::Loopback, 2);
    cfg.session = Some(SessionConfig {
        dir: dir.clone(),
        every: 1,
        retain: SessionConfig::DEFAULT_RETAIN,
        crash_after: Some(3),
    });
    let _ = coordinator::run_experiment_synthetic(cfg, m.clone(), |_| {}).unwrap_err();

    // Simulate a kill mid-write: truncate the newest snapshot.
    let store = SessionStore::open(&dir).unwrap();
    let snaps = store.snapshots().unwrap();
    let (newest_round, newest_path) = snaps.last().cloned().unwrap();
    assert_eq!(newest_round, 4, "crash after round 3 leaves snapshot 4");
    let bytes = std::fs::read(&newest_path).unwrap();
    std::fs::write(&newest_path, &bytes[..bytes.len() / 3]).unwrap();

    let state = store.latest().unwrap().expect("an older snapshot survives");
    assert_eq!(
        state.next_round, 3,
        "resume must fall back to the previous valid checkpoint"
    );
    // Clear the injected crash for the resumed leg (operational session
    // settings may differ on resume; the experiment itself may not).
    let mut resume_cfg = state.cfg.clone();
    if let Some(s) = resume_cfg.session.as_mut() {
        s.crash_after = None;
    }
    let resumed = coordinator::run_experiment_synthetic_session(
        resume_cfg,
        m.clone(),
        ElasticPlan::default(),
        Some(state),
        |_| {},
    )
    .unwrap();
    let reference =
        coordinator::run_experiment_synthetic(scfg(TransportKind::Loopback, 2), m.clone(), |_| {})
            .unwrap();
    assert_eq!(
        resumed.rounds, reference.rounds,
        "resume from the fallback checkpoint diverged"
    );
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn client_state_import_validates_before_mutating() {
    let m = manifest();
    let init = ParamSet::new(
        m.clone(),
        m.tensors.iter().map(|t| vec![0.0; t.numel()]).collect(),
    )
    .unwrap();
    let mut client = Client::new(
        0,
        init,
        vec![0, 1, 2, 3],
        vec![4, 5],
        LrSchedule::new(ScheduleKind::Linear, 0.1, 20, 5),
        true, // residuals on
        9,
    );
    let good = client.export_state();

    let mut bad = good.clone();
    bad.id = 1;
    assert!(client.import_state(&bad).is_err(), "wrong id accepted");
    let mut bad = good.clone();
    bad.train_order.push(9);
    assert!(
        client.import_state(&bad).is_err(),
        "wrong train-order length accepted"
    );
    let mut bad = good.clone();
    bad.residual = None;
    assert!(
        client.import_state(&bad).is_err(),
        "missing residual accepted"
    );
    let mut bad = good.clone();
    bad.residual = Some(vec![vec![0.0; 2]]); // wrong slab count
    assert!(
        client.import_state(&bad).is_err(),
        "mis-shaped residual accepted"
    );
    let mut bad = good.clone();
    bad.wopt.m[0].push(0.0); // wrong moment slab length
    assert!(
        client.import_state(&bad).is_err(),
        "mis-shaped optimizer moments accepted"
    );

    // After every rejected import the state is untouched (no partial
    // apply), and the good state still installs cleanly.
    assert_eq!(client.export_state(), good);
    client.import_state(&good).unwrap();
    assert_eq!(client.export_state(), good);
}

// ---------------------------------------------------------------------------
// 4 · a real kill -9 of a real process
// ---------------------------------------------------------------------------

#[test]
fn killed_fsfl_process_resumes_byte_identical_on_the_synth_plane() {
    let exe = env!("CARGO_BIN_EXE_fsfl");
    let base = tmp_dir("proc_kill");
    let out_ref = base.join("out_ref");
    let out_victim = base.join("out_victim");
    let out_resumed = base.join("out_resumed");
    let ckpt = base.join("ckpt");
    // The run grows 2→3 shards before round 2, so the SIGKILL below
    // (after three round lines) lands *after* the resize: the resumed
    // run must rebuild the post-resize membership from the snapshot.
    let run_args = [
        "run",
        "--synth",
        "--clients",
        "4",
        "--rounds",
        "6",
        "--compute-shards",
        "2",
        "--elastic-resize",
        "2:3",
        "--transport",
        "loopback",
        "--seed",
        "11",
    ];

    // Reference: an uninterrupted run.
    let status = Command::new(exe)
        .args(run_args)
        .arg("--out")
        .arg(&out_ref)
        .stdout(Stdio::null())
        .status()
        .unwrap();
    assert!(status.success(), "reference run failed");

    // Victim: checkpoint every round; SIGKILL it after three round
    // lines — past the 2→3 resize — (a round line is printed only
    // after its snapshot is on disk).
    let mut child = Command::new(exe)
        .args(run_args)
        .arg("--checkpoint-dir")
        .arg(&ckpt)
        .arg("--out")
        .arg(&out_victim)
        .stdout(Stdio::piped())
        .spawn()
        .unwrap();
    {
        let stdout = child.stdout.take().expect("piped stdout");
        let reader = std::io::BufReader::new(stdout);
        let mut round_lines = 0usize;
        for line in reader.lines() {
            let line = line.unwrap_or_default();
            if line.starts_with("round") {
                round_lines += 1;
                if round_lines >= 3 {
                    break;
                }
            }
        }
        assert!(round_lines >= 1, "victim produced no round lines");
    }
    let _ = child.kill(); // SIGKILL — no cleanup, a genuine crash
    let _ = child.wait();

    // Resume and finish.
    let status = Command::new(exe)
        .args(["run", "--resume"])
        .arg(&ckpt)
        .arg("--out")
        .arg(&out_resumed)
        .stdout(Stdio::null())
        .status()
        .unwrap();
    assert!(status.success(), "resume run failed");

    // The resumed run's CSV (snapshot rounds + re-run rounds) must be
    // byte-identical to the uninterrupted run's.
    let name = "synth-FSFL.csv";
    let a = std::fs::read(out_ref.join(name)).unwrap();
    let b = std::fs::read(out_resumed.join(name)).unwrap();
    assert_eq!(
        a, b,
        "resumed CSV differs from the uninterrupted run's CSV"
    );
    let _ = std::fs::remove_dir_all(&base);
}

// ---------------------------------------------------------------------------
// 5 · cold-state paging
// ---------------------------------------------------------------------------

/// `scfg` with a cold-state paging budget.
fn pcfg(transport: TransportKind, shards: usize, resident: usize) -> ExperimentConfig {
    let mut cfg = scfg(transport, shards);
    cfg.resident_clients = resident;
    cfg
}

#[test]
fn paging_budget_is_byte_identical_across_transports() {
    // The budget crosses the INIT handshake (wire config v5), drives
    // the per-round page-in/evict bracket on stateful shards, and must
    // never perturb selection, scheduling, bitstreams or the measured
    // frame-layer traffic. Budget 1 is the harshest setting: every
    // non-selected client is cold between rounds.
    let m = manifest();
    for transport in TRANSPORTS {
        let reference =
            coordinator::run_experiment_synthetic(pcfg(transport, 2, 0), m.clone(), |_| {})
                .unwrap();
        let paged =
            coordinator::run_experiment_synthetic(pcfg(transport, 2, 1), m.clone(), |_| {})
                .unwrap();
        assert_eq!(
            paged.rounds,
            reference.rounds,
            "{}: a resident budget of 1 changed the RunLog",
            transport.name()
        );
        assert_eq!(
            paged.wire,
            reference.wire,
            "{}: a resident budget of 1 changed the measured wire bytes",
            transport.name()
        );
    }
}

#[test]
fn paging_budget_is_byte_identical_across_crash_and_resume() {
    let m = manifest();
    for transport in TRANSPORTS {
        let tag = transport.name();
        // Reference: fully resident, uninterrupted.
        let reference =
            coordinator::run_experiment_synthetic(pcfg(transport, 2, 0), m.clone(), |_| {})
                .unwrap();

        // Victim: budget 1, checkpoint every round, crash after round 2.
        let dir = tmp_dir(&format!("paging_resume_{tag}"));
        let mut cfg = pcfg(transport, 2, 1);
        cfg.session = Some(SessionConfig {
            dir: dir.clone(),
            every: 1,
            retain: SessionConfig::DEFAULT_RETAIN,
            crash_after: Some(2),
        });
        let err = coordinator::run_experiment_synthetic(cfg, m.clone(), |_| {}).unwrap_err();
        assert!(
            format!("{err:#}").contains("injected crash"),
            "{tag}: expected the injected crash, got: {err:#}"
        );

        // Resume keeps the budget (it is part of the snapshot config)
        // and must still land on the fully-resident reference.
        let store = SessionStore::open(&dir).unwrap();
        let state = store.latest().unwrap().expect("snapshot written");
        assert_eq!(state.next_round, 3, "{tag}: crash after round 2");
        assert_eq!(
            state.cfg.resident_clients, 1,
            "{tag}: snapshot must preserve the paging budget"
        );
        let resumed = coordinator::run_experiment_synthetic_session(
            state.cfg.clone(),
            m.clone(),
            ElasticPlan::default(),
            Some(state),
            |_| {},
        )
        .unwrap();
        assert_eq!(
            resumed.rounds, reference.rounds,
            "{tag}: paged resume diverged from the fully-resident run"
        );
        let _ = std::fs::remove_dir_all(&dir);
    }
}

#[test]
fn fsfl_run_with_a_resident_budget_pins_the_csv() {
    // End-to-end CLI plumbing: `--resident-clients 1` must leave the
    // emitted CSV byte-identical to the unflagged run.
    let exe = env!("CARGO_BIN_EXE_fsfl");
    let base = tmp_dir("paging_csv");
    let out_ref = base.join("out_ref");
    let out_paged = base.join("out_paged");
    let run_args = [
        "run",
        "--synth",
        "--clients",
        "4",
        "--rounds",
        "5",
        "--compute-shards",
        "2",
        "--transport",
        "loopback",
        "--seed",
        "11",
    ];
    let status = Command::new(exe)
        .args(run_args)
        .arg("--out")
        .arg(&out_ref)
        .stdout(Stdio::null())
        .status()
        .unwrap();
    assert!(status.success(), "reference run failed");
    let status = Command::new(exe)
        .args(run_args)
        .args(["--resident-clients", "1"])
        .arg("--out")
        .arg(&out_paged)
        .stdout(Stdio::null())
        .status()
        .unwrap();
    assert!(status.success(), "paged run failed");

    let name = "synth-FSFL.csv";
    let a = std::fs::read(out_ref.join(name)).unwrap();
    let b = std::fs::read(out_paged.join(name)).unwrap();
    assert_eq!(a, b, "--resident-clients 1 changed the CSV output");
    let _ = std::fs::remove_dir_all(&base);
}
