//! Shared harness helpers for the integration suites
//! (`integration_parallel.rs`, `integration_transport.rs`): the
//! hand-built test manifest, deterministic synthetic deltas (thin
//! wrappers over `fl::synth`), the codec-round driver, and byte-level
//! lane fingerprints. One copy so the parallel-equivalence and
//! transport-conformance suites can never drift apart on what
//! "identical" means.
#![allow(dead_code)]

use std::sync::Arc;

use fsfl::compression::{QuantConfig, SparsifyMode};
use fsfl::exec::WorkerPool;
use fsfl::fl::scheduler::{self, ScheduleMode};
use fsfl::fl::synth::{synth_client_delta, synth_scale_delta};
use fsfl::fl::{Protocol, ProtocolConfig, RoundLane, SyntheticPlane};
use fsfl::model::params::Delta;
use fsfl::model::{Group, Kind, Manifest, TensorSpec};

/// Client count the codec-plane suites run with.
pub const CLIENTS: usize = 8;

/// Hand-built three-tensor manifest: a row-structured conv weight, its
/// fine-quantized bias, and a per-filter scale vector.
pub fn manifest() -> Arc<Manifest> {
    let tensors = vec![
        TensorSpec {
            name: "c.w".into(),
            shape: vec![16, 48],
            kind: Kind::ConvW,
            group: Group::Weight,
            layer: "c".into(),
            out_ch: Some(16),
            scale_for: None,
        },
        TensorSpec {
            name: "c.b".into(),
            shape: vec![16],
            kind: Kind::Bias,
            group: Group::Weight,
            layer: "c".into(),
            out_ch: Some(16),
            scale_for: None,
        },
        TensorSpec {
            name: "c.s".into(),
            shape: vec![16],
            kind: Kind::Scale,
            group: Group::Scale,
            layer: "c".into(),
            out_ch: Some(16),
            scale_for: Some("c.w".into()),
        },
    ];
    Arc::new(Manifest {
        model: "t".into(),
        variant: "t".into(),
        classes: 2,
        input: vec![4, 4, 1],
        batch: 1,
        param_count: 16 * 48 + 16 + 16,
        scale_count: 16,
        tensors,
    })
}

/// Allocating wrapper over [`synth_client_delta`].
pub fn client_delta(m: &Arc<Manifest>, seed: u64) -> Delta {
    let mut d = Delta::zeros(m.clone());
    synth_client_delta(m, seed, &mut d);
    d
}

/// Allocating wrapper over [`synth_scale_delta`].
pub fn scale_delta(m: &Arc<Manifest>, seed: u64) -> Delta {
    let mut d = Delta::zeros(m.clone());
    synth_scale_delta(m, seed, &mut d);
    d
}

/// Run the codec stages of one round over `lanes` at the given pool
/// width, from fixed inputs. Every other lane carries a scale update,
/// so both the W and S streams are exercised.
pub fn codec_round(
    lanes: &mut [RoundLane],
    pool: &WorkerPool,
    pcfg: &ProtocolConfig,
    m: &Arc<Manifest>,
    round_seed: u64,
) {
    let update_idx = m.update_indices();
    let scale_idx = m.group_indices(Group::Scale);
    for (k, lane) in lanes.iter_mut().enumerate() {
        lane.begin(k);
        lane.raw.copy_from(&client_delta(m, round_seed + k as u64));
    }
    pool.run_mut(lanes, |_, lane| lane.encode_upstream(pcfg, &update_idx));
    for (k, lane) in lanes.iter_mut().enumerate() {
        if pcfg.scaled && k % 2 == 0 {
            lane.sdelta.copy_from(&scale_delta(m, round_seed + k as u64));
            lane.scale_accepted = true;
        }
    }
    pool.run_mut(lanes, |_, lane| lane.finish_round(pcfg, &scale_idx));
    for lane in lanes.iter_mut() {
        if let Some(e) = lane.error.take() {
            panic!("codec stage failed: {e:#}");
        }
    }
}

/// Byte-level fingerprint of everything a round produced.
pub type RoundFp = Vec<(Vec<Vec<u8>>, u64, u64, usize)>;

/// Fingerprint `lanes`: exact stream bytes, client-view and decoded
/// checksums, and upstream byte accounting.
pub fn fingerprint(lanes: &[RoundLane]) -> RoundFp {
    lanes
        .iter()
        .map(|l| {
            (
                l.streams().iter().map(|s| s.to_vec()).collect(),
                l.update.checksum(),
                l.decoded.checksum(),
                l.up_bytes,
            )
        })
        .collect()
}

/// Pool widths every equivalence suite sweeps: serial, small, machine.
pub fn pool_widths() -> Vec<usize> {
    let ncpu = std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(4);
    vec![1, 2, ncpu]
}

/// Every Table-2 protocol preset the codec suites sweep.
pub fn protocols() -> Vec<(&'static str, ProtocolConfig)> {
    let q = QuantConfig::default();
    let dynamic = SparsifyMode::Dynamic {
        delta: 1.0,
        gamma: 1.0,
    };
    let topk = SparsifyMode::TopK { rate: 0.9 };
    vec![
        ("fedavg", Protocol::FedAvg.config(dynamic, q)),
        ("fedavg_q", Protocol::FedAvgQ.config(dynamic, q)),
        ("fsfl", Protocol::Fsfl.config(dynamic, q)),
        ("stc", Protocol::Stc.config(topk, q)),
        ("stc_scaled", Protocol::StcScaled.config(topk, q)),
        ("eqs23", Protocol::SparseOnly.config(dynamic, q)),
    ]
}

/// Drive one scheduled round over `lanes` on the library's
/// [`SyntheticPlane`] and surface codec errors.
pub fn scheduled_round(
    mode: ScheduleMode,
    pool: &WorkerPool,
    lanes: &mut Vec<RoundLane>,
    order: &[usize],
    pcfg: &ProtocolConfig,
    m: &Arc<Manifest>,
    round_seed: u64,
) {
    let update_idx = m.update_indices();
    let scale_idx = m.group_indices(Group::Scale);
    let mut compute = SyntheticPlane {
        manifest: m.clone(),
        round_seed,
        scaled: pcfg.scaled,
        straggle: None,
    };
    scheduler::run_round(
        mode,
        pool,
        &mut compute,
        lanes,
        order,
        pcfg,
        &update_idx,
        &scale_idx,
    )
    .unwrap();
    for lane in lanes.iter_mut() {
        if let Some(e) = lane.error.take() {
            panic!("codec stage failed: {e:#}");
        }
    }
}
