//! Integration tests over the PJRT runtime + AOT artifacts: the rust
//! side must faithfully drive the jax-lowered step functions.
//!
//! Requires `make artifacts` (tiny_cnn) — the Makefile test target
//! guarantees this ordering.

use fsfl::data::{batches, Dataset, TaskKind, TaskSpec};
use fsfl::model::Group;
use fsfl::runtime::{ModelRuntime, Optimizer, Runtime};

fn artifacts_root() -> std::path::PathBuf {
    std::env::var("FSFL_ARTIFACTS")
        .map(Into::into)
        .unwrap_or_else(|_| std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts"))
}

/// PJRT runtime + tiny_cnn artifacts, or `None` (test skips) when the
/// build uses the null xla backend or `make artifacts` hasn't run.
fn runtime() -> Option<Runtime> {
    if !artifacts_root().join("tiny_cnn").join("manifest.tsv").exists() {
        eprintln!("skipping: no artifacts (run `make artifacts`)");
        return None;
    }
    match Runtime::cpu() {
        Ok(rt) => Some(rt),
        Err(e) => {
            eprintln!("skipping: {e}");
            None
        }
    }
}

fn batch_for(mr: &ModelRuntime) -> (Vec<f32>, Vec<f32>) {
    let man = &mr.manifest;
    let spec = TaskSpec::new(TaskKind::CifarLike, man.input[0], man.input[2], 7);
    let ds = Dataset::generate(&spec, man.batch, 0);
    let order: Vec<usize> = (0..ds.len()).collect();
    let b = batches(&ds, &order, man.batch).remove(0);
    (b.x, b.y)
}

#[test]
fn train_step_learns_and_freezes_scales() {
    let Some(rt) = runtime() else { return };
    let mr = ModelRuntime::open(&rt, artifacts_root(), "tiny_cnn").unwrap();
    let mut params = mr.init_params().unwrap();
    let before_scales: Vec<Vec<f32>> = params
        .group_indices(Group::Scale)
        .iter()
        .map(|&i| params.tensors[i].clone())
        .collect();
    let mut opt = mr.opt_state(Group::Weight);
    let (x, y) = batch_for(&mr);
    let mut losses = Vec::new();
    for _ in 0..30 {
        let out = mr
            .train_step(&mut params, &mut opt, Optimizer::Adam, 5e-3, &x, &y)
            .unwrap();
        assert!(out.loss.is_finite());
        losses.push(out.loss);
    }
    assert!(
        losses.last().unwrap() < &(losses[0] * 0.7),
        "loss did not decrease: {losses:?}"
    );
    assert_eq!(opt.t, 30.0);
    // S must be untouched by weight training (Algorithm 1)
    for (slot, &i) in params.group_indices(Group::Scale).iter().enumerate() {
        assert_eq!(params.tensors[i], before_scales[slot], "scale {i} changed");
    }
}

#[test]
fn scale_step_only_moves_scales() {
    let Some(rt) = runtime() else { return };
    let mr = ModelRuntime::open(&rt, artifacts_root(), "tiny_cnn").unwrap();
    let mut params = mr.init_params().unwrap();
    let baseline = params.clone();
    let mut opt = mr.opt_state(Group::Scale);
    let (x, y) = batch_for(&mr);
    for _ in 0..5 {
        mr.scale_step(&mut params, &mut opt, Optimizer::Adam, 5e-2, &x, &y)
            .unwrap();
    }
    let scale_idx = params.group_indices(Group::Scale);
    let mut changed = 0;
    for (i, (t, b)) in params.tensors.iter().zip(&baseline.tensors).enumerate() {
        if scale_idx.contains(&i) {
            if t != b {
                changed += 1;
            }
        } else {
            assert_eq!(t, b, "non-scale tensor {i} changed during scale step");
        }
    }
    assert!(changed > 0, "no scales moved");
}

#[test]
fn sgd_variants_run() {
    let Some(rt) = runtime() else { return };
    let mr = ModelRuntime::open(&rt, artifacts_root(), "tiny_cnn").unwrap();
    let mut params = mr.init_params().unwrap();
    let (x, y) = batch_for(&mr);
    let mut wopt = mr.opt_state(Group::Weight);
    let out = mr
        .train_step(&mut params, &mut wopt, Optimizer::Sgd, 1e-2, &x, &y)
        .unwrap();
    assert!(out.loss.is_finite());
    let mut sopt = mr.opt_state(Group::Scale);
    let out = mr
        .scale_step(&mut params, &mut sopt, Optimizer::Sgd, 1e-2, &x, &y)
        .unwrap();
    assert!(out.loss.is_finite());
}

#[test]
fn eval_is_deterministic_and_stateless() {
    let Some(rt) = runtime() else { return };
    let mr = ModelRuntime::open(&rt, artifacts_root(), "tiny_cnn").unwrap();
    let params = mr.init_params().unwrap();
    let snapshot = params.clone();
    let (x, y) = batch_for(&mr);
    let a = mr.eval_step(&params, &x, &y).unwrap();
    let b = mr.eval_step(&params, &x, &y).unwrap();
    assert_eq!(a.loss, b.loss);
    assert_eq!(a.correct, b.correct);
    assert!(a.correct >= 0.0 && a.correct <= mr.batch_size() as f32);
    assert_eq!(params, snapshot);
}

#[test]
fn predict_matches_classes() {
    let Some(rt) = runtime() else { return };
    let mr = ModelRuntime::open(&rt, artifacts_root(), "tiny_cnn").unwrap();
    let params = mr.init_params().unwrap();
    let (x, _y) = batch_for(&mr);
    let preds = mr.predict_step(&params, &x).unwrap();
    assert_eq!(preds.len(), mr.batch_size());
    for &p in &preds {
        assert!(p >= 0.0 && (p as usize) < mr.manifest.classes);
        assert_eq!(p.fract(), 0.0);
    }
}

#[test]
fn predict_consistent_with_eval_correct_count() {
    let Some(rt) = runtime() else { return };
    let mr = ModelRuntime::open(&rt, artifacts_root(), "tiny_cnn").unwrap();
    let params = mr.init_params().unwrap();
    let (x, y) = batch_for(&mr);
    let ev = mr.eval_step(&params, &x, &y).unwrap();
    let preds = mr.predict_step(&params, &x).unwrap();
    let classes = mr.manifest.classes;
    let correct = preds
        .iter()
        .enumerate()
        .filter(|(i, &p)| y[i * classes + p as usize] == 1.0)
        .count();
    assert_eq!(correct as f32, ev.correct);
}

#[test]
fn manifest_and_bundle_agree() {
    let Some(rt) = runtime() else { return };
    let mr = ModelRuntime::open(&rt, artifacts_root(), "tiny_cnn").unwrap();
    let params = mr.init_params().unwrap();
    assert_eq!(params.numel(), mr.manifest.param_count);
    // scales initialized to 1 (Algorithm 1 init)
    for &i in &params.group_indices(Group::Scale) {
        assert!(params.tensors[i].iter().all(|&s| s == 1.0));
    }
    assert_eq!(mr.manifest.scale_param_count(), mr.manifest.scale_count);
}
