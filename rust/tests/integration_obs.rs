//! Observability-plane integration suite: the golden deterministic
//! trace, the live metrics endpoint, and the `--trace-out` /
//! `fsfl trace summarize` CLI round trip.
//!
//! 1. **Golden trace** — a 2-shard × 2-round synthetic run driven by a
//!    zero-tick [`ScriptedClock`] shared between the coordinator and
//!    the telemetry handle. Every span lands at t=0, so the exported
//!    Chrome-trace document is a pure function of the config: a rerun
//!    must reproduce it byte for byte, and the blessed fixture
//!    (`tests/fixtures/golden_trace.json`, `FSFL_BLESS=1` to re-bless)
//!    pins it across commits.
//! 2. **Registry agreement** — the metrics registry's round/byte
//!    counters must equal the `RunLog` the same run returned.
//! 3. **Metrics endpoint** — a real `GET /metrics` over localhost TCP
//!    against [`MetricsServer`] returns Prometheus text carrying the
//!    run's counters.
//! 4. **CLI round trip** — `fsfl run --synth --trace-out FILE` writes a
//!    trace the strict reader accepts, and `fsfl trace summarize FILE`
//!    renders the per-stage latency table from it.

mod common;

use std::path::PathBuf;
use std::sync::atomic::Ordering;
use std::sync::Arc;
use std::time::Duration;

use common::*;

use fsfl::coordinator::{self, ElasticPlan};
use fsfl::data::TaskKind;
use fsfl::fl::{ExperimentConfig, Protocol, TransportKind};
use fsfl::obs::{summarize, Telemetry};
use fsfl::supervise::ScriptedClock;

/// A unique temp dir per test (removed on success, kept on failure).
fn tmp_dir(tag: &str) -> PathBuf {
    let root = std::env::var_os("FSFL_SESSION_TMP")
        .map(PathBuf::from)
        .unwrap_or_else(std::env::temp_dir);
    let _ = std::fs::create_dir_all(&root);
    let d = root.join(format!("fsfl_obs_{}_{tag}", std::process::id()));
    let _ = std::fs::remove_dir_all(&d);
    std::fs::create_dir_all(&d).unwrap();
    d
}

/// The pinned trace cell: 2 mpsc shards, 2 rounds, 4 clients, fixed
/// seed — small enough that the golden fixture stays reviewable.
fn golden_cfg() -> ExperimentConfig {
    let mut cfg = ExperimentConfig::quick("synth", TaskKind::CifarLike, Protocol::Fsfl);
    cfg.clients = 4;
    cfg.rounds = 2;
    cfg.participation = 1.0;
    cfg.seed = 9;
    cfg.compute_shards = 2;
    cfg.transport = TransportKind::Mpsc;
    cfg
}

/// Run the golden cell under a zero-tick scripted clock and export its
/// trace. The same clock drives the run and timestamps the spans, so
/// nothing wall-clock-dependent reaches the document.
fn golden_trace() -> (String, fsfl::metrics::RunLog, Arc<Telemetry>) {
    let clock = Arc::new(ScriptedClock::new(Duration::ZERO));
    let telemetry = Telemetry::new(clock.clone(), true);
    let log = coordinator::run_experiment_synthetic_session_observed(
        golden_cfg(),
        manifest(),
        ElasticPlan::default(),
        None,
        Some(clock),
        Some(telemetry.clone()),
        |_| {},
    )
    .expect("golden cell must complete");
    let doc = fsfl::obs::chrome::render(&telemetry.drain_spans(), telemetry.dropped_spans());
    (doc, log, telemetry)
}

// ---------------------------------------------------------------------------
// 1 · golden deterministic trace
// ---------------------------------------------------------------------------

#[test]
fn golden_trace_is_byte_stable_and_pinned() {
    let fixture = PathBuf::from(env!("CARGO_MANIFEST_DIR"))
        .join("tests")
        .join("fixtures")
        .join("golden_trace.json");
    let (doc, log, _) = golden_trace();
    assert_eq!(log.rounds.len(), 2);

    // The exported document must satisfy the strict reader and the
    // summarize verb (the CI obs job gates on the same round trip).
    let summary = summarize::summarize_str(&doc).expect("exported trace must summarize");
    assert!(summary.contains("per-stage latency"), "got: {summary}");
    assert!(summary.contains("round 0:"), "got: {summary}");
    assert!(summary.contains("round 1:"), "got: {summary}");

    // Byte-identical rerun: scripted time erases scheduling noise.
    let (doc2, _, _) = golden_trace();
    assert_eq!(doc, doc2, "golden trace is not deterministic");

    if std::env::var_os("FSFL_BLESS").is_some() {
        let blessed = format!(
            "# Golden Chrome trace of the pinned 2-shard x 2-round synth\n\
             # cell (integration_obs.rs::golden_cfg, zero-tick scripted\n\
             # clock). Re-bless with FSFL_BLESS=1 after an intentional\n\
             # instrumentation change.\n\
             {doc}"
        );
        std::fs::write(&fixture, blessed).unwrap();
        return;
    }
    let raw = std::fs::read_to_string(&fixture)
        .unwrap_or_else(|e| panic!("missing fixture {}: {e}", fixture.display()));
    let body: String = raw
        .lines()
        .filter(|l| !l.starts_with('#'))
        .map(|l| format!("{l}\n"))
        .collect();
    if body.trim() == "PENDING-BLESS" {
        // Not blessed on a toolchain-bearing host yet; the rerun check
        // above already pins determinism.
        return;
    }
    assert_eq!(
        doc, body,
        "golden trace drifted from the blessed fixture; if the change is \
         intentional, re-bless with FSFL_BLESS=1 cargo test \
         golden_trace_is_byte_stable_and_pinned"
    );
}

// ---------------------------------------------------------------------------
// 2 · registry ↔ RunLog agreement
// ---------------------------------------------------------------------------

#[test]
fn registry_counters_agree_with_the_run_log() {
    let (_, log, telemetry) = golden_trace();
    let m = &telemetry.metrics;
    assert_eq!(
        m.rounds_total.load(Ordering::Relaxed) as usize,
        log.rounds.len()
    );
    assert_eq!(
        m.up_bytes_total.load(Ordering::Relaxed) as usize,
        log.total_bytes(true)
    );
    assert_eq!(
        m.down_bytes_total.load(Ordering::Relaxed) as usize,
        log.rounds.iter().map(|r| r.down_bytes).sum::<usize>()
    );
    assert_eq!(m.deaths_total.load(Ordering::Relaxed), 0);
    // The undisturbed mpsc run ends with no pending fan-in slots and no
    // paged clients.
    assert_eq!(m.fan_in_pending.load(Ordering::Relaxed), 0);
    assert_eq!(m.paged_clients.load(Ordering::Relaxed), 0);
}

// ---------------------------------------------------------------------------
// 3 · metrics endpoint over localhost TCP
// ---------------------------------------------------------------------------

#[test]
fn metrics_endpoint_serves_the_run_counters_over_tcp() {
    use std::io::{Read, Write};

    let (_, log, telemetry) = golden_trace();
    let server = fsfl::obs::MetricsServer::bind("127.0.0.1:0", telemetry.clone())
        .expect("binding an ephemeral localhost port");
    let addr = server.addr();

    let mut stream = std::net::TcpStream::connect(addr).expect("connecting to metrics endpoint");
    stream
        .write_all(b"GET /metrics HTTP/1.1\r\nHost: localhost\r\nConnection: close\r\n\r\n")
        .unwrap();
    let mut response = String::new();
    stream.read_to_string(&mut response).unwrap();

    assert!(
        response.starts_with("HTTP/1.1 200 OK"),
        "unexpected status: {}",
        response.lines().next().unwrap_or("")
    );
    assert!(response.contains("text/plain"), "missing content type");
    assert!(
        response.contains(&format!("fsfl_rounds_total {}", log.rounds.len())),
        "scrape must carry the run's round counter: {response}"
    );
    assert!(
        response.contains(&format!("fsfl_up_bytes_total {}", log.total_bytes(true))),
        "scrape must carry the run's upstream bytes: {response}"
    );
    server.shutdown();
}

// ---------------------------------------------------------------------------
// 4 · CLI round trip: --trace-out → trace summarize
// ---------------------------------------------------------------------------

#[test]
fn cli_trace_out_and_summarize_round_trip() {
    let exe = env!("CARGO_BIN_EXE_fsfl");
    let dir = tmp_dir("cli");
    let trace = dir.join("trace.json");
    let status = std::process::Command::new(exe)
        .args(["run", "--synth", "--rounds", "2", "--clients", "3"])
        .args(["--compute-shards", "2"])
        .arg("--trace-out")
        .arg(&trace)
        .arg("--out")
        .arg(&dir)
        .stdout(std::process::Stdio::null())
        .status()
        .expect("spawning fsfl run --trace-out");
    assert!(status.success(), "fsfl run exited with {status}");

    // The written document passes the strict reader via the library…
    let doc = std::fs::read_to_string(&trace).expect("trace file written");
    summarize::summarize_str(&doc).expect("written trace must summarize");

    // …and through the CLI verb.
    let out = std::process::Command::new(exe)
        .args(["trace", "summarize"])
        .arg(&trace)
        .output()
        .expect("spawning fsfl trace summarize");
    assert!(out.status.success(), "trace summarize exited non-zero");
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(
        text.contains("per-stage latency"),
        "summarize output missing latency table: {text}"
    );
    assert!(text.contains("round 0:"), "summarize output: {text}");
    let _ = std::fs::remove_dir_all(&dir);
}
