//! Serial/parallel equivalence of the codec plane and the round scheduler.
//!
//! The round scheduler fans per-client codec work (sparsify → quantize →
//! DeepCABAC encode, server-side decode) out over `exec::WorkerPool`,
//! optionally software-pipelined against compute, optionally sharded
//! over several compute threads. The contract: **none of pool width,
//! schedule mode, shard count, partial participation or transport
//! changes any output** — bitstreams are byte-identical and decoded
//! updates bit-for-bit equal vs the staged serial path, with buffers
//! recycled across rounds. The codec-plane and scheduler tests drive
//! the real `RoundLane`/`scheduler` machinery on synthetic compute and
//! run everywhere (shared helpers live in `tests/common/mod.rs`; the
//! wire-transport conformance suite is `integration_transport.rs`); the
//! full-experiment tests additionally pin `RunLog` equality and are
//! skipped without a PJRT backend + artifacts.

mod common;

use common::*;

use fsfl::data::TaskKind;
use fsfl::exec::WorkerPool;
use fsfl::fl::scheduler::{self, ScheduleMode};
use fsfl::fl::{Experiment, ExperimentConfig, Protocol, TransportKind};
use fsfl::fl::RoundLane;
use fsfl::runtime::Runtime;

#[test]
fn bitstreams_identical_across_pool_widths() {
    let m = manifest();
    for (name, pcfg) in protocols() {
        let mut reference = None;
        for width in pool_widths() {
            let pool = WorkerPool::new(width);
            let mut lanes: Vec<RoundLane> =
                (0..CLIENTS).map(|_| RoundLane::new(m.clone())).collect();
            codec_round(&mut lanes, &pool, &pcfg, &m, 100);
            let fp = fingerprint(&lanes);
            match &reference {
                None => reference = Some(fp),
                Some(r) => assert_eq!(&fp, r, "{name}: width {width} diverged from serial"),
            }
        }
    }
}

#[test]
fn recycled_lanes_match_fresh_lanes_across_rounds() {
    // Buffer reuse must not leak state between rounds: round 2 through
    // recycled lanes must equal round 2 through brand-new lanes.
    let m = manifest();
    let pool = WorkerPool::new(3);
    for (name, pcfg) in protocols() {
        let mut recycled: Vec<RoundLane> =
            (0..CLIENTS).map(|_| RoundLane::new(m.clone())).collect();
        codec_round(&mut recycled, &pool, &pcfg, &m, 100);
        codec_round(&mut recycled, &pool, &pcfg, &m, 200);
        let mut fresh: Vec<RoundLane> =
            (0..CLIENTS).map(|_| RoundLane::new(m.clone())).collect();
        codec_round(&mut fresh, &pool, &pcfg, &m, 200);
        assert_eq!(
            fingerprint(&recycled),
            fingerprint(&fresh),
            "{name}: recycled buffers leaked state into round 2"
        );
    }
}

#[test]
fn wire_decode_reconstructs_client_view_exactly() {
    // The server-side decode of the actual bitstreams (W + S) must equal
    // the client's dequantized view bit for bit — the release-build
    // guarantee behind the debug-only checksum assert.
    let m = manifest();
    let pool = WorkerPool::serial();
    for (name, pcfg) in protocols() {
        let mut lanes: Vec<RoundLane> =
            (0..CLIENTS).map(|_| RoundLane::new(m.clone())).collect();
        codec_round(&mut lanes, &pool, &pcfg, &m, 7);
        for lane in &lanes {
            assert_eq!(lane.decoded, lane.update, "{name}: wire decode diverged");
        }
    }
}

#[test]
fn pipelined_schedule_matches_staged_serial_under_partial_participation() {
    // 5 of 8 clients participate per round; three rounds through
    // recycled lanes. Every (mode, width) combination must reproduce the
    // staged/serial reference byte for byte.
    let m = manifest();
    let n = CLIENTS;
    let take = 5;
    for (name, pcfg) in protocols() {
        let mut reference: Option<Vec<_>> = None;
        for mode in [ScheduleMode::Staged, ScheduleMode::Pipelined] {
            for width in pool_widths() {
                let pool = WorkerPool::new(width);
                let mut lanes: Vec<RoundLane> =
                    (0..take).map(|_| RoundLane::new(m.clone())).collect();
                let mut order = Vec::new();
                let mut fps = Vec::new();
                for t in 0..3 {
                    scheduler::select_participants(42, t, n, take, &mut order);
                    assert_eq!(order.len(), take);
                    scheduled_round(mode, &pool, &mut lanes, &order, &pcfg, &m, 1000 + t as u64);
                    fps.push(fingerprint(&lanes));
                }
                match &reference {
                    None => reference = Some(fps),
                    Some(r) => assert_eq!(
                        &fps, r,
                        "{name}: mode {mode:?} width {width} diverged from staged serial"
                    ),
                }
            }
        }
    }
}

#[test]
fn sharded_rounds_match_staged_serial_under_partial_participation() {
    // Clients sharded round-robin over real threads, each shard running
    // the scheduler on its own subset with its own recycled lanes; the
    // ordered fan-in must reproduce the single-shard staged serial round
    // byte for byte — including with pipelining inside the shards.
    let m = manifest();
    let n = CLIENTS;
    let take = 6;
    let seed = 7u64;
    let rounds = 2usize;
    for (name, pcfg) in protocols() {
        // Reference: staged serial, single shard.
        let mut reference = Vec::new();
        {
            let mut lanes: Vec<RoundLane> = (0..take).map(|_| RoundLane::new(m.clone())).collect();
            let mut order = Vec::new();
            for t in 0..rounds {
                scheduler::select_participants(seed, t, n, take, &mut order);
                scheduled_round(
                    ScheduleMode::Staged,
                    &WorkerPool::serial(),
                    &mut lanes,
                    &order,
                    &pcfg,
                    &m,
                    500 + t as u64,
                );
                reference.push(fingerprint(&lanes));
            }
        }

        for shards in [2usize, 3] {
            for mode in [ScheduleMode::Staged, ScheduleMode::Pipelined] {
                // Per-shard free-lane pools persist across rounds, like
                // the sharded coordinator's.
                let mut shard_free: Vec<Vec<RoundLane>> =
                    (0..shards).map(|_| Vec::new()).collect();
                let mut order = Vec::new();
                for t in 0..rounds {
                    scheduler::select_participants(seed, t, n, take, &mut order);
                    let mut per_shard: Vec<Vec<(usize, usize)>> = vec![Vec::new(); shards];
                    for (slot, &ci) in order.iter().enumerate() {
                        per_shard[scheduler::shard_of(ci, shards)].push((slot, ci));
                    }
                    let (tx, rx) = std::sync::mpsc::channel::<Vec<(usize, RoundLane)>>();
                    std::thread::scope(|s| {
                        for (shard, slots) in per_shard.into_iter().enumerate() {
                            let tx = tx.clone();
                            let pcfg = &pcfg;
                            let m2 = m.clone();
                            let mut free = std::mem::take(&mut shard_free[shard]);
                            let round_seed = 500 + t as u64;
                            s.spawn(move || {
                                let order: Vec<usize> =
                                    slots.iter().map(|&(_, ci)| ci).collect();
                                while free.len() < order.len() {
                                    free.push(RoundLane::new(m2.clone()));
                                }
                                free.truncate(order.len());
                                let mut lanes = free;
                                scheduled_round(
                                    mode,
                                    &WorkerPool::new(2),
                                    &mut lanes,
                                    &order,
                                    pcfg,
                                    &m2,
                                    round_seed,
                                );
                                let tagged: Vec<(usize, RoundLane)> = slots
                                    .iter()
                                    .map(|&(slot, _)| slot)
                                    .zip(lanes.drain(..))
                                    .collect();
                                tx.send(tagged).unwrap();
                            });
                        }
                    });
                    drop(tx);
                    let mut all: Vec<(usize, RoundLane)> = Vec::new();
                    for part in rx {
                        all.extend(part);
                    }
                    let tagged = scheduler::fan_in(all);
                    let ordered: Vec<RoundLane> =
                        tagged.into_iter().map(|(_, lane)| lane).collect();
                    assert_eq!(
                        fingerprint(&ordered),
                        reference[t],
                        "{name}: shards {shards} mode {mode:?} round {t} diverged"
                    );
                    // Recycle lanes back to their owning shard.
                    for lane in ordered {
                        shard_free[scheduler::shard_of(lane.client, shards)].push(lane);
                    }
                }
            }
        }
    }
}

#[test]
fn full_experiment_runlog_identical_across_pool_widths() {
    let artifacts: std::path::PathBuf = std::env::var("FSFL_ARTIFACTS")
        .map(Into::into)
        .unwrap_or_else(|_| std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts"));
    if !artifacts.join("tiny_cnn").join("manifest.tsv").exists() {
        eprintln!("skipping: no artifacts (run `make artifacts`)");
        return;
    }
    let rt = match Runtime::cpu() {
        Ok(rt) => rt,
        Err(e) => {
            eprintln!("skipping: {e}");
            return;
        }
    };
    let mut reference: Option<Vec<(usize, usize, f64, f64, Vec<f64>)>> = None;
    for width in pool_widths() {
        let mut cfg = ExperimentConfig::quick("tiny_cnn", TaskKind::CifarLike, Protocol::Fsfl);
        cfg.artifacts_root = artifacts.clone();
        cfg.rounds = 3;
        cfg.clients = 4;
        cfg.train_per_client = 48;
        cfg.val_per_client = 16;
        cfg.test_samples = 32;
        cfg.seed = 11;
        cfg.codec_workers = width;
        let mut exp = Experiment::build(&rt, cfg).unwrap();
        let log = exp.run().unwrap();
        assert!(exp.replicas_in_sync(), "width {width}: replicas diverged");
        let fp: Vec<(usize, usize, f64, f64, Vec<f64>)> = log
            .rounds
            .iter()
            .map(|r| {
                (
                    r.up_bytes,
                    r.down_bytes,
                    r.accuracy,
                    r.update_sparsity,
                    r.client_sparsity.clone(),
                )
            })
            .collect();
        match &reference {
            None => reference = Some(fp),
            Some(r) => assert_eq!(&fp, r, "width {width}: RunLog diverged from serial"),
        }
    }
}

#[test]
fn full_experiment_runlog_identical_across_schedules_shards_and_transports() {
    // The end-to-end determinism invariant: pipelined scheduling,
    // sharded deployment and wire transports must reproduce the staged
    // single-thread RunLog exactly. Needs a PJRT backend + artifacts
    // (skips otherwise); the PJRT-free conformance grid lives in
    // `integration_transport.rs`.
    let artifacts: std::path::PathBuf = std::env::var("FSFL_ARTIFACTS")
        .map(Into::into)
        .unwrap_or_else(|_| std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts"));
    if !artifacts.join("tiny_cnn").join("manifest.tsv").exists() {
        eprintln!("skipping: no artifacts (run `make artifacts`)");
        return;
    }
    if let Err(e) = Runtime::cpu() {
        eprintln!("skipping: {e}");
        return;
    }
    let base_cfg = || {
        let mut cfg = ExperimentConfig::quick("tiny_cnn", TaskKind::CifarLike, Protocol::Fsfl);
        cfg.artifacts_root = artifacts.clone();
        cfg.rounds = 3;
        cfg.clients = 5;
        cfg.participation = 0.6; // 3 of 5 participate per round
        cfg.train_per_client = 48;
        cfg.val_per_client = 16;
        cfg.test_samples = 32;
        cfg.seed = 23;
        cfg
    };
    let fp_of = |log: &fsfl::metrics::RunLog| -> Vec<(usize, usize, f64, f64, Vec<f64>)> {
        log.rounds
            .iter()
            .map(|r| {
                (
                    r.up_bytes,
                    r.down_bytes,
                    r.accuracy,
                    r.update_sparsity,
                    r.client_sparsity.clone(),
                )
            })
            .collect()
    };

    let grid = [
        (false, 1, TransportKind::Mpsc),
        (true, 1, TransportKind::Mpsc),
        (false, 2, TransportKind::Mpsc),
        (true, 3, TransportKind::Mpsc),
        (false, 2, TransportKind::Loopback),
        (true, 2, TransportKind::Tcp),
    ];
    let mut reference: Option<Vec<(usize, usize, f64, f64, Vec<f64>)>> = None;
    for (pipelined, shards, transport) in grid {
        let mut cfg = base_cfg();
        cfg.pipelined = pipelined;
        cfg.compute_shards = shards;
        cfg.transport = transport;
        let log = fsfl::coordinator::run_experiment_threaded(cfg, |_| {}).unwrap();
        let fp = fp_of(&log);
        match &reference {
            None => reference = Some(fp),
            Some(r) => assert_eq!(
                &fp, r,
                "pipelined={pipelined} shards={shards} transport={}: RunLog diverged from staged single-thread",
                transport.name()
            ),
        }
    }
}
