//! Property-style integration tests of the compression substrate
//! (randomized over fsfl's deterministic RNG — the offline registry has
//! no proptest): arbitrary updates must round-trip exactly through
//! sparsify → quantize → DeepCABAC encode → decode, and compressed sizes
//! must track sparsity.

use std::sync::Arc;

use fsfl::compression::cabac::{decode_update, encode_update};
use fsfl::compression::{QuantConfig, SparsifyMode, UpdateCodec};
use fsfl::data::XorShiftRng;
use fsfl::model::params::Delta;
use fsfl::model::{Group, Kind, Manifest, TensorSpec};

fn manifest(rows: usize, row_len: usize, side: usize) -> Arc<Manifest> {
    let tensors = vec![
        TensorSpec {
            name: "w".into(),
            shape: vec![rows, row_len],
            kind: Kind::ConvW,
            group: Group::Weight,
            layer: "l".into(),
            out_ch: Some(rows),
            scale_for: None,
        },
        TensorSpec {
            name: "s".into(),
            shape: vec![side],
            kind: Kind::Scale,
            group: Group::Scale,
            layer: "l".into(),
            out_ch: Some(side),
            scale_for: None,
        },
    ];
    Arc::new(Manifest {
        model: "pt".into(),
        variant: "pt".into(),
        classes: 2,
        input: vec![2, 2, 1],
        batch: 1,
        param_count: rows * row_len + side,
        scale_count: side,
        tensors,
    })
}

/// Random sparse-ish update: zeros, large values and sub-step noise mixed.
fn random_delta(rng: &mut XorShiftRng) -> Delta {
    let rows = 1 + rng.below(12);
    let row_len = 1 + rng.below(20);
    let side = 1 + rng.below(8);
    let m = manifest(rows, row_len, side);
    let mut d = Delta::zeros(m);
    d.tensors[0] = (0..rows * row_len)
        .map(|_| match rng.below(6) {
            0 | 1 | 2 => 0.0,
            3 | 4 => (rng.next_f32() - 0.5) * 2.0,
            _ => (rng.next_f32() - 0.5) * 2e-5,
        })
        .collect();
    d.tensors[1] = (0..side).map(|_| (rng.next_f32() - 0.5) * 0.02).collect();
    d
}

/// decode(encode(Δ)) == the dequantized Δ̂ returned by encode, always.
#[test]
fn codec_roundtrip_exact() {
    let mut rng = XorShiftRng::new(1);
    for _ in 0..128 {
        let d = random_delta(&mut rng);
        let m = d.manifest.clone();
        let q = QuantConfig::default();
        let (bytes, deq, _stats) = encode_update(&d, &[0, 1], &|spec| q.step_for(spec));
        let back = decode_update(&bytes, &m).unwrap();
        assert_eq!(back, deq);
    }
}

/// Quantization error of the reconstruction is bounded by step/2.
#[test]
fn codec_error_bounded() {
    let mut rng = XorShiftRng::new(2);
    for _ in 0..128 {
        let d = random_delta(&mut rng);
        let q = QuantConfig::default();
        let (_bytes, deq, _) = encode_update(&d, &[0, 1], &|spec| q.step_for(spec));
        for (x, y) in d.tensors[0].iter().zip(&deq.tensors[0]) {
            assert!((x - y).abs() <= q.coarse_step / 2.0 + 1e-6, "{x} vs {y}");
        }
        for (x, y) in d.tensors[1].iter().zip(&deq.tensors[1]) {
            assert!((x - y).abs() <= q.fine_step / 2.0 + 1e-9, "{x} vs {y}");
        }
    }
}

/// Sparser updates never encode to more bytes.
#[test]
fn sparser_is_smaller() {
    for seed in 0..64u64 {
        let m = manifest(16, 32, 4);
        let mut rng = XorShiftRng::new(seed);
        let mut dense = Delta::zeros(m.clone());
        dense.tensors[0] = (0..16 * 32).map(|_| rng.normal() * 0.01).collect();
        let q = QuantConfig::default();
        let step = |spec: &TensorSpec| q.step_for(spec);
        let (b_dense, _, _) = encode_update(&dense, &[0], &step);
        let mut sparse = dense.clone();
        fsfl::compression::sparsify::apply_topk(&mut sparse.tensors[0], 0.9);
        let (b_sparse, _, _) = encode_update(&sparse, &[0], &step);
        assert!(
            b_sparse.len() <= b_dense.len(),
            "seed {seed}: {} > {}",
            b_sparse.len(),
            b_dense.len()
        );
    }
}

/// STC codec: levels are ternary, roundtrip holds.
#[test]
fn stc_roundtrip_and_ternary() {
    for seed in 0..64u64 {
        let m = manifest(8, 16, 2);
        let mut rng = XorShiftRng::new(seed ^ 0xABCD);
        let mut d = Delta::zeros(m.clone());
        d.tensors[0] = (0..8 * 16).map(|_| rng.normal() * 0.02).collect();
        d.tensors[1] = vec![1e-5, -2e-5];
        let codec = UpdateCodec::stc(0.75);
        let (bytes, deq, _) = codec.encode(d, &[0, 1]);
        let back = codec.decode(&bytes, &m).unwrap();
        assert_eq!(back, deq);
        let mags: Vec<f32> = deq.tensors[0]
            .iter()
            .filter(|&&x| x != 0.0)
            .map(|x| x.abs())
            .collect();
        if let Some(&m0) = mags.first() {
            for &v in &mags {
                assert!((v - m0).abs() < 1e-6, "non-ternary magnitudes");
            }
        }
        // ~25% survivors
        let nz = mags.len() as f64 / (8.0 * 16.0);
        assert!((nz - 0.25).abs() < 0.05, "nz={nz}");
    }
}

/// Dynamic sparsification (Eqs. 2+3) then codec roundtrip.
#[test]
fn dynamic_pipeline_roundtrip() {
    for seed in 0..64u64 {
        let m = manifest(12, 24, 3);
        let mut rng = XorShiftRng::new(seed ^ 0x77);
        let mut d = Delta::zeros(m.clone());
        d.tensors[0] = (0..12 * 24).map(|_| rng.normal() * 0.005).collect();
        d.tensors[1] = vec![0.001, -0.002, 0.0005];
        let codec = UpdateCodec {
            sparsify: SparsifyMode::Dynamic {
                delta: 1.0,
                gamma: 1.0,
            },
            quant: QuantConfig::default(),
            ternary: false,
        };
        let (bytes, deq, stats) = codec.encode(d, &[0, 1]);
        let back = codec.decode(&bytes, &m).unwrap();
        assert_eq!(back, deq);
        assert!(stats.sparsity() > 0.0);
    }
}

/// Entire-row structured sparsity pays ~one bit per skipped row: an update
/// with 90% zero rows must code dramatically smaller than element-wise
/// zeros of the same count spread randomly.
#[test]
fn row_skip_exploits_structure() {
    let m = manifest(100, 64, 1);
    let mut rng = XorShiftRng::new(9);
    // structured: 10 dense rows, 90 all-zero rows
    let mut structured = Delta::zeros(m.clone());
    for r in 0..10 {
        for c in 0..64 {
            structured.tensors[0][r * 64 + c] = rng.normal() * 0.01;
        }
    }
    // unstructured: same number of nonzeros scattered
    let mut scattered = Delta::zeros(m.clone());
    let mut placed = 0;
    while placed < 640 {
        let i = rng.below(100 * 64);
        if scattered.tensors[0][i] == 0.0 {
            scattered.tensors[0][i] = rng.normal() * 0.01;
            placed += 1;
        }
    }
    let q = QuantConfig::default();
    let step = |spec: &TensorSpec| q.step_for(spec);
    let (b_struct, _, s_struct) = encode_update(&structured, &[0], &step);
    let (b_scatter, _, _) = encode_update(&scattered, &[0], &step);
    assert_eq!(s_struct.rows_skipped, 90);
    assert!(
        (b_struct.len() as f64) < 0.8 * b_scatter.len() as f64,
        "structured {} vs scattered {}",
        b_struct.len(),
        b_scatter.len()
    );
}

/// Frozen-context (no adaptation) streams roundtrip too, and adaptive
/// contexts always code sparse updates tighter.
#[test]
fn context_adaptation_roundtrip_and_wins() {
    use fsfl::compression::cabac::encode_update_opts;
    let m = manifest(64, 64, 1);
    let q = QuantConfig::default();
    let step = |spec: &TensorSpec| q.step_for(spec);
    for seed in 0..16u64 {
        let mut rng = XorShiftRng::new(seed ^ 0x51);
        let mut d = Delta::zeros(m.clone());
        for x in d.tensors[0].iter_mut() {
            if rng.below(20) == 0 {
                *x = rng.normal() * 0.01;
            }
        }
        let (b_ad, deq_ad, _) = encode_update_opts(&d, &[0], &step, true);
        let (b_fz, deq_fz, _) = encode_update_opts(&d, &[0], &step, false);
        assert_eq!(decode_update(&b_ad, &m).unwrap(), deq_ad);
        assert_eq!(decode_update(&b_fz, &m).unwrap(), deq_fz);
        assert_eq!(deq_ad, deq_fz, "flag must not change reconstruction");
        assert!(
            b_ad.len() < b_fz.len(),
            "seed {seed}: adaptive {} >= frozen {}",
            b_ad.len(),
            b_fz.len()
        );
    }
}

/// Residual + codec: over rounds, accumulated residual drains into
/// transmitted updates (no signal permanently lost).
#[test]
fn residual_conservation_over_rounds() {
    let m = manifest(4, 8, 1);
    let mut residual = fsfl::compression::Residual::zeros(m.clone());
    let codec = UpdateCodec::fixed_rate(0.75);
    let mut rng = XorShiftRng::new(33);
    let mut total_raw = Delta::zeros(m.clone());
    let mut total_sent = Delta::zeros(m.clone());
    for _ in 0..50 {
        let mut raw = Delta::zeros(m.clone());
        raw.tensors[0] = (0..32).map(|_| rng.normal() * 0.01).collect();
        total_raw.accumulate(&raw);
        residual.inject(&mut raw);
        let (_bytes, sent, _) = codec.encode(raw.clone(), &[0]);
        residual.update(&raw, &sent);
        total_sent.accumulate(&sent);
    }
    // Conservation: total_raw - total_sent == final residual exactly (up
    // to f32 summation noise) — nothing was lost, only deferred.
    let mut outstanding = total_raw.clone();
    outstanding.accumulate_scaled(&total_sent, -1.0);
    let diff = (outstanding.l2_norm() - residual.l2_norm()).abs();
    assert!(
        diff < 1e-4,
        "outstanding {} vs residual {}",
        outstanding.l2_norm(),
        residual.l2_norm()
    );
}
