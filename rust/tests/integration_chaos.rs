//! Chaos conformance suite for the self-healing round supervisor.
//!
//! Every test injects a scripted [`ChaosDeath`] into a synthetic-plane
//! sharded run and pins the recovery invariants:
//!
//! 1. **Respawn replay** — a shard killed mid-round (or mid-checkpoint
//!    collect) is respawned, rehydrated from the recovery cache, and the
//!    in-flight round replayed; the final per-round metrics are
//!    byte-identical to the undisturbed run, on every transport.
//! 2. **Quorum degradation** — with `on_loss = degrade`, the dead
//!    shard's clients fold deterministically into the survivors
//!    (`survivors[c % survivors.len()]`), eval migrates to the first
//!    survivor, and the run still matches the undisturbed metrics.
//! 3. **Deadline detection** — a silent straggler (stalls but keeps its
//!    connection open) is detected purely by the scripted round
//!    deadline, then recovered like a crash.
//! 4. **No wall-clock sleeps** — all legs run on a [`ScriptedClock`];
//!    recovery backoff sleeps land in the scripted log, never in real
//!    time, so the whole suite stays fast and deterministic.
//!
//! The incident history rides in `RunLog.events` (excluded from the
//! metrics CSV), so byte-identity of `rounds` and the recorded
//! Death → Respawned/Degraded sequence are asserted independently.

mod common;

use std::path::PathBuf;
use std::sync::Arc;
use std::time::Duration;

use common::*;

use fsfl::coordinator::{self, ChaosDeath, ChaosPoint, ElasticPlan};
use fsfl::data::TaskKind;
use fsfl::fl::{
    ExperimentConfig, OnShardLoss, Protocol, RoundPolicy, SessionConfig, TransportKind,
};
use fsfl::metrics::{RunLog, ShardEventKind};
use fsfl::session::SessionStore;
use fsfl::supervise::ScriptedClock;

/// A unique temp dir per test leg (removed on success; best effort).
/// CI points `FSFL_SESSION_TMP` at a known root so checkpoint dirs of
/// *failed* legs survive for the artifact upload.
fn tmp_dir(tag: &str) -> PathBuf {
    let root = std::env::var_os("FSFL_SESSION_TMP")
        .map(PathBuf::from)
        .unwrap_or_else(std::env::temp_dir);
    let _ = std::fs::create_dir_all(&root);
    let d = root.join(format!("fsfl_chaos_{}_{tag}", std::process::id()));
    let _ = std::fs::remove_dir_all(&d);
    std::fs::create_dir_all(&d).unwrap();
    d
}

fn ccfg(transport: TransportKind, shards: usize) -> ExperimentConfig {
    let mut cfg = ExperimentConfig::quick("synth", TaskKind::CifarLike, Protocol::Fsfl);
    cfg.clients = 5;
    cfg.rounds = 6;
    cfg.participation = 0.6; // 3 of 5 participate per round
    cfg.seed = 77;
    cfg.compute_shards = shards;
    cfg.transport = transport;
    cfg
}

/// Supervision policy for the crash legs: loss handling only. Detection
/// is via the torn connection itself (ConnDown), so no scripted time
/// has to pass — the run is deterministic with leases and deadlines off.
fn policy(on_loss: OnShardLoss) -> RoundPolicy {
    RoundPolicy {
        backoff: Duration::from_millis(10),
        join_timeout: Duration::from_secs(30),
        on_loss,
        ..RoundPolicy::default()
    }
}

const TRANSPORTS: [TransportKind; 3] = [
    TransportKind::Mpsc,
    TransportKind::Loopback,
    TransportKind::Tcp,
];

fn undisturbed(transport: TransportKind) -> RunLog {
    let reference =
        coordinator::run_experiment_synthetic(ccfg(transport, 2), manifest(), |_| {}).unwrap();
    assert_eq!(reference.rounds.len(), 6);
    assert!(reference.events.is_empty());
    reference
}

/// Run `cfg` under a scripted clock with one injected death; returns
/// the finished log and the scripted clock for sleep-log assertions.
fn chaotic(cfg: ExperimentConfig, death: ChaosDeath) -> (RunLog, Arc<ScriptedClock>) {
    let clock = Arc::new(ScriptedClock::new(Duration::from_millis(5)));
    let log = coordinator::run_experiment_synthetic_supervised(
        cfg,
        manifest(),
        ElasticPlan::default(),
        None,
        Some(clock.clone()),
        vec![death],
        |_| {},
    )
    .unwrap();
    (log, clock)
}

// ---------------------------------------------------------------------------
// 1 · kill mid-round → respawn replay, every transport
// ---------------------------------------------------------------------------

#[test]
fn mid_round_kill_respawns_byte_identical_across_transports() {
    for transport in TRANSPORTS {
        let reference = undisturbed(transport);
        let mut cfg = ccfg(transport, 2);
        cfg.policy = policy(OnShardLoss::Respawn);
        let death = ChaosDeath {
            shard: 1,
            round: 2,
            point: ChaosPoint::MidRound,
        };
        let (log, clock) = chaotic(cfg, death);
        let tag = transport.name();
        assert_eq!(
            log.rounds, reference.rounds,
            "{tag}: recovered run diverged from the undisturbed run"
        );
        assert_eq!(log.events.len(), 2, "{tag}: events {:?}", log.events);
        assert_eq!((log.events[0].round, log.events[0].shard), (2, 1), "{tag}");
        assert!(
            matches!(log.events[0].kind, ShardEventKind::Death { .. }),
            "{tag}: {:?}",
            log.events[0]
        );
        assert_eq!(
            log.events[1].kind,
            ShardEventKind::Respawned { attempt: 1 },
            "{tag}"
        );
        assert!(
            !clock.slept().is_empty(),
            "{tag}: respawn backoff must sleep on the scripted clock"
        );
    }
}

// ---------------------------------------------------------------------------
// 2 · kill mid-round → quorum degradation, every transport
// ---------------------------------------------------------------------------

#[test]
fn mid_round_kill_degrades_deterministically_across_transports() {
    for transport in TRANSPORTS {
        let reference = undisturbed(transport);
        // Kill shard 0 — the harder case: its clients {0, 2, 4} must
        // fold into shard 1 and the eval role must migrate with them.
        let mut cfg = ccfg(transport, 2);
        cfg.policy = policy(OnShardLoss::Degrade);
        let death = ChaosDeath {
            shard: 0,
            round: 3,
            point: ChaosPoint::MidRound,
        };
        let (log, _clock) = chaotic(cfg, death);
        let tag = transport.name();
        assert_eq!(
            log.rounds, reference.rounds,
            "{tag}: degraded run diverged from the undisturbed run"
        );
        assert_eq!(log.events.len(), 2, "{tag}: events {:?}", log.events);
        assert!(
            matches!(log.events[0].kind, ShardEventKind::Death { .. }),
            "{tag}: {:?}",
            log.events[0]
        );
        assert_eq!(
            log.events[1].kind,
            ShardEventKind::Degraded {
                clients: vec![0, 2, 4]
            },
            "{tag}: orphan fold-in must be deterministic"
        );
        assert_eq!((log.events[1].round, log.events[1].shard), (3, 0), "{tag}");
    }
}

// ---------------------------------------------------------------------------
// 3 · kill mid-STATE-collect (checkpointing every round)
// ---------------------------------------------------------------------------

#[test]
fn mid_collect_kill_recovers_and_checkpoints_across_transports() {
    for transport in TRANSPORTS {
        for on_loss in [OnShardLoss::Respawn, OnShardLoss::Degrade] {
            let reference = undisturbed(transport);
            let tag = format!("{}_{on_loss:?}", transport.name());
            let dir = tmp_dir(&format!("collect_{tag}"));
            let mut cfg = ccfg(transport, 2);
            cfg.policy = policy(on_loss);
            cfg.session = Some(SessionConfig {
                dir: dir.clone(),
                every: 1,
                retain: SessionConfig::DEFAULT_RETAIN,
                crash_after: None,
            });
            let death = ChaosDeath {
                shard: 1,
                round: 2,
                point: ChaosPoint::MidCollect,
            };
            let (log, _clock) = chaotic(cfg, death);
            assert_eq!(
                log.rounds, reference.rounds,
                "{tag}: recovered run diverged from the undisturbed run"
            );
            assert_eq!((log.events[0].round, log.events[0].shard), (2, 1), "{tag}");
            assert!(
                matches!(log.events[0].kind, ShardEventKind::Death { .. }),
                "{tag}: {:?}",
                log.events[0]
            );
            match on_loss {
                OnShardLoss::Respawn => assert_eq!(
                    log.events[1].kind,
                    ShardEventKind::Respawned { attempt: 1 },
                    "{tag}"
                ),
                _ => assert_eq!(
                    log.events[1].kind,
                    ShardEventKind::Degraded {
                        clients: vec![1, 3]
                    },
                    "{tag}"
                ),
            }
            // The interrupted checkpoint was retried: the session ends
            // with a snapshot covering the full run.
            let store = SessionStore::open(&dir).unwrap();
            let state = store.latest().unwrap().expect("final snapshot written");
            assert_eq!(state.next_round, 6, "{tag}: checkpoint chain truncated");
            let _ = std::fs::remove_dir_all(&dir);
        }
    }
}

// ---------------------------------------------------------------------------
// 4 · silent straggler → scripted deadline detection
// ---------------------------------------------------------------------------

#[test]
fn stalled_shard_is_detected_by_the_scripted_round_deadline() {
    let reference = undisturbed(TransportKind::Mpsc);
    let mut cfg = ccfg(TransportKind::Mpsc, 2);
    cfg.policy = RoundPolicy {
        heartbeat: Duration::from_millis(20),
        round_deadline: Duration::from_millis(50),
        backoff: Duration::from_millis(10),
        join_timeout: Duration::from_secs(30),
        on_loss: OnShardLoss::Respawn,
        ..RoundPolicy::default()
    };
    let death = ChaosDeath {
        shard: 1,
        round: 1,
        point: ChaosPoint::Stall,
    };
    let (log, clock) = chaotic(cfg, death);
    assert_eq!(
        log.rounds, reference.rounds,
        "deadline recovery diverged from the undisturbed run"
    );
    assert_eq!((log.events[0].round, log.events[0].shard), (1, 1));
    match &log.events[0].kind {
        ShardEventKind::Death { reason } => assert!(
            reason.contains("round deadline"),
            "stall must be caught by the deadline, got: {reason}"
        ),
        other => panic!("expected a deadline death, got {other:?}"),
    }
    assert_eq!(log.events[1].kind, ShardEventKind::Respawned { attempt: 1 });
    // The stall itself, its detection, and the respawn backoff all ran
    // on scripted time — the sleep log proves no wall-clock waiting.
    assert!(
        !clock.slept().is_empty(),
        "recovery must sleep on the scripted clock"
    );
}

// ---------------------------------------------------------------------------
// 5 · degrade, then elastic resize: the explicit assignment heals
// ---------------------------------------------------------------------------

#[test]
fn degrade_then_resize_heals_and_stays_byte_identical() {
    // Regression: after quorum degradation installs an explicit
    // `sup.assign` and marks the dead slot, a later scripted resize
    // must (a) not fan the state collect into the dead slot's closed
    // channel and (b) re-admit dead slots even when the target equals
    // the current count (the old same-size guard skipped the heal
    // entirely). Both a same-size heal and a grow are exercised; both
    // must land on the undisturbed metrics.
    let reference = undisturbed(TransportKind::Mpsc);
    for target in [2usize, 3] {
        let mut cfg = ccfg(TransportKind::Mpsc, 2);
        cfg.policy = policy(OnShardLoss::Degrade);
        let death = ChaosDeath {
            shard: 1,
            round: 1,
            point: ChaosPoint::MidRound,
        };
        let clock = Arc::new(ScriptedClock::new(Duration::from_millis(5)));
        let log = coordinator::run_experiment_synthetic_supervised(
            cfg,
            manifest(),
            ElasticPlan {
                replace: Vec::new(),
                resize: vec![(3, target)],
            },
            None,
            Some(clock),
            vec![death],
            |_| {},
        )
        .unwrap_or_else(|e| panic!("2->{target} resize after degrade failed: {e:#}"));
        assert_eq!(
            log.rounds, reference.rounds,
            "2->{target}: degrade-then-resize diverged from the undisturbed run"
        );
        assert_eq!(log.events.len(), 2, "2->{target}: events {:?}", log.events);
        assert!(
            matches!(log.events[0].kind, ShardEventKind::Death { .. }),
            "2->{target}: {:?}",
            log.events[0]
        );
        assert_eq!(
            log.events[1].kind,
            ShardEventKind::Degraded {
                clients: vec![1, 3]
            },
            "2->{target}: orphan fold-in must precede the healing resize"
        );
    }
}

// ---------------------------------------------------------------------------
// 6 · chained incidents: a degraded run keeps its snapshot/resume story
// ---------------------------------------------------------------------------

#[test]
fn degraded_run_remains_resumable() {
    let reference = undisturbed(TransportKind::Loopback);
    // Degrade at round 1, then kill the run at round 3 and resume: the
    // resumed (fresh, full-quorum) run must still land on the reference
    // metrics — degradation never leaks into the persisted state.
    let dir = tmp_dir("degrade_resume");
    let mut cfg = ccfg(TransportKind::Loopback, 2);
    cfg.policy = policy(OnShardLoss::Degrade);
    cfg.session = Some(SessionConfig {
        dir: dir.clone(),
        every: 1,
        retain: SessionConfig::DEFAULT_RETAIN,
        crash_after: Some(3),
    });
    let death = ChaosDeath {
        shard: 1,
        round: 1,
        point: ChaosPoint::MidRound,
    };
    let clock = Arc::new(ScriptedClock::new(Duration::from_millis(5)));
    let err = coordinator::run_experiment_synthetic_supervised(
        cfg,
        manifest(),
        ElasticPlan::default(),
        None,
        Some(clock),
        vec![death],
        |_| {},
    )
    .unwrap_err();
    assert!(
        format!("{err:#}").contains("injected crash"),
        "expected the injected crash, got: {err:#}"
    );
    let store = SessionStore::open(&dir).unwrap();
    let state = store.latest().unwrap().expect("snapshot written");
    assert_eq!(state.next_round, 4, "crash after round 3");
    let resumed = coordinator::run_experiment_synthetic_session(
        state.cfg.clone(),
        manifest(),
        ElasticPlan::default(),
        Some(state),
        |_| {},
    )
    .unwrap();
    assert_eq!(
        resumed.rounds, reference.rounds,
        "resume after a degraded run diverged"
    );
    let _ = std::fs::remove_dir_all(&dir);
}
