//! Hierarchical tree fan-in conformance suite (PJRT-free: everything
//! runs on the deterministic `fl::synth` compute plane).
//!
//! The contract under test (see the tree/aggregation-plane section of
//! `ARCHITECTURE.md`): with `tree_children = K` on a wire transport,
//! every top-level shard slot becomes a mid-tier aggregator that owns
//! `K` leaf shards and reduces their lanes through the same
//! associative, slot-ordered `scheduler::fan_in` the coordinator uses —
//! so **every tree shape produces a `RunLog` with rounds byte-identical
//! to the flat fan-in** (and, by the repo's standing invariant, to the
//! single-thread mpsc run). `RunLog::wire` is *topology-dependent*
//! (only coordinator↔aggregator frames are measured; subtree-internal
//! loopback traffic is private), so these tests compare `log.rounds`,
//! never `log.wire`.
//!
//! 1. **Conformance** — loopback and tcp, `tree_children ∈ {1, 2, 3}`,
//!    all pinned against the flat mpsc reference.
//! 2. **Mpsc ignores the knob** — nothing is serialized on mpsc, so
//!    `tree_children` must be a no-op there.
//! 3. **Static membership only** — supervision, elastic plans and chaos
//!    are rejected up front with a descriptive error.
//! 4. **External aggregators** — `fsfl aggregator --connect … --children
//!    K` processes (and in-process `join_aggregator` threads) join a
//!    `serve_session` listener and pin the same rounds.

mod common;

use std::process::{Command, Stdio};
use std::time::Duration;

use common::*;

use fsfl::coordinator::{self, ComputeSpec, ElasticPlan};
use fsfl::data::TaskKind;
use fsfl::fl::{ExperimentConfig, Protocol, RoundPolicy, TransportKind};

/// The shared experiment shape: 5 clients, 6 rounds, 3 participants per
/// round — small enough for CI, churny enough that a routing bug in the
/// subtree's leaf arithmetic would misassign at least one client.
fn tcfg(transport: TransportKind, shards: usize, children: usize) -> ExperimentConfig {
    let mut cfg = ExperimentConfig::quick("synth", TaskKind::CifarLike, Protocol::Fsfl);
    cfg.clients = 5;
    cfg.rounds = 6;
    cfg.participation = 0.6;
    cfg.seed = 77;
    cfg.compute_shards = shards;
    cfg.transport = transport;
    cfg.tree_children = children;
    cfg
}

/// The flat single-process reference every tree shape must reproduce.
fn flat_reference() -> fsfl::metrics::RunLog {
    let m = manifest();
    coordinator::run_experiment_synthetic(tcfg(TransportKind::Mpsc, 2, 0), m, |_| {}).unwrap()
}

// ---------------------------------------------------------------------------
// 1 · conformance: every tree shape pins the flat rounds
// ---------------------------------------------------------------------------

#[test]
fn tree_fan_in_pins_the_flat_round_log_across_transports() {
    let m = manifest();
    let reference = flat_reference();
    assert_eq!(reference.rounds.len(), 6);
    for transport in [TransportKind::Loopback, TransportKind::Tcp] {
        for children in [1usize, 2, 3] {
            let log = coordinator::run_experiment_synthetic(
                tcfg(transport, 2, children),
                m.clone(),
                |_| {},
            )
            .unwrap_or_else(|e| {
                panic!("{} tree_children={children} failed: {e:#}", transport.name())
            });
            assert_eq!(
                log.rounds,
                reference.rounds,
                "{} tree_children={children}: tree fan-in changed the RunLog rounds",
                transport.name()
            );
            // Wire traffic is measured at the coordinator↔aggregator
            // boundary — present and non-trivial, but topology-shaped,
            // so only its existence is pinned here.
            let wire = log.wire.expect("wire transports measure traffic");
            assert!(
                wire.total() > 0,
                "{} tree_children={children}: no coordinator-level wire traffic measured",
                transport.name()
            );
        }
    }
}

#[test]
fn telemetry_is_passive_on_tree_and_flat_topologies() {
    // Observability-plane requirement: a live telemetry handle must
    // leave the tree fan-in's RunLog (rounds and measured wire bytes)
    // byte-identical, on both the flat and the hierarchical topology.
    use std::sync::Arc;

    use fsfl::obs::Telemetry;
    use fsfl::supervise::MonotonicClock;

    let m = manifest();
    for children in [0usize, 2] {
        let cfg = tcfg(TransportKind::Loopback, 2, children);
        let plain = coordinator::run_experiment_synthetic_session_observed(
            cfg.clone(),
            m.clone(),
            ElasticPlan::default(),
            None,
            None,
            None,
            |_| {},
        )
        .unwrap();
        let telemetry = Telemetry::new(Arc::new(MonotonicClock::new()), true);
        let observed = coordinator::run_experiment_synthetic_session_observed(
            cfg,
            m.clone(),
            ElasticPlan::default(),
            None,
            None,
            Some(telemetry.clone()),
            |_| {},
        )
        .unwrap();
        assert_eq!(
            plain.rounds, observed.rounds,
            "tree_children={children}: telemetry changed the RunLog rounds"
        );
        assert_eq!(
            plain.wire, observed.wire,
            "tree_children={children}: telemetry changed the measured wire bytes"
        );
        assert!(
            !telemetry.drain_spans().is_empty(),
            "tree_children={children}: tracing was on but recorded no spans"
        );
    }
}

#[test]
fn uneven_tree_shapes_pin_the_flat_round_log() {
    // 3 top-level aggregators × 2 leaves = 6 leaf shards over 5 clients:
    // at least one leaf owns no client at all, and round-robin slot sets
    // split unevenly across subtrees. The empty-sub-ROUND contract (every
    // child sees every round for its seed bookkeeping) is what keeps
    // this shape byte-identical.
    let m = manifest();
    let reference = flat_reference();
    let log =
        coordinator::run_experiment_synthetic(tcfg(TransportKind::Loopback, 3, 2), m, |_| {})
            .unwrap();
    assert_eq!(
        log.rounds, reference.rounds,
        "3×2 tree (more leaves than clients) changed the RunLog rounds"
    );
}

// ---------------------------------------------------------------------------
// 2 · mpsc ignores the knob
// ---------------------------------------------------------------------------

#[test]
fn mpsc_transport_ignores_tree_children() {
    let m = manifest();
    let reference = flat_reference();
    let log =
        coordinator::run_experiment_synthetic(tcfg(TransportKind::Mpsc, 2, 3), m, |_| {}).unwrap();
    assert_eq!(
        log.rounds, reference.rounds,
        "tree_children must be a no-op on the mpsc transport"
    );
    assert!(log.wire.is_none(), "mpsc measures no wire traffic");
    assert!(log.events.is_empty(), "static run must log no shard events");
}

// ---------------------------------------------------------------------------
// 3 · static, unsupervised membership only
// ---------------------------------------------------------------------------

#[test]
fn tree_rejects_supervision_and_elastic_membership_up_front() {
    let m = manifest();

    // Supervision (any liveness knob) + tree: rejected before any
    // worker spawns.
    let mut cfg = tcfg(TransportKind::Loopback, 2, 2);
    cfg.policy = RoundPolicy {
        heartbeat: Duration::from_secs(5),
        ..RoundPolicy::default()
    };
    let err = coordinator::run_experiment_synthetic(cfg, m.clone(), |_| {}).unwrap_err();
    assert!(
        format!("{err:#}").contains("requires static, unsupervised membership"),
        "undescriptive supervision rejection: {err:#}"
    );

    // Elastic membership plan + tree: same rejection.
    let plan = ElasticPlan {
        resize: vec![(2, 3)],
        ..Default::default()
    };
    let err = coordinator::run_experiment_synthetic_session(
        tcfg(TransportKind::Loopback, 2, 2),
        m.clone(),
        plan,
        None,
        |_| {},
    )
    .unwrap_err();
    assert!(
        format!("{err:#}").contains("requires static, unsupervised membership"),
        "undescriptive elastic-plan rejection: {err:#}"
    );
}

// ---------------------------------------------------------------------------
// 4 · externally-launched aggregators over a real TCP listener
// ---------------------------------------------------------------------------

#[test]
fn join_aggregator_threads_over_a_tcp_listener_pin_the_flat_rounds() {
    use std::net::TcpListener;

    let m = manifest();
    let reference = flat_reference();
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap().to_string();
    // Two top-level slots, each served by an external aggregator that
    // fans out to 2 leaves (4 leaf shards total). The coordinator's
    // config still says tree_children = 2, but in listener-admission
    // mode the externally-launched worker decides its own role — the
    // flag documents the intended topology.
    let aggs: Vec<_> = (0..2)
        .map(|_| {
            let addr = addr.clone();
            std::thread::spawn(move || coordinator::join_aggregator(&addr, 2))
        })
        .collect();
    let log = coordinator::serve_session(
        tcfg(TransportKind::Tcp, 2, 2),
        &listener,
        ComputeSpec::Synthetic { manifest: m.clone() },
        ElasticPlan::default(),
        None,
        || Ok(()),
        |_| {},
    )
    .unwrap();
    for a in aggs {
        a.join().unwrap().unwrap();
    }
    assert_eq!(
        log.rounds, reference.rounds,
        "externally-joined aggregators changed the RunLog rounds"
    );
}

#[test]
fn fsfl_aggregator_processes_pin_the_flat_rounds() {
    use std::net::TcpListener;

    let exe = env!("CARGO_BIN_EXE_fsfl");
    let m = manifest();
    let reference = flat_reference();
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap().to_string();
    // The real CLI shape: one `fsfl aggregator` OS process per top-level
    // slot, connecting into the coordinator's listener (connect-retry
    // covers the race with admission).
    let children: Vec<_> = (0..2)
        .map(|_| {
            Command::new(exe)
                .args(["aggregator", "--connect", &addr, "--children", "2"])
                .stdout(Stdio::null())
                .stderr(Stdio::piped())
                .spawn()
                .unwrap()
        })
        .collect();
    let log = coordinator::serve_session(
        tcfg(TransportKind::Tcp, 2, 2),
        &listener,
        ComputeSpec::Synthetic { manifest: m.clone() },
        ElasticPlan::default(),
        None,
        || Ok(()),
        |_| {},
    )
    .unwrap();
    for mut c in children {
        let status = c.wait().unwrap();
        assert!(status.success(), "fsfl aggregator process exited non-zero");
    }
    assert_eq!(
        log.rounds, reference.rounds,
        "fsfl aggregator processes changed the RunLog rounds"
    );
}
