//! Wire-transport conformance and fault-injection suite.
//!
//! Four layers, all PJRT-free (the protocol machinery runs on the
//! deterministic `fl::synth` compute plane, so every test here runs in
//! CI on the vendored null XLA backend):
//!
//! 1. **Frame/property tests** — a seeded randomized corpus through the
//!    frame codec; truncations and bit flips must error, never panic,
//!    never yield a corrupt frame.
//! 2. **Wire round-trips** — every `ShardCmd`/`ShardMsg` image through
//!    `net::wire`, including real encoded lanes for every Table-2
//!    protocol; malformed lane frames are rejected with no partial
//!    lanes.
//! 3. **Differential conformance** — the same seeded experiment run
//!    via in-process mpsc, loopback transport and TCP transport ×
//!    {staged, pipelined} × shard counts {1, 2, 3} (plus real OS
//!    processes over TCP) must produce byte-identical `RunLog` metrics;
//!    the synthetic eval is a checksum of every aggregated broadcast,
//!    so metric equality pins bitstream equality.
//! 4. **Fault injection** — a shard connection dropped mid-round (or
//!    corrupted) makes the coordinator fail fast with a descriptive
//!    error: no deadlock, no torn aggregation.

mod common;

use std::net::{SocketAddr, TcpListener};
use std::sync::Arc;
use std::time::{Duration, Instant};

use common::*;

use fsfl::compression::{CodecScratch, UpdateCodec};
use fsfl::coordinator::{self, ComputeSpec};
use fsfl::data::{TaskKind, XorShiftRng};
use fsfl::exec::WorkerPool;
use fsfl::fl::{EvalReport, ExperimentConfig, Protocol, RoundLane, TransportKind};
use fsfl::metrics::{MsgKind, RunLog, ScaleStats, WireStats};
use fsfl::model::{Delta, Manifest, ParamSet};
use fsfl::net::{frame, wire, FrameSink, FrameSource, TcpTransport, Transport};

// ---------------------------------------------------------------------------
// 1 · frame codec property tests
// ---------------------------------------------------------------------------

fn corpus(rng: &mut XorShiftRng, n: usize, max_len: usize) -> Vec<Vec<u8>> {
    (0..n)
        .map(|_| {
            let len = (rng.next_u64() as usize) % (max_len + 1);
            (0..len).map(|_| rng.next_u64() as u8).collect()
        })
        .collect()
}

#[test]
fn frame_codec_round_trips_a_randomized_corpus() {
    let mut rng = XorShiftRng::new(0xF4A3E);
    let payloads = corpus(&mut rng, 200, 4096);
    // All frames through one contiguous stream, like a socket would see.
    let mut stream = Vec::new();
    for p in &payloads {
        frame::write_frame(&mut stream, p).unwrap();
    }
    let mut r = stream.as_slice();
    let mut buf = Vec::new();
    for (i, p) in payloads.iter().enumerate() {
        assert!(
            frame::read_frame(&mut r, &mut buf, frame::MAX_PAYLOAD).unwrap(),
            "frame {i} missing"
        );
        assert_eq!(&buf, p, "frame {i} corrupted");
    }
    assert!(
        !frame::read_frame(&mut r, &mut buf, frame::MAX_PAYLOAD).unwrap(),
        "stream must end with a clean EOF"
    );
}

#[test]
fn frame_codec_never_accepts_truncated_or_flipped_frames() {
    let mut rng = XorShiftRng::new(0xBADF00D);
    for p in corpus(&mut rng, 40, 256) {
        let mut wire_bytes = Vec::new();
        frame::write_frame(&mut wire_bytes, &p).unwrap();
        let mut buf = Vec::new();
        // every truncation point errors (cut 0 is a clean EOF)
        for cut in 1..wire_bytes.len() {
            let mut r = &wire_bytes[..cut];
            assert!(
                frame::read_frame(&mut r, &mut buf, frame::MAX_PAYLOAD).is_err(),
                "truncation at {cut}/{} accepted",
                wire_bytes.len()
            );
        }
        // random single-bit flips error (or, if the flip lands in the
        // length field and enlarges it, error via truncation)
        for _ in 0..32 {
            let byte = (rng.next_u64() as usize) % wire_bytes.len();
            let bit = (rng.next_u64() as usize) % 8;
            let mut bad = wire_bytes.clone();
            bad[byte] ^= 1 << bit;
            let mut r = bad.as_slice();
            match frame::read_frame(&mut r, &mut buf, frame::MAX_PAYLOAD) {
                Err(_) => {}
                Ok(got) => panic!(
                    "flip at byte {byte} bit {bit} accepted (returned {got}) for {}-byte payload",
                    p.len()
                ),
            }
        }
    }
}

#[test]
fn frame_codec_bounds_hostile_length_fields() {
    let mut rng = XorShiftRng::new(0xC0FFEE);
    let mut buf = Vec::new();
    // Adversarial length-field corpus: every frame below has a valid
    // magic but a forged length, read through the peer-facing cap
    // `FrameSource` actually installs (`MAX_FRAME_LEN`). Each must
    // error cleanly — no panic, and never ballooning the read buffer
    // to the claimed length.
    let forged: Vec<u32> = vec![
        u32::MAX,
        u32::MAX - 1,
        frame::MAX_PAYLOAD as u32, // writer-legal, peer-facing-illegal
        (frame::MAX_FRAME_LEN + 1) as u32,
        (frame::MAX_FRAME_LEN as u32) << 1,
        0x8000_0000,
    ];
    for claimed in forged {
        for body in [0usize, 7, 256] {
            let mut wire_bytes = Vec::new();
            frame::write_frame(&mut wire_bytes, &vec![0xAB; body]).unwrap();
            wire_bytes[4..8].copy_from_slice(&claimed.to_le_bytes());
            let mut r = wire_bytes.as_slice();
            let err = frame::read_frame(&mut r, &mut buf, frame::MAX_FRAME_LEN)
                .expect_err("forged length accepted");
            assert!(
                format!("{err}").contains("oversized"),
                "claimed {claimed} with {body}-byte body: unexpected error {err}"
            );
        }
    }
    // In-cap forged lengths over a truncated stream: the reader may
    // only learn the length lied from the payload running dry, and the
    // buffer must grow chunkwise, not by the claimed amount.
    for _ in 0..20 {
        // Claims start at 8 so none can coincide with the real 4-byte
        // payload (which would make the frame legitimately valid).
        let claimed = 8 + (rng.next_u64() as u32) % (frame::MAX_FRAME_LEN as u32 - 8);
        let mut wire_bytes = Vec::new();
        frame::write_frame(&mut wire_bytes, b"tiny").unwrap();
        wire_bytes[4..8].copy_from_slice(&claimed.to_le_bytes());
        let mut r = wire_bytes.as_slice();
        buf = Vec::new();
        let err = frame::read_frame(&mut r, &mut buf, frame::MAX_FRAME_LEN)
            .expect_err("forged in-cap length accepted over a short stream");
        assert!(
            format!("{err}").contains("mid-frame") || format!("{err}").contains("checksum"),
            "claimed {claimed}: unexpected error {err}"
        );
        assert!(
            buf.capacity() <= 8 << 20,
            "claimed {claimed} ballooned the buffer to {} bytes",
            buf.capacity()
        );
    }
}

// ---------------------------------------------------------------------------
// 2 · wire message round-trips over real lanes
// ---------------------------------------------------------------------------

fn zero_params(m: &Arc<Manifest>) -> ParamSet {
    ParamSet::new(
        m.clone(),
        m.tensors.iter().map(|t| vec![0.0; t.numel()]).collect(),
    )
    .unwrap()
}

/// Fingerprint of the transmitted scalars `RoundLane::wire_parts`
/// carries alongside the streams.
fn lane_meta(l: &RoundLane) -> (usize, u128, u128, bool, usize, usize) {
    (
        l.up_bytes,
        l.train_ms,
        l.scale_ms,
        l.scale_accepted,
        l.stats.rows_skipped,
        l.stats.rows_total,
    )
}

#[test]
fn round_done_round_trips_real_lanes_for_every_protocol() {
    let m = manifest();
    let pool = WorkerPool::serial();
    for (name, pcfg) in protocols() {
        let mut lanes: Vec<RoundLane> =
            (0..CLIENTS).map(|_| RoundLane::new(m.clone())).collect();
        codec_round(&mut lanes, &pool, &pcfg, &m, 900);
        let want_fp = fingerprint(&lanes);
        let want_meta: Vec<_> = lanes.iter().map(lane_meta).collect();
        let tagged: Vec<(usize, RoundLane)> =
            lanes.into_iter().enumerate().map(|(i, l)| (i * 3, l)).collect();

        let mut buf = Vec::new();
        wire::encode_round_done(&mut buf, 1, &tagged).unwrap();
        assert_eq!(wire::msg_tag(&buf).unwrap(), wire::MsgTag::RoundDone);

        // decode through a recycled pool (stale lanes must be fully
        // overwritten) and through fresh allocation
        for prime_pool in [false, true] {
            let mut free: Vec<RoundLane> = Vec::new();
            if prime_pool {
                let mut stale: Vec<RoundLane> =
                    (0..CLIENTS).map(|_| RoundLane::new(m.clone())).collect();
                codec_round(&mut stale, &pool, &pcfg, &m, 77); // different round
                free.extend(stale);
            }
            let (shard, got) = wire::decode_round_done_into(&buf, &m, &mut free).unwrap();
            assert_eq!(shard, 1);
            let slots: Vec<usize> = got.iter().map(|(s, _)| *s).collect();
            assert_eq!(slots, (0..CLIENTS).map(|i| i * 3).collect::<Vec<_>>());
            let restored: Vec<RoundLane> = got.into_iter().map(|(_, l)| l).collect();
            // decoded stream bytes, checksums and byte accounting all
            // survive the wire — and update == decoded by restoration
            for ((lane, fp), meta) in restored.iter().zip(&want_fp).zip(&want_meta) {
                assert_eq!(
                    lane.streams().iter().map(|s| s.to_vec()).collect::<Vec<_>>(),
                    fp.0,
                    "{name}: stream bytes diverged (pool primed: {prime_pool})"
                );
                assert_eq!(lane.decoded.checksum(), fp.2, "{name}: decode diverged");
                assert_eq!(lane.update.checksum(), fp.2, "{name}: update != decoded");
                assert_eq!(lane.up_bytes, fp.3, "{name}: up_bytes diverged");
                assert_eq!(&lane_meta(lane), meta, "{name}: lane metadata diverged");
            }
        }
    }
}

#[test]
fn round_done_truncations_and_bad_flags_never_panic_or_yield_partial_lanes() {
    let m = manifest();
    let pool = WorkerPool::serial();
    let (_, pcfg) = protocols().remove(2); // fsfl: W streams + S streams
    let mut lanes: Vec<RoundLane> = (0..2).map(|_| RoundLane::new(m.clone())).collect();
    codec_round(&mut lanes, &pool, &pcfg, &m, 31);
    let tagged: Vec<(usize, RoundLane)> = lanes.into_iter().enumerate().collect();
    let mut buf = Vec::new();
    wire::encode_round_done(&mut buf, 0, &tagged).unwrap();

    // every truncation errors; the recycled pool never shrinks below
    // what the failed decode consumed-and-dropped
    for cut in 1..buf.len() {
        let mut free: Vec<RoundLane> = Vec::new();
        assert!(
            wire::decode_round_done_into(&buf[..cut], &m, &mut free).is_err(),
            "truncated ROUND_DONE at {cut}/{} accepted",
            buf.len()
        );
    }

    // flag corruption: first lane's flags byte sits after
    // tag(1) + shard(8) + count(8) + slot(8) + client(8)
    let flags_off = 1 + 8 + 8 + 8 + 8;
    for bad_flags in [0u8, 0b101, 0b110, 0b1000] {
        let mut bad = buf.clone();
        bad[flags_off] = bad_flags;
        let mut free: Vec<RoundLane> = Vec::new();
        assert!(
            wire::decode_round_done_into(&bad, &m, &mut free).is_err(),
            "invalid lane flags {bad_flags:#05b} accepted"
        );
    }
}

#[test]
fn ready_round_trips_manifest_and_params() {
    let m = manifest();
    let mut init = zero_params(&m);
    let mut rng = XorShiftRng::new(5);
    for t in init.tensors.iter_mut() {
        for x in t.iter_mut() {
            *x = rng.normal();
        }
    }
    let mut buf = Vec::new();
    wire::encode_ready(&mut buf, 2, &init);
    assert_eq!(wire::msg_tag(&buf).unwrap(), wire::MsgTag::Ready);
    let (shard, got) = wire::decode_ready(&buf).unwrap();
    assert_eq!(shard, 2);
    assert_eq!(*got.manifest, *m, "manifest must survive the tsv round-trip");
    assert_eq!(got.tensors, init.tensors, "param bits must survive");
}

#[test]
fn heartbeat_frames_round_trip_and_reject_every_adversarial_mutation() {
    let mut rng = XorShiftRng::new(0xBEA7);
    let mut cmd = Vec::new();
    let mut msg = Vec::new();
    // Nonce corpus: boundary values plus seeded random draws; shard ids
    // span the realistic range.
    let mut nonces = vec![0u64, 1, u64::MAX, u64::MAX - 1, 0x8000_0000_0000_0000];
    nonces.extend((0..32).map(|_| rng.next_u64()));
    for (i, &nonce) in nonces.iter().enumerate() {
        let shard = rng.below(64);

        // command: round-trip, tag dispatch, trailing-byte rejection
        wire::encode_heartbeat_cmd(&mut cmd, nonce);
        assert_eq!(wire::cmd_tag(&cmd).unwrap(), wire::CmdTag::Heartbeat);
        assert_eq!(wire::decode_heartbeat_cmd(&cmd).unwrap(), nonce);
        for cut in 0..cmd.len() {
            assert!(
                wire::decode_heartbeat_cmd(&cmd[..cut]).is_err(),
                "truncated HEARTBEAT cmd at {cut}/{} accepted",
                cmd.len()
            );
        }
        let mut padded = cmd.clone();
        padded.push(0);
        assert!(
            wire::decode_heartbeat_cmd(&padded).is_err(),
            "trailing byte after HEARTBEAT cmd accepted"
        );

        // message: same battery, plus the shard id
        wire::encode_heartbeat_msg(&mut msg, shard, nonce);
        assert_eq!(wire::msg_tag(&msg).unwrap(), wire::MsgTag::Heartbeat);
        assert_eq!(wire::decode_heartbeat_msg(&msg).unwrap(), (shard, nonce));
        for cut in 0..msg.len() {
            assert!(
                wire::decode_heartbeat_msg(&msg[..cut]).is_err(),
                "truncated HEARTBEAT msg at {cut}/{} accepted",
                msg.len()
            );
        }
        let mut padded = msg.clone();
        padded.push(0);
        assert!(
            wire::decode_heartbeat_msg(&padded).is_err(),
            "trailing byte after HEARTBEAT msg accepted"
        );

        // cross-tag confusion: a cmd payload must not decode as a msg
        // and vice versa (0x06 vs 0x16 differ in exactly one bit)
        assert!(
            wire::decode_heartbeat_msg(&cmd).is_err(),
            "HEARTBEAT cmd bytes accepted by the msg decoder"
        );
        assert!(
            wire::decode_heartbeat_cmd(&msg).is_err(),
            "HEARTBEAT msg bytes accepted by the cmd decoder"
        );

        // a flipped nonce byte must surface as a *different* nonce, not
        // be silently canonicalized (leases key on exact echo)
        if i < 8 {
            let byte = 1 + rng.below(8); // inside the nonce field
            let mut flipped = cmd.clone();
            flipped[byte] ^= 0x01;
            let got = wire::decode_heartbeat_cmd(&flipped).unwrap();
            assert_ne!(got, nonce, "nonce corruption went unnoticed");
        }
    }
}

#[test]
fn resize_bearing_state_and_init_frames_round_trip_and_reject_truncation() {
    use fsfl::fl::OptSnapshot;
    use fsfl::net::wire::{StateCmd, StateInstall};

    let m = manifest();
    let mut params = zero_params(&m);
    params.tensors[0][3] = -1.5;
    let client = |id: usize| fsfl::fl::ClientState {
        id,
        rng: 0x1234_5678_9ABC_DEF0 + id as u64,
        sched_global: 11,
        sched_period: 4,
        train_order: vec![2, 0, 1],
        residual: Some(vec![vec![0.5, -0.25], vec![]]),
        wopt: OptSnapshot {
            m: vec![vec![0.1]],
            v: vec![vec![0.2]],
            t: 3.0,
        },
        sopt: OptSnapshot {
            m: vec![],
            v: vec![],
            t: 0.0,
        },
    };

    // The resize install: a worker that joined as shard 1 of 2 is
    // rehydrated under the *resized* 1-of-3 assignment — the
    // previously forward-compat-only `(shard, shards)` fields are now
    // load-bearing, so pin their exact round-trip plus the migrated
    // client set that the new round-robin assignment owns.
    let cmd = StateCmd {
        collect: false,
        install: Some(StateInstall {
            shard: 1,
            shards: 3,
            rounds_done: 2,
            params: params.clone(),
            clients: vec![client(1), client(4)],
        }),
    };
    let mut buf = Vec::new();
    wire::encode_state_cmd(&mut buf, &cmd);
    assert_eq!(wire::cmd_tag(&buf).unwrap(), wire::CmdTag::State);
    let back = wire::decode_state_cmd(&buf, &m).unwrap();
    let inst = back.install.expect("install lost");
    assert_eq!((inst.shard, inst.shards, inst.rounds_done), (1, 3, 2));
    assert_eq!(inst.params, params, "absolute params must survive bit-exact");
    assert_eq!(inst.clients, vec![client(1), client(4)]);

    // every truncation errors, never panics, never yields a partial install
    for cut in 1..buf.len() {
        assert!(
            wire::decode_state_cmd(&buf[..cut], &m).is_err(),
            "truncated resize STATE at {cut}/{} accepted",
            buf.len()
        );
    }

    // a degenerate re-assignment (shard ≥ shards) is rejected outright
    let bad = StateCmd {
        collect: false,
        install: Some(StateInstall {
            shard: 3,
            shards: 3,
            rounds_done: 0,
            params: params.clone(),
            clients: Vec::new(),
        }),
    };
    wire::encode_state_cmd(&mut buf, &bad);
    assert!(
        wire::decode_state_cmd(&buf, &m).is_err(),
        "shard 3 of 3 must be rejected"
    );

    // The late-joiner INIT: a grown slot's handshake carries the
    // post-resize count (shard 2 of 3 while the config still says
    // compute_shards = 2).
    let mut cfg = ExperimentConfig::quick("t", TaskKind::CifarLike, fsfl::fl::Protocol::Fsfl);
    cfg.compute_shards = 2;
    wire::encode_init(&mut buf, 2, 3, &cfg, &ComputeSpec::Synthetic { manifest: m.clone() });
    assert_eq!(wire::cmd_tag(&buf).unwrap(), wire::CmdTag::Init);
    let init = wire::decode_init(&buf).unwrap();
    assert_eq!((init.shard, init.shards), (2, 3));
    assert_eq!(init.cfg.compute_shards, 2, "the config crosses unmodified");
    for cut in 1..buf.len() {
        assert!(
            wire::decode_init(&buf[..cut]).is_err(),
            "truncated late-joiner INIT at {cut}/{} accepted",
            buf.len()
        );
    }
}

#[test]
fn apply_round_trips_dense_and_stream_formats() {
    let m = manifest();
    let mut rng = XorShiftRng::new(0xA11CE);
    let mut broadcast = Delta::zeros(m.clone());
    for t in broadcast.tensors.iter_mut() {
        for x in t.iter_mut() {
            *x = rng.normal();
        }
    }
    let mut scratch = CodecScratch::default();

    // dense format: raw f32 broadcast, bit-exact round-trip
    let mut buf = Vec::new();
    wire::encode_apply(&mut buf, &broadcast, true);
    assert_eq!(wire::cmd_tag(&buf).unwrap(), wire::CmdTag::Apply);
    let mut out = Delta::zeros(m.clone());
    let eval = wire::decode_apply_into(&buf, &mut out, None, &mut scratch).unwrap();
    assert!(eval, "eval flag lost");
    assert_eq!(out, broadcast, "dense APPLY must round-trip bit-exact");
    for cut in 1..buf.len() {
        assert!(
            wire::decode_apply_into(&buf[..cut], &mut out, None, &mut scratch).is_err(),
            "truncated dense APPLY at {cut}/{} accepted",
            buf.len()
        );
    }

    // stream format: the server encodes the broadcast once; every shard
    // decodes the identical bytes through its downstream codec copy
    let codec = UpdateCodec::fsfl(1.0, 1.0);
    let indices: Vec<usize> = (0..m.tensors.len()).collect();
    let mut raw = broadcast.clone();
    let mut deq = Delta::zeros(m.clone());
    let mut stream = Vec::new();
    codec.encode_into(&mut raw, &indices, &mut scratch, &mut deq, &mut stream);
    wire::encode_apply_stream(&mut buf, &stream, false);
    assert_eq!(wire::cmd_tag(&buf).unwrap(), wire::CmdTag::Apply);
    let eval = wire::decode_apply_into(&buf, &mut out, Some(&codec), &mut scratch).unwrap();
    assert!(!eval, "eval flag invented");
    assert_eq!(out, deq, "stream APPLY must decode to the server's dequantized Δ̂");

    // a stream payload without a configured downstream codec is a
    // protocol error, not a panic
    assert!(
        wire::decode_apply_into(&buf, &mut out, None, &mut scratch).is_err(),
        "stream APPLY without a downstream codec accepted"
    );

    // unknown format byte (after tag + eval flag) is rejected
    let mut bad = buf.clone();
    bad[2] = 9;
    assert!(
        wire::decode_apply_into(&bad, &mut out, Some(&codec), &mut scratch).is_err(),
        "unknown APPLY format byte accepted"
    );
}

#[test]
fn stop_eval_and_failed_round_trip_and_reject_truncation() {
    // STOP is a bare tag
    let mut buf = Vec::new();
    wire::encode_stop(&mut buf);
    assert_eq!(wire::cmd_tag(&buf).unwrap(), wire::CmdTag::Stop);
    assert_eq!(buf.len(), 1, "STOP carries no payload");

    // EVAL: central-model report plus per-layer scale statistics
    let report = EvalReport {
        loss: 0.25,
        accuracy: 0.875,
        f1: 0.8125,
    };
    let stats = vec![
        ScaleStats {
            layer: "conv1".into(),
            min: -0.5,
            q25: 0.1,
            median: 0.5,
            q75: 0.9,
            max: 1.5,
            mean: 0.55,
            suppressed: 0.125,
        },
        ScaleStats {
            layer: "fc".into(),
            min: 0.0,
            q25: 0.0,
            median: 0.0,
            q75: 0.0,
            max: 0.0,
            mean: 0.0,
            suppressed: 1.0,
        },
    ];
    wire::encode_eval(&mut buf, &report, &stats);
    assert_eq!(wire::msg_tag(&buf).unwrap(), wire::MsgTag::Eval);
    let (back, back_stats) = wire::decode_eval(&buf).unwrap();
    assert_eq!(
        (back.loss, back.accuracy, back.f1),
        (report.loss, report.accuracy, report.f1),
        "EVAL report diverged"
    );
    assert_eq!(back_stats, stats, "scale stats diverged");
    for cut in 1..buf.len() {
        assert!(
            wire::decode_eval(&buf[..cut]).is_err(),
            "truncated EVAL at {cut}/{} accepted",
            buf.len()
        );
    }

    // FAILED: shard index + error text (non-ASCII must survive)
    let text = "shard 3: µ-law explosion";
    wire::encode_failed(&mut buf, 3, text);
    assert_eq!(wire::msg_tag(&buf).unwrap(), wire::MsgTag::Failed);
    assert_eq!(wire::decode_failed(&buf).unwrap(), (3, text.to_string()));
    for cut in 1..buf.len() {
        assert!(
            wire::decode_failed(&buf[..cut]).is_err(),
            "truncated FAILED at {cut}/{} accepted",
            buf.len()
        );
    }

    // cross-decodes reject: a FAILED payload is not an EVAL and vice versa
    assert!(wire::decode_eval(&buf).is_err(), "FAILED decoded as EVAL");
    wire::encode_eval(&mut buf, &report, &[]);
    assert!(wire::decode_failed(&buf).is_err(), "EVAL decoded as FAILED");
}

#[test]
fn every_msg_kind_is_reachable_from_a_real_encoder() {
    let m = manifest();
    let cfg = ExperimentConfig::quick("kinds", TaskKind::CifarLike, Protocol::Fsfl);
    let empty_lanes: Vec<(usize, RoundLane)> = Vec::new();
    let mut buf = Vec::new();
    let mut payloads: Vec<(&str, Vec<u8>, MsgKind)> = Vec::new();

    wire::encode_init(&mut buf, 0, 1, &cfg, &ComputeSpec::Synthetic { manifest: m.clone() });
    payloads.push(("INIT", buf.clone(), MsgKind::Init));
    wire::encode_round(&mut buf, &[(0, 0)]);
    payloads.push(("ROUND", buf.clone(), MsgKind::Round));
    wire::encode_apply(&mut buf, &Delta::zeros(m.clone()), false);
    payloads.push(("APPLY", buf.clone(), MsgKind::Apply));
    wire::encode_stop(&mut buf);
    payloads.push(("STOP", buf.clone(), MsgKind::Stop));
    wire::encode_state_cmd(
        &mut buf,
        &wire::StateCmd {
            collect: true,
            install: None,
        },
    );
    payloads.push(("STATE", buf.clone(), MsgKind::State));
    wire::encode_state_msg(&mut buf, 0, &[]);
    payloads.push(("STATE_MSG", buf.clone(), MsgKind::State));
    wire::encode_heartbeat_cmd(&mut buf, 7);
    payloads.push(("HEARTBEAT", buf.clone(), MsgKind::Heartbeat));
    wire::encode_heartbeat_msg(&mut buf, 1, 7);
    payloads.push(("HEARTBEAT_MSG", buf.clone(), MsgKind::Heartbeat));
    wire::encode_ready(&mut buf, 0, &zero_params(&m));
    payloads.push(("READY", buf.clone(), MsgKind::Ready));
    wire::encode_round_done(&mut buf, 0, &empty_lanes).unwrap();
    payloads.push(("ROUND_DONE", buf.clone(), MsgKind::RoundDone));
    wire::encode_eval(
        &mut buf,
        &EvalReport {
            loss: 0.0,
            accuracy: 0.0,
            f1: 0.0,
        },
        &[],
    );
    payloads.push(("EVAL", buf.clone(), MsgKind::Eval));
    wire::encode_failed(&mut buf, 0, "x");
    payloads.push(("FAILED", buf.clone(), MsgKind::Failed));
    // forward-compat bucket: unknown tag bytes and empty payloads
    payloads.push(("UNKNOWN_TAG", vec![0xEE], MsgKind::Other));
    payloads.push(("EMPTY", Vec::new(), MsgKind::Other));

    let mut covered = [false; MsgKind::COUNT];
    for (name, payload, want) in &payloads {
        let got = wire::kind_of(payload);
        assert_eq!(got, *want, "{name}: kind_of misclassified");
        covered[got.index()] = true;
    }
    for kind in MsgKind::ALL {
        assert!(
            covered[kind.index()],
            "MsgKind::{kind:?} unreachable from the encoder corpus — \
             add an encoder round-trip for it above"
        );
    }
}

// ---------------------------------------------------------------------------
// 3 · differential conformance
// ---------------------------------------------------------------------------

/// Exact per-round fingerprint: every metric field, floats as bit
/// patterns. The synthetic eval derives accuracy/f1/loss from the FNV
/// checksum of all aggregated broadcasts, so equality here pins the
/// transmitted bitstreams bit-for-bit.
type RoundsFp = Vec<(
    usize,
    usize,
    usize,
    u64,
    u64,
    u64,
    u64,
    Vec<u64>,
    u64,
    usize,
    u128,
    u128,
)>;

fn fp_rounds(log: &RunLog) -> RoundsFp {
    log.rounds
        .iter()
        .map(|r| {
            (
                r.round,
                r.up_bytes,
                r.down_bytes,
                r.accuracy.to_bits(),
                r.f1.to_bits(),
                r.test_loss.to_bits(),
                r.update_sparsity.to_bits(),
                r.client_sparsity.iter().map(|s| s.to_bits()).collect(),
                r.rows_skipped.to_bits(),
                r.scale_accepted,
                r.train_ms,
                r.scale_ms,
            )
        })
        .collect()
}

fn synth_cfg(protocol: Protocol) -> ExperimentConfig {
    let mut cfg = ExperimentConfig::quick("synth", TaskKind::CifarLike, protocol);
    if matches!(protocol, Protocol::Stc | Protocol::StcScaled) {
        cfg.sparsify = fsfl::compression::SparsifyMode::TopK { rate: 0.9 };
    }
    cfg.clients = 5;
    cfg.rounds = 3;
    cfg.participation = 0.6; // 3 of 5 participate per round
    cfg.seed = 23;
    cfg
}

#[test]
fn runlog_identical_across_transports_schedules_and_shard_counts() {
    let m = manifest();
    for protocol in [Protocol::Fsfl, Protocol::Stc, Protocol::FedAvg] {
        // Reference: the single-process staged schedule (1 shard, mpsc).
        let mut reference: Option<RoundsFp> = None;
        for shards in [1usize, 2, 3] {
            for pipelined in [false, true] {
                let mut wire_ref: Option<WireStats> = None;
                for transport in [
                    TransportKind::Mpsc,
                    TransportKind::Loopback,
                    TransportKind::Tcp,
                ] {
                    let mut cfg = synth_cfg(protocol);
                    cfg.compute_shards = shards;
                    cfg.pipelined = pipelined;
                    cfg.transport = transport;
                    let log =
                        coordinator::run_experiment_synthetic(cfg, m.clone(), |_| {}).unwrap();
                    let fp = fp_rounds(&log);
                    assert_eq!(fp.len(), 3, "wrong round count");
                    match &reference {
                        None => reference = Some(fp),
                        Some(r) => assert_eq!(
                            &fp,
                            r,
                            "{:?} shards={shards} pipelined={pipelined} transport={}: \
                             RunLog diverged from staged single-process",
                            protocol,
                            transport.name()
                        ),
                    }
                    if transport.is_wire() {
                        let w = log.wire.expect("wire transports must measure traffic");
                        assert!(
                            w.sent() > 0 && w.received() > 0,
                            "wire bytes must be measured, not estimated"
                        );
                        // Deterministic framing: loopback and TCP move
                        // byte-identical traffic for the same config.
                        match &wire_ref {
                            None => wire_ref = Some(w),
                            Some(r) => assert_eq!(
                                &w, r,
                                "{:?} shards={shards} pipelined={pipelined}: \
                                 loopback vs tcp measured traffic diverged",
                                protocol
                            ),
                        }
                    } else {
                        assert!(log.wire.is_none(), "mpsc moves no wire bytes");
                    }
                }
            }
        }
    }
}

#[test]
fn bidirectional_broadcast_stream_is_conformant_across_transports() {
    // Encode-once APPLY: with `bidirectional` set, wire transports fan
    // out the server's downstream bitstream (encoded once per round)
    // instead of the dense f32 delta; shards decode those exact bytes.
    // The RunLog must stay byte-identical to the in-process mpsc path
    // (which applies the dense dequantized broadcast directly), and
    // loopback/TCP must measure identical frame-layer traffic.
    let m = manifest();
    let mut reference: Option<RoundsFp> = None;
    let mut wire_ref: Option<WireStats> = None;
    for transport in [
        TransportKind::Mpsc,
        TransportKind::Loopback,
        TransportKind::Tcp,
    ] {
        let mut cfg = synth_cfg(Protocol::Fsfl);
        cfg.bidirectional = true;
        cfg.compute_shards = 2;
        cfg.transport = transport;
        let log = coordinator::run_experiment_synthetic(cfg, m.clone(), |_| {}).unwrap();
        let fp = fp_rounds(&log);
        match &reference {
            None => reference = Some(fp),
            Some(r) => assert_eq!(
                &fp,
                r,
                "bidirectional {}: RunLog diverged (stream APPLY != dense broadcast)",
                transport.name()
            ),
        }
        if transport.is_wire() {
            let w = log.wire.expect("wire transports must measure traffic");
            assert!(
                w.sent() > 0 && w.received() > 0,
                "stream APPLY bytes must be measured at the frame layer"
            );
            match &wire_ref {
                None => wire_ref = Some(w),
                Some(r) => assert_eq!(
                    &w, r,
                    "bidirectional: loopback vs tcp measured traffic diverged"
                ),
            }
        }
    }
}

#[test]
fn tcp_shard_processes_match_the_single_process_staged_schedule() {
    // The acceptance pin: `run_experiment_sharded` over TCP with real
    // OS shard-worker processes reproduces the single-process staged
    // RunLog byte for byte.
    let m = manifest();
    let reference = {
        let mut cfg = synth_cfg(Protocol::Fsfl);
        cfg.compute_shards = 1;
        cfg.pipelined = false;
        cfg.transport = TransportKind::Mpsc;
        coordinator::run_experiment_synthetic(cfg, m.clone(), |_| {}).unwrap()
    };
    let exe = std::path::Path::new(env!("CARGO_BIN_EXE_fsfl"));
    for shards in [2usize, 3] {
        let mut cfg = synth_cfg(Protocol::Fsfl);
        cfg.compute_shards = shards;
        cfg.transport = TransportKind::Tcp;
        let log = coordinator::run_experiment_processes(
            cfg,
            ComputeSpec::Synthetic {
                manifest: m.clone(),
            },
            exe,
            |_| {},
        )
        .unwrap();
        assert_eq!(
            fp_rounds(&log),
            fp_rounds(&reference),
            "{shards} OS shard processes diverged from the single-process staged schedule"
        );
        let w = log.wire.expect("process deployment must measure traffic");
        assert!(w.sent() > 0 && w.received() > 0);
    }
}

#[test]
fn telemetry_is_strictly_passive_across_transports() {
    // The observability plane's hard requirement: attaching a live
    // telemetry handle (span tracing on, metrics registry counting)
    // must leave every run output byte-identical — RunLog rounds,
    // measured per-kind wire traffic and the emitted CSV.
    use fsfl::coordinator::ElasticPlan;
    use fsfl::obs::Telemetry;
    use fsfl::supervise::MonotonicClock;

    let m = manifest();
    for transport in [
        TransportKind::Mpsc,
        TransportKind::Loopback,
        TransportKind::Tcp,
    ] {
        let mut cfg = synth_cfg(Protocol::Fsfl);
        cfg.compute_shards = 2;
        cfg.transport = transport;
        let plain = coordinator::run_experiment_synthetic_session_observed(
            cfg.clone(),
            m.clone(),
            ElasticPlan::default(),
            None,
            None,
            None,
            |_| {},
        )
        .unwrap();
        let telemetry = Telemetry::new(Arc::new(MonotonicClock::new()), true);
        let observed = coordinator::run_experiment_synthetic_session_observed(
            cfg,
            m.clone(),
            ElasticPlan::default(),
            None,
            None,
            Some(telemetry.clone()),
            |_| {},
        )
        .unwrap();
        assert_eq!(
            fp_rounds(&plain),
            fp_rounds(&observed),
            "{}: telemetry changed the RunLog rounds",
            transport.name()
        );
        assert_eq!(
            plain.wire,
            observed.wire,
            "{}: telemetry changed the measured per-kind wire bytes",
            transport.name()
        );
        // …and the handle genuinely observed the run while staying
        // passive: the registry counted every round and byte, and the
        // trace sink recorded spans.
        use std::sync::atomic::Ordering;
        assert_eq!(
            telemetry.metrics.rounds_total.load(Ordering::Relaxed) as usize,
            observed.rounds.len(),
            "{}: registry missed rounds",
            transport.name()
        );
        assert_eq!(
            telemetry.metrics.up_bytes_total.load(Ordering::Relaxed) as usize,
            observed.total_bytes(true),
            "{}: registry missed upstream bytes",
            transport.name()
        );
        if let Some(w) = observed.wire {
            assert_eq!(
                telemetry.metrics.wire_snapshot(),
                w,
                "{}: registry wire counters diverged from RunLog::wire",
                transport.name()
            );
        }
        assert!(
            !telemetry.drain_spans().is_empty(),
            "{}: tracing was on but no spans were recorded",
            transport.name()
        );
    }
}

// ---------------------------------------------------------------------------
// 4 · fault injection
// ---------------------------------------------------------------------------

/// Join a thread with a watchdog: a coordinator that deadlocks instead
/// of failing fast is itself a test failure (mirrors the shape of the
/// `exec::WorkerPool` worker-panic test: the failure must propagate,
/// never hang the caller).
fn join_with_timeout<T: Send + 'static>(
    h: std::thread::JoinHandle<T>,
    secs: u64,
    what: &str,
) -> T {
    // fsfl-lint: allow(clock): wall-clock watchdog guarding against a deadlocked coordinator; must not depend on the clock under test
    let deadline = Instant::now() + Duration::from_secs(secs);
    while !h.is_finished() {
        assert!(
            Instant::now() < deadline, // fsfl-lint: allow(clock): same watchdog read as above

            "{what}: no result after {secs}s — coordinator deadlocked"
        );
        std::thread::sleep(Duration::from_millis(25));
    }
    h.join().expect("watchdogged thread panicked")
}

fn open_fake(addr: SocketAddr) -> (FrameSink, FrameSource) {
    let t: Box<dyn Transport> = Box::new(TcpTransport::connect(addr).unwrap());
    t.open().unwrap()
}

/// Drive the fake shard through INIT → READY and return its assigned
/// shard id plus the open halves.
fn fake_handshake(addr: SocketAddr, m: &Arc<Manifest>) -> (usize, FrameSink, FrameSource) {
    let (mut sink, mut source) = open_fake(addr);
    let mut buf = Vec::new();
    assert!(source.recv(&mut buf).unwrap(), "coordinator closed early");
    let init = wire::decode_init(&buf).unwrap();
    let mut out = Vec::new();
    wire::encode_ready(&mut out, init.shard, &zero_params(m));
    sink.send(&out).unwrap();
    (init.shard, sink, source)
}

#[test]
fn shard_dropped_during_startup_fails_fast() {
    let m = manifest();
    let mut cfg = synth_cfg(Protocol::Fsfl);
    cfg.clients = 2;
    cfg.compute_shards = 1;
    cfg.transport = TransportKind::Tcp;
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap();
    let spec = ComputeSpec::Synthetic {
        manifest: m.clone(),
    };
    let coord = std::thread::spawn(move || {
        coordinator::serve(cfg, &listener, spec, || Ok(()), |_| {})
    });
    // Connect, read INIT, then vanish before READY.
    let (_sink, mut source) = open_fake(addr);
    let mut buf = Vec::new();
    assert!(source.recv(&mut buf).unwrap());
    drop(_sink);
    drop(source);
    let err = join_with_timeout(coord, 30, "startup-drop").unwrap_err();
    let msg = format!("{err:#}");
    assert!(
        msg.contains("shard 0") && (msg.contains("closed") || msg.contains("receive failed")),
        "undescriptive startup-failure error: {msg}"
    );
}

#[test]
fn shard_dropped_mid_round_fails_fast_with_descriptive_error() {
    // Two shards; shard A is a *real* worker (`join_shard`), shard B
    // completes the handshake, receives its ROUND command, then drops
    // the connection instead of delivering lanes. The coordinator must
    // surface a descriptive shard failure promptly — not deadlock on
    // the fan-in barrier, not aggregate a torn round.
    let m = manifest();
    let mut cfg = synth_cfg(Protocol::Fsfl);
    cfg.clients = 4;
    cfg.compute_shards = 2;
    cfg.transport = TransportKind::Tcp;
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap();
    let spec = ComputeSpec::Synthetic {
        manifest: m.clone(),
    };
    let coord = std::thread::spawn(move || {
        coordinator::serve(cfg, &listener, spec, || Ok(()), |_| {})
    });

    // Shard A: a fully real worker serving the whole protocol.
    let addr_str = addr.to_string();
    let real = std::thread::spawn(move || coordinator::join_shard(&addr_str));

    // Shard B: handshakes, takes its round assignment, dies.
    let (shard_b, sink_b, mut source_b) = fake_handshake(addr, &m);
    let mut buf = Vec::new();
    assert!(source_b.recv(&mut buf).unwrap(), "expected a ROUND command");
    assert_eq!(wire::cmd_tag(&buf).unwrap(), wire::CmdTag::Round);
    drop(sink_b);
    drop(source_b);

    let err = join_with_timeout(coord, 30, "mid-round-drop").unwrap_err();
    let msg = format!("{err:#}");
    assert!(
        msg.contains(&format!("shard {shard_b}")),
        "error does not name the dead shard: {msg}"
    );
    assert!(
        msg.contains("closed") || msg.contains("receive failed") || msg.contains("disconnected"),
        "error does not describe the disconnect: {msg}"
    );
    // The surviving worker must wind down (Ok after a Stop, or a
    // "coordinator disconnected" error if teardown won the race) —
    // never hang. The watchdog is the assertion.
    let _ = join_with_timeout(real, 30, "surviving worker");
}

#[test]
fn corrupted_frame_from_a_shard_fails_the_run_descriptively() {
    let m = manifest();
    let mut cfg = synth_cfg(Protocol::Fsfl);
    cfg.clients = 2;
    cfg.compute_shards = 1;
    cfg.transport = TransportKind::Tcp;
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap();
    let spec = ComputeSpec::Synthetic {
        manifest: m.clone(),
    };
    let coord = std::thread::spawn(move || {
        coordinator::serve(cfg, &listener, spec, || Ok(()), |_| {})
    });
    // Raw socket: handshake bytes are garbage, not a frame.
    let stream = std::net::TcpStream::connect(addr).unwrap();
    {
        use std::io::Write as _;
        let mut s = &stream;
        s.write_all(b"this is not a frame at all..............").unwrap();
        s.flush().unwrap();
    }
    let err = join_with_timeout(coord, 30, "corrupt-frame").unwrap_err();
    let msg = format!("{err:#}");
    assert!(
        msg.contains("shard 0"),
        "error does not name the shard: {msg}"
    );
    assert!(
        msg.contains("magic") || msg.contains("receive failed"),
        "error does not describe the corruption: {msg}"
    );
    drop(stream);
}
