//! Harness conformance for the `fsfl bench` plane.
//!
//! Four invariants, all exercised against the real binary via
//! `CARGO_BIN_EXE_fsfl` (no mocked children):
//!
//! 1. **Run-line schema** — one 2-round Suite A smoke cell driven
//!    through [`driver::run_scenario`] produces a JSON line that parses
//!    with the dependency-free reader and passes
//!    [`summary::validate_run_line`], with live per-round latencies and
//!    a >1× upstream compression ratio vs the dense-f32 baseline.
//! 2. **Seed reproducibility** — the Suite B scenario list is a pure
//!    function of its seed, and re-running one cell yields an identical
//!    [`summary::reproducible_view`] (timing fields excluded).
//! 3. **Chaos recovery** — the `b-kill` leg SIGKILLs the child after k
//!    observed round lines and `--resume`s it to the full round count.
//! 4. **`fsfl bench` CLI** — the smoke Suite A grid end to end:
//!    `bench_runs.jsonl` (one valid line per cell) plus a
//!    schema-conformant `BENCH_scenarios.json`.
//!
//! Plus the golden-output regression pin: one deterministic
//! scripted-clock degrade cell whose synth-plane CSV and compact event
//! history are frozen in `tests/fixtures/golden_suite_a_cell.txt`
//! (bless with `FSFL_BLESS=1`).

mod common;

use std::path::PathBuf;
use std::sync::Arc;
use std::time::Duration;

use fsfl::bench::driver::{self, BenchCtx};
use fsfl::bench::json;
use fsfl::bench::spec::{self, ChaosLeg, ModelSize, Scenario};
use fsfl::bench::summary;
use fsfl::coordinator::{self, ChaosDeath, ChaosPoint, ElasticPlan};
use fsfl::data::TaskKind;
use fsfl::fl::{ExperimentConfig, OnShardLoss, Protocol, RoundPolicy, TransportKind};
use fsfl::metrics::RunLog;
use fsfl::supervise::ScriptedClock;

/// A unique temp dir per test (removed on success; kept on failure for
/// post-mortems, matching the chaos suite's convention).
fn tmp_dir(tag: &str) -> PathBuf {
    let root = std::env::var_os("FSFL_SESSION_TMP")
        .map(PathBuf::from)
        .unwrap_or_else(std::env::temp_dir);
    let _ = std::fs::create_dir_all(&root);
    let d = root.join(format!("fsfl_bench_{}_{tag}", std::process::id()));
    let _ = std::fs::remove_dir_all(&d);
    std::fs::create_dir_all(&d).unwrap();
    d
}

fn ctx(tag: &str) -> BenchCtx {
    BenchCtx {
        exe: PathBuf::from(env!("CARGO_BIN_EXE_fsfl")),
        scratch: tmp_dir(tag),
        clock: Arc::new(fsfl::supervise::MonotonicClock::new()),
    }
}

// ---------------------------------------------------------------------------
// 1 · one Suite A smoke cell → valid run line
// ---------------------------------------------------------------------------

#[test]
fn suite_a_smoke_cell_yields_a_schema_valid_run_line() {
    let ctx = ctx("cell");
    let s = Scenario::cell(
        TransportKind::Mpsc,
        false,
        2,
        ModelSize::Small,
        4,
        2,
        spec::SUITE_A_SEED,
    );
    let rec = driver::run_scenario(&ctx, &s);
    assert!(rec.ok, "scenario failed: {:?}", rec.error);
    assert_eq!(rec.rounds_done, 2);
    assert_eq!(
        rec.round_ms.len(),
        2,
        "expected one live round line per round: {:?}",
        rec.round_ms
    );
    assert!(rec.round_ms.iter().all(|&ms| ms >= 0.0));
    assert!(rec.up_bytes > 0 && rec.down_bytes > 0);
    assert!(
        rec.compression_x().is_some_and(|x| x > 1.0),
        "sparse upstream must beat the dense-f32 baseline: {:?} vs dense {}",
        rec.up_bytes,
        rec.dense_bytes
    );
    // The line the summary files are built from must self-validate.
    let line = rec.to_json_line();
    let parsed = json::parse(&line).unwrap_or_else(|e| panic!("unparsable run line {line}: {e}"));
    summary::validate_run_line(&parsed).unwrap_or_else(|e| panic!("schema gate: {e}: {line}"));
    let _ = std::fs::remove_dir_all(&ctx.scratch);
}

// ---------------------------------------------------------------------------
// 2 · seed reproducibility
// ---------------------------------------------------------------------------

#[test]
fn same_seed_reruns_are_identical_apart_from_timing() {
    // Scenario derivation is a pure function of the seed…
    assert_eq!(spec::suite_b(7, true), spec::suite_b(7, true));
    assert_eq!(spec::suite_b(7, false), spec::suite_b(7, false));
    assert_ne!(spec::suite_b(7, true), spec::suite_b(9, true));

    // …and an actual rerun of a cell matches field-for-field once the
    // wall-clock fields are projected out.
    let ctx_a = ctx("repro_a");
    let ctx_b = ctx("repro_b");
    let s = Scenario::cell(
        TransportKind::Loopback,
        false,
        1,
        ModelSize::Small,
        4,
        2,
        spec::SUITE_A_SEED,
    );
    let rec_a = driver::run_scenario(&ctx_a, &s);
    let rec_b = driver::run_scenario(&ctx_b, &s);
    assert!(rec_a.ok, "first run failed: {:?}", rec_a.error);
    assert!(rec_b.ok, "second run failed: {:?}", rec_b.error);
    let view_a = summary::reproducible_view(&json::parse(&rec_a.to_json_line()).unwrap());
    let view_b = summary::reproducible_view(&json::parse(&rec_b.to_json_line()).unwrap());
    assert!(!view_a.is_empty());
    assert_eq!(view_a, view_b, "non-timing fields diverged across reruns");
    let _ = std::fs::remove_dir_all(&ctx_a.scratch);
    let _ = std::fs::remove_dir_all(&ctx_b.scratch);
}

// ---------------------------------------------------------------------------
// 3 · SIGKILL + --resume chaos leg
// ---------------------------------------------------------------------------

#[test]
fn kill_resume_leg_recovers_to_the_full_round_count() {
    let s = spec::suite_b(7, true)
        .into_iter()
        .find(|s| matches!(s.chaos, Some(ChaosLeg::KillResume { .. })))
        .expect("smoke Suite B always carries a kill leg");
    let ctx = ctx("kill");
    let rec = driver::run_scenario(&ctx, &s);
    assert!(rec.ok, "kill/resume scenario failed: {:?}", rec.error);
    assert!(rec.resumed, "the driver must have run a --resume phase");
    assert_eq!(rec.rounds_done, s.rounds);
    let parsed = json::parse(&rec.to_json_line()).unwrap();
    summary::validate_run_line(&parsed).unwrap();
    // Chaos runs keep timing AND wire bytes out of the reproducible
    // view (the kill point shifts how much was in flight).
    let view = summary::reproducible_view(&parsed);
    for dropped in ["wall_ms", "round_ms", "wire_sent", "wire_recv"] {
        assert!(
            view.iter().all(|(k, _)| k != dropped),
            "{dropped} must not appear in a chaos run's reproducible view"
        );
    }
    let _ = std::fs::remove_dir_all(&ctx.scratch);
}

// ---------------------------------------------------------------------------
// 4 · `fsfl bench --suite a --smoke` end to end
// ---------------------------------------------------------------------------

#[test]
fn bench_subcommand_smoke_grid_writes_valid_artifacts() {
    let dir = tmp_dir("cli");
    let out = dir.join("bench-out");
    let status = std::process::Command::new(env!("CARGO_BIN_EXE_fsfl"))
        .args(["bench", "--suite", "a", "--smoke", "--out"])
        .arg(&out)
        .status()
        .expect("spawning fsfl bench");
    assert!(status.success(), "fsfl bench exited with {status}");

    let runs = std::fs::read_to_string(out.join("bench_runs.jsonl")).expect("bench_runs.jsonl");
    let mut n = 0usize;
    for line in runs.lines().filter(|l| !l.trim().is_empty()) {
        let v = json::parse(line).unwrap_or_else(|e| panic!("bad run line {line}: {e}"));
        summary::validate_run_line(&v).unwrap_or_else(|e| panic!("schema gate: {e}: {line}"));
        assert_eq!(v.get("ok").and_then(json::Value::as_bool), Some(true));
        n += 1;
    }
    assert_eq!(n, spec::suite_a(true).len(), "one line per smoke cell");

    let text = std::fs::read_to_string(out.join("BENCH_scenarios.json")).expect("summary file");
    let parsed = json::parse(&text).expect("summary is valid JSON");
    summary::validate_summary(&parsed).expect("summary passes the schema gate");
    let _ = std::fs::remove_dir_all(&dir);
}

// ---------------------------------------------------------------------------
// Golden-output regression pin
// ---------------------------------------------------------------------------

/// The pinned deterministic cell: the chaos plane's scripted-clock
/// degrade leg (mpsc, 2 shards, shard 0 killed mid-round 3 with
/// `on_loss = degrade`). No wall-clock sleeps reach the run, so its
/// synth-plane CSV is reproducible byte for byte.
fn golden_cell_log() -> RunLog {
    let mut cfg = ExperimentConfig::quick("synth", TaskKind::CifarLike, Protocol::Fsfl);
    cfg.clients = 5;
    cfg.rounds = 6;
    cfg.participation = 0.6;
    cfg.seed = 77;
    cfg.compute_shards = 2;
    cfg.transport = TransportKind::Mpsc;
    cfg.policy = RoundPolicy {
        backoff: Duration::from_millis(10),
        join_timeout: Duration::from_secs(30),
        on_loss: OnShardLoss::Degrade,
        ..RoundPolicy::default()
    };
    let clock = Arc::new(ScriptedClock::new(Duration::from_millis(5)));
    coordinator::run_experiment_synthetic_supervised(
        cfg,
        common::manifest(),
        ElasticPlan::default(),
        None,
        Some(clock),
        vec![ChaosDeath {
            shard: 0,
            round: 3,
            point: ChaosPoint::MidRound,
        }],
        |_| {},
    )
    .expect("golden cell must complete")
}

/// Pinned compact event history of the golden cell: shard 0 dies in
/// round 3, its clients {0, 2, 4} fold into the survivor.
const GOLDEN_EVENTS: &str = "D3s0;G3s0c0+2+4";

#[test]
fn golden_cell_csv_and_event_history_are_pinned() {
    let fixture = PathBuf::from(env!("CARGO_MANIFEST_DIR"))
        .join("tests")
        .join("fixtures")
        .join("golden_suite_a_cell.txt");
    let log = golden_cell_log();
    assert_eq!(log.events_compact(), GOLDEN_EVENTS);

    let dir = tmp_dir("golden");
    let path = dir.join("run.csv");
    log.write_csv(&path).unwrap();
    let csv = std::fs::read_to_string(&path).unwrap();
    assert!(
        csv.starts_with("round,up_bytes,down_bytes,"),
        "CSV header drifted: {}",
        csv.lines().next().unwrap_or("")
    );

    if std::env::var_os("FSFL_BLESS").is_some() {
        let blessed = format!(
            "# Golden synth-plane trajectory of the pinned degrade cell\n\
             # (see integration_bench.rs::golden_cell_log). Re-bless with\n\
             # FSFL_BLESS=1 after an intentional numeric change.\n\
             # events: {GOLDEN_EVENTS}\n\
             {csv}"
        );
        std::fs::write(&fixture, blessed).unwrap();
        let _ = std::fs::remove_dir_all(&dir);
        return;
    }

    let raw = std::fs::read_to_string(&fixture)
        .unwrap_or_else(|e| panic!("missing fixture {}: {e}", fixture.display()));
    let body: String = raw
        .lines()
        .filter(|l| !l.starts_with('#'))
        .map(|l| format!("{l}\n"))
        .collect();
    if body.trim() == "PENDING-BLESS" {
        // The fixture has not been blessed on a toolchain-bearing host
        // yet. Pin determinism in the meantime: an identical rerun must
        // reproduce the CSV byte for byte.
        let log2 = golden_cell_log();
        assert_eq!(log2.events_compact(), GOLDEN_EVENTS);
        let path2 = dir.join("rerun.csv");
        log2.write_csv(&path2).unwrap();
        assert_eq!(
            csv,
            std::fs::read_to_string(&path2).unwrap(),
            "golden cell is not deterministic — blessing would be meaningless"
        );
    } else {
        assert_eq!(
            csv, body,
            "golden CSV drifted from the blessed fixture; if the change \
             is intentional, re-bless with FSFL_BLESS=1 cargo test \
             golden_cell_csv_and_event_history_are_pinned"
        );
    }
    let _ = std::fs::remove_dir_all(&dir);
}
