//! `fsfl lint` end-to-end: the analysis plane run against real
//! directory trees.
//!
//! Two halves:
//!
//! 1. **Round-trip on a synthetic crate** — a temp-dir fixture with one
//!    seeded violation per rule must produce exactly those findings at
//!    exactly those `file:line` coordinates (and a clean fixture must
//!    produce none), pinning the scanner's line accounting through the
//!    full `run_lint` pipeline: walker → scanner → rules → sort.
//! 2. **The repository itself** — `run_lint` over this crate must come
//!    back clean, so `cargo test` enforces every source invariant even
//!    where CI's dedicated `fsfl lint` step is not wired in.

use std::fs;
use std::path::{Path, PathBuf};

use fsfl::analysis::run_lint;

/// Fresh fixture directory under the system temp dir. Seeded by case
/// name + pid so parallel test binaries never collide; recreated from
/// scratch each run.
fn fixture_dir(case: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("fsfl-lint-it-{}-{case}", std::process::id()));
    let _ = fs::remove_dir_all(&dir);
    fs::create_dir_all(dir.join("src")).expect("create fixture dir");
    dir
}

fn write(root: &Path, rel: &str, content: &str) {
    let path = root.join(rel);
    if let Some(parent) = path.parent() {
        fs::create_dir_all(parent).expect("create fixture subdir");
    }
    fs::write(path, content).expect("write fixture file");
}

#[test]
fn clean_fixture_tree_produces_no_findings() {
    let root = fixture_dir("clean");
    write(
        &root,
        "src/lib.rs",
        "//! Clean fixture crate.\n\
         \n\
         /// Wrapping add.\n\
         pub fn add(a: u64, b: u64) -> u64 {\n\
             a.wrapping_add(b)\n\
         }\n",
    );
    let report = run_lint(&root).expect("lint run");
    assert_eq!(report.files_scanned, 1);
    assert!(
        report.clean(),
        "clean fixture produced findings: {:?}",
        report.findings
    );
    let _ = fs::remove_dir_all(&root);
}

#[test]
fn seeded_violations_surface_at_exact_file_line_coordinates() {
    let root = fixture_dir("seeded");
    // line 5: raw clock read; line 9: same read under a justified allow
    write(
        &root,
        "src/timer.rs",
        "//! Fixture: clock discipline.\n\
         use std::time::Instant;\n\
         \n\
         pub fn bad() -> Instant {\n\
             Instant::now()\n\
         }\n\
         \n\
         pub fn good() -> Instant {\n\
             Instant::now() // fsfl-lint: allow(clock): fixture-sanctioned read\n\
         }\n",
    );
    // line 3: non-test unwrap in net code; line 12: test-only unwrap (allowed)
    write(
        &root,
        "src/net/conn.rs",
        "//! Fixture: panic hygiene.\n\
         pub fn parse(x: Option<u8>) -> u8 {\n\
             x.unwrap()\n\
         }\n\
         \n\
         #[cfg(test)]\n\
         mod tests {\n\
             #[test]\n\
             fn ok() {\n\
                 assert_eq!(super::parse(Some(3)), 3);\n\
                 let _ = None::<u8>.unwrap_or(0);\n\
                 let _ = Some(1u8).unwrap();\n\
             }\n\
         }\n",
    );
    // line 4: allocation inside a hot fence; line 10: unsafe without SAFETY
    write(
        &root,
        "src/codec.rs",
        "//! Fixture: hot fence + safety.\n\
         // fsfl-lint: hot\n\
         pub fn hot_path(out: &mut Vec<u8>) {\n\
             let staged = vec![0u8; 4];\n\
             out.extend_from_slice(&staged);\n\
         }\n\
         // fsfl-lint: end-hot\n\
         \n\
         pub fn reinterpret(x: &u32) -> u32 {\n\
             unsafe { *(x as *const u32) }\n\
         }\n",
    );
    // line 2: allow() without the mandatory justification
    write(
        &root,
        "src/meta.rs",
        "//! Fixture: directive hygiene.\n\
         // fsfl-lint: allow(clock)\n\
         pub fn noop() {}\n",
    );

    let report = run_lint(&root).expect("lint run");
    assert_eq!(report.files_scanned, 4);
    let got: Vec<(&str, usize, &str)> = report
        .findings
        .iter()
        .map(|f| (f.file.as_str(), f.line, f.rule))
        .collect();
    assert_eq!(
        got,
        vec![
            ("src/codec.rs", 4, "hot-alloc"),
            ("src/codec.rs", 10, "safety"),
            ("src/meta.rs", 2, "directive"),
            ("src/net/conn.rs", 3, "panic"),
            ("src/timer.rs", 5, "clock"),
        ],
        "full findings: {:#?}",
        report.findings
    );
    // every finding renders as `file:line: [rule] message`
    for f in &report.findings {
        let line = f.to_string();
        assert!(
            line.starts_with(&format!("{}:{}: [{}] ", f.file, f.line, f.rule)),
            "malformed finding line: {line}"
        );
    }
    // and the JSON view carries the same coordinates
    let json = report.to_json();
    assert!(json.starts_with("{\"files_scanned\":4,\"findings\":["));
    assert!(
        json.contains("{\"file\":\"src/timer.rs\",\"line\":5,\"rule\":\"clock\""),
        "json missing the clock finding: {json}"
    );
    let _ = fs::remove_dir_all(&root);
}

#[test]
fn repository_tip_lints_clean() {
    let crate_dir = Path::new(env!("CARGO_MANIFEST_DIR"));
    let report = run_lint(crate_dir).expect("lint run over the repository");
    assert!(
        report.files_scanned > 40,
        "suspiciously few files scanned ({}) — walker regression?",
        report.files_scanned
    );
    assert!(
        report.clean(),
        "lint findings on the repository tip:\n{}",
        report
            .findings
            .iter()
            .map(|f| f.to_string())
            .collect::<Vec<_>>()
            .join("\n")
    );
}
