//! Model metadata and parameter plumbing.
//!
//! The python AOT pipeline (`python/compile/aot.py`) emits, per model
//! variant, a `manifest.json` describing every parameter tensor (name,
//! shape, kind, group) in **wire order**, plus an `init.bin` tensor
//! bundle with the initial values.  This module is the rust mirror of
//! that contract: everything the coordinator knows about a model —
//! which tensors are conv filters (row-structured for Eq. 3), which are
//! scale factors, which are BatchNorm state — comes from here.

mod io;
mod manifest;
pub mod params;

pub use io::{read_bundle, read_bundle_from, write_bundle, write_bundle_to, BundleTensor};
pub use manifest::{Group, Kind, Manifest, TensorSpec};
pub use params::{Delta, ParamSet};
