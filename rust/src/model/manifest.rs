//! `manifest.tsv` parsing — the python↔rust model contract.
//!
//! The AOT pipeline emits both `manifest.json` (for humans/tools) and a
//! line-based `manifest.tsv` that this module parses (the build
//! environment has no serde). Format:
//!
//! ```text
//! model<TAB>tiny_cnn
//! variant<TAB>tiny_cnn
//! classes<TAB>10
//! input<TAB>16 16 3
//! batch<TAB>16
//! param_count<TAB>1692
//! scale_count<TAB>34
//! tensor<TAB>name<TAB>kind<TAB>group<TAB>layer<TAB>out_ch<TAB>scale_for<TAB>d0 d1 ...
//! ```

use std::path::Path;

use anyhow::{anyhow, Context, Result};

/// What a tensor *is* — drives codec decisions (structured sparsification
/// applies to row-structured weight kinds; scales/bias/BN use the fine
/// quantization step per paper Sec. 5.1).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Kind {
    /// Convolution weights (rows = output filters).
    ConvW,
    /// Depthwise-convolution weights.
    DwConvW,
    /// Dense/linear weights (rows = output neurons).
    DenseW,
    /// Bias vector.
    Bias,
    /// BatchNorm affine scale γ.
    BnGamma,
    /// BatchNorm affine shift β.
    BnBeta,
    /// BatchNorm running mean.
    BnMean,
    /// BatchNorm running variance.
    BnVar,
    /// Per-filter scale factor (the paper's S).
    Scale,
}

impl Kind {
    /// Row-structured kinds: one row of the 2-D tensor = one filter /
    /// output neuron — the granularity of Eq. (3) and Eq. (4).
    pub fn is_row_structured(self) -> bool {
        matches!(self, Kind::ConvW | Kind::DwConvW | Kind::DenseW)
    }

    /// Side-parameters quantized with the fine step size (2.38e-6 in the
    /// paper): scaling factors, biases and BatchNorm parameters.
    pub fn is_fine_quantized(self) -> bool {
        !self.is_row_structured()
    }

    /// The TSV tag this kind parses from (inverse of
    /// [`std::str::FromStr`]).
    pub fn as_str(self) -> &'static str {
        match self {
            Kind::ConvW => "conv_w",
            Kind::DwConvW => "dw_conv_w",
            Kind::DenseW => "dense_w",
            Kind::Bias => "bias",
            Kind::BnGamma => "bn_gamma",
            Kind::BnBeta => "bn_beta",
            Kind::BnMean => "bn_mean",
            Kind::BnVar => "bn_var",
            Kind::Scale => "scale",
        }
    }
}

impl std::str::FromStr for Kind {
    type Err = anyhow::Error;
    fn from_str(s: &str) -> Result<Self> {
        Ok(match s {
            "conv_w" => Kind::ConvW,
            "dw_conv_w" => Kind::DwConvW,
            "dense_w" => Kind::DenseW,
            "bias" => Kind::Bias,
            "bn_gamma" => Kind::BnGamma,
            "bn_beta" => Kind::BnBeta,
            "bn_mean" => Kind::BnMean,
            "bn_var" => Kind::BnVar,
            "scale" => Kind::Scale,
            other => return Err(anyhow!("unknown tensor kind {other:?}")),
        })
    }
}

/// Update/training group a tensor belongs to.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Group {
    /// Trained by `train_step` (W, biases, BN affine).
    Weight,
    /// Trained by `scale_step` (the paper's S).
    Scale,
    /// BatchNorm running stats — updated by `train_step` from batch
    /// statistics, frozen during scale training.
    State,
    /// Never updated (partial-update models' feature extractors).
    Frozen,
}

impl Group {
    /// The TSV tag this group parses from (inverse of
    /// [`std::str::FromStr`]).
    pub fn as_str(self) -> &'static str {
        match self {
            Group::Weight => "weight",
            Group::Scale => "scale",
            Group::State => "state",
            Group::Frozen => "frozen",
        }
    }
}

impl std::str::FromStr for Group {
    type Err = anyhow::Error;
    fn from_str(s: &str) -> Result<Self> {
        Ok(match s {
            "weight" => Group::Weight,
            "scale" => Group::Scale,
            "state" => Group::State,
            "frozen" => Group::Frozen,
            other => return Err(anyhow!("unknown tensor group {other:?}")),
        })
    }
}

/// One parameter tensor's metadata, in wire order.
#[derive(Debug, Clone, PartialEq)]
pub struct TensorSpec {
    /// Unique tensor name (e.g. `conv1.w`).
    pub name: String,
    /// Tensor shape (row-structured kinds are 2-D: rows × row_len).
    pub shape: Vec<usize>,
    /// What the tensor is (drives codec decisions).
    pub kind: Kind,
    /// Which training group updates it.
    pub group: Group,
    /// Layer this tensor belongs to.
    pub layer: String,
    /// Output-channel count for filterable tensors.
    pub out_ch: Option<usize>,
    /// For scale tensors: the weight tensor they scale.
    pub scale_for: Option<String>,
}

impl TensorSpec {
    /// Element count (≥ 1).
    pub fn numel(&self) -> usize {
        self.shape.iter().product::<usize>().max(1)
    }

    /// (rows, row_len) for row-structured tensors.
    pub fn rows(&self) -> Option<(usize, usize)> {
        if self.kind.is_row_structured() && self.shape.len() == 2 {
            Some((self.shape[0], self.shape[1]))
        } else {
            None
        }
    }
}

/// The full model contract emitted by the python AOT pipeline.
#[derive(Debug, Clone, PartialEq)]
pub struct Manifest {
    /// Base model name.
    pub model: String,
    /// Variant name (an `artifacts/` subdirectory).
    pub variant: String,
    /// Output class count.
    pub classes: usize,
    /// (H, W, C)
    pub input: Vec<usize>,
    /// Fixed batch dimension baked into the step HLOs.
    pub batch: usize,
    /// Total parameter count across all tensors.
    pub param_count: usize,
    /// Total scale-factor count (paper Table 1 `#params_add`).
    pub scale_count: usize,
    /// Every parameter tensor, in wire order.
    pub tensors: Vec<TensorSpec>,
}

impl Manifest {
    /// Load and validate a `manifest.tsv`.
    pub fn load(path: impl AsRef<Path>) -> Result<Self> {
        let path = path.as_ref();
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading manifest {}", path.display()))?;
        let man = Self::parse(&text).with_context(|| format!("parsing {}", path.display()))?;
        man.validate()?;
        Ok(man)
    }

    /// Parse manifest text (see the module docs for the format).
    pub fn parse(text: &str) -> Result<Self> {
        let mut model = String::new();
        let mut variant = String::new();
        let mut classes = 0usize;
        let mut input = Vec::new();
        let mut batch = 0usize;
        let mut param_count = 0usize;
        let mut scale_count = 0usize;
        let mut tensors = Vec::new();
        for (ln, line) in text.lines().enumerate() {
            if line.trim().is_empty() {
                continue;
            }
            let fields: Vec<&str> = line.split('\t').collect();
            let tag = fields[0];
            let val = |i: usize| -> Result<&str> {
                fields
                    .get(i)
                    .copied()
                    .ok_or_else(|| anyhow!("line {}: missing field {i}", ln + 1))
            };
            match tag {
                "model" => model = val(1)?.to_string(),
                "variant" => variant = val(1)?.to_string(),
                "classes" => classes = val(1)?.parse()?,
                "input" => {
                    input = val(1)?
                        .split_whitespace()
                        .map(|d| d.parse::<usize>())
                        .collect::<std::result::Result<_, _>>()?
                }
                "batch" => batch = val(1)?.parse()?,
                "param_count" => param_count = val(1)?.parse()?,
                "scale_count" => scale_count = val(1)?.parse()?,
                "tensor" => {
                    let shape = val(7)?
                        .split_whitespace()
                        .map(|d| d.parse::<usize>())
                        .collect::<std::result::Result<Vec<_>, _>>()?;
                    tensors.push(TensorSpec {
                        name: val(1)?.to_string(),
                        kind: val(2)?.parse()?,
                        group: val(3)?.parse()?,
                        layer: val(4)?.to_string(),
                        out_ch: match val(5)? {
                            "-" => None,
                            s => Some(s.parse()?),
                        },
                        scale_for: match val(6)? {
                            "-" => None,
                            s => Some(s.to_string()),
                        },
                        shape,
                    });
                }
                other => return Err(anyhow!("line {}: unknown tag {other:?}", ln + 1)),
            }
        }
        if tensors.is_empty() {
            return Err(anyhow!("manifest has no tensors"));
        }
        Ok(Self {
            model,
            variant,
            classes,
            input,
            batch,
            param_count,
            scale_count,
            tensors,
        })
    }

    /// Structural sanity checks: unique names, 2-D row-structured
    /// tensors, parameter-count and scale-target consistency.
    pub fn validate(&self) -> Result<()> {
        let mut seen = std::collections::HashSet::new();
        for t in &self.tensors {
            if !seen.insert(&t.name) {
                return Err(anyhow!("duplicate tensor {}", t.name));
            }
            if t.kind.is_row_structured() && t.shape.len() != 2 {
                return Err(anyhow!("{}: row-structured tensor must be 2-D", t.name));
            }
        }
        let total: usize = self.tensors.iter().map(|t| t.numel()).sum();
        if total != self.param_count {
            return Err(anyhow!(
                "param_count mismatch: manifest says {}, tensors sum to {total}",
                self.param_count
            ));
        }
        for t in &self.tensors {
            if let Some(sf) = &t.scale_for {
                let target = self
                    .tensors
                    .iter()
                    .find(|u| &u.name == sf)
                    .ok_or_else(|| anyhow!("{}: scale_for {:?} not found", t.name, sf))?;
                if target.shape[0] != t.numel() {
                    return Err(anyhow!("{}: scale len != target rows", t.name));
                }
            }
        }
        Ok(())
    }

    /// Render the manifest back to its `manifest.tsv` text form — the
    /// exact format [`Manifest::parse`] reads (round-trip pinned by unit
    /// test). This is how the model contract crosses the shard wire: a
    /// joining shard sends its manifest in the `Ready` handshake so the
    /// coordinator needs no artifacts directory of its own.
    pub fn to_tsv(&self) -> String {
        use std::fmt::Write as _;
        let mut out = String::new();
        let _ = writeln!(out, "model\t{}", self.model);
        let _ = writeln!(out, "variant\t{}", self.variant);
        let _ = writeln!(out, "classes\t{}", self.classes);
        let dims: Vec<String> = self.input.iter().map(|d| d.to_string()).collect();
        let _ = writeln!(out, "input\t{}", dims.join(" "));
        let _ = writeln!(out, "batch\t{}", self.batch);
        let _ = writeln!(out, "param_count\t{}", self.param_count);
        let _ = writeln!(out, "scale_count\t{}", self.scale_count);
        for t in &self.tensors {
            let shape: Vec<String> = t.shape.iter().map(|d| d.to_string()).collect();
            let _ = writeln!(
                out,
                "tensor\t{}\t{}\t{}\t{}\t{}\t{}\t{}",
                t.name,
                t.kind.as_str(),
                t.group.as_str(),
                t.layer,
                t.out_ch.map(|c| c.to_string()).unwrap_or_else(|| "-".into()),
                t.scale_for.clone().unwrap_or_else(|| "-".into()),
                shape.join(" "),
            );
        }
        out
    }

    /// Wire-order index of a tensor by name.
    pub fn index_of(&self, name: &str) -> Option<usize> {
        self.tensors.iter().position(|t| t.name == name)
    }

    /// Wire-order indices of every tensor in a training group.
    pub fn group_indices(&self, group: Group) -> Vec<usize> {
        self.tensors
            .iter()
            .enumerate()
            .filter(|(_, t)| t.group == group)
            .map(|(i, _)| i)
            .collect()
    }

    /// Tensors whose updates are transmitted: everything that can change
    /// locally (weight + scale + state); frozen tensors never move.
    pub fn update_indices(&self) -> Vec<usize> {
        self.tensors
            .iter()
            .enumerate()
            .filter(|(_, t)| t.group != Group::Frozen)
            .map(|(i, _)| i)
            .collect()
    }

    /// Total number of trainable scale factors (paper Table 1 #params_add).
    pub fn scale_param_count(&self) -> usize {
        self.group_indices(Group::Scale)
            .iter()
            .map(|&i| self.tensors[i].numel())
            .sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = "model\tm\nvariant\tv\nclasses\t2\ninput\t4 4 1\nbatch\t2\nparam_count\t13\nscale_count\t3\ntensor\tc.w\tconv_w\tweight\tc\t3\t-\t3 3\ntensor\tc.s\tscale\tscale\tc\t3\tc.w\t3\ntensor\tc.b\tbias\tweight\tc\t1\t-\t1\n";

    #[test]
    fn parse_sample() {
        let m = Manifest::parse(SAMPLE).unwrap();
        m.validate().unwrap();
        assert_eq!(m.classes, 2);
        assert_eq!(m.tensors.len(), 3);
        assert_eq!(m.tensors[0].rows(), Some((3, 3)));
        assert_eq!(m.tensors[1].scale_for.as_deref(), Some("c.w"));
        assert_eq!(m.group_indices(Group::Scale), vec![1]);
        assert_eq!(m.update_indices(), vec![0, 1, 2]);
        assert_eq!(m.scale_param_count(), 3);
    }

    #[test]
    fn tsv_round_trips() {
        let m = Manifest::parse(SAMPLE).unwrap();
        let again = Manifest::parse(&m.to_tsv()).unwrap();
        assert_eq!(m, again, "to_tsv → parse must be the identity");
        again.validate().unwrap();
    }

    #[test]
    fn bad_scale_target_rejected() {
        let bad = SAMPLE.replace("\tc.w\t3\n", "\tnope\t3\n");
        let m = Manifest::parse(&bad).unwrap();
        assert!(m.validate().is_err());
    }

    #[test]
    fn param_count_mismatch_rejected() {
        let bad = SAMPLE.replace("param_count\t13", "param_count\t14");
        let m = Manifest::parse(&bad).unwrap();
        assert!(m.validate().is_err());
    }
}
