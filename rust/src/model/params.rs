//! [`ParamSet`] — the full model state as flat f32 vectors in wire order.
//!
//! All FL-side arithmetic (differential updates Eq. 1, aggregation,
//! residuals, sparsification) happens on this representation; the runtime
//! converts to/from XLA literals at step boundaries only.

use std::sync::Arc;

use anyhow::{anyhow, Result};

use super::{Group, Manifest};

/// Model parameters: one `Vec<f32>` per manifest tensor, in wire order.
#[derive(Debug, Clone, PartialEq)]
pub struct ParamSet {
    /// The model contract these values conform to.
    pub manifest: Arc<Manifest>,
    /// Flat tensor values, in manifest (wire) order.
    pub tensors: Vec<Vec<f32>>,
}

impl ParamSet {
    /// Wrap tensor values, validating counts/shapes against the manifest.
    pub fn new(manifest: Arc<Manifest>, tensors: Vec<Vec<f32>>) -> Result<Self> {
        if tensors.len() != manifest.tensors.len() {
            return Err(anyhow!(
                "tensor count {} != manifest {}",
                tensors.len(),
                manifest.tensors.len()
            ));
        }
        for (t, spec) in tensors.iter().zip(&manifest.tensors) {
            if t.len() != spec.numel() {
                return Err(anyhow!("{}: len {} != {}", spec.name, t.len(), spec.numel()));
            }
        }
        Ok(Self { manifest, tensors })
    }

    /// A same-shape parameter set with every value zero.
    pub fn zeros_like(&self) -> Self {
        Self {
            manifest: self.manifest.clone(),
            tensors: self.tensors.iter().map(|t| vec![0.0; t.len()]).collect(),
        }
    }

    /// Load initial parameters from an `init.bin` bundle, verifying names
    /// and shapes against the manifest.
    pub fn from_bundle(manifest: Arc<Manifest>, path: impl AsRef<std::path::Path>) -> Result<Self> {
        let bundle = super::read_bundle(path)?;
        if bundle.len() != manifest.tensors.len() {
            return Err(anyhow!("bundle/manifest tensor count mismatch"));
        }
        let mut tensors = Vec::with_capacity(bundle.len());
        for (bt, spec) in bundle.into_iter().zip(&manifest.tensors) {
            if bt.name != spec.name {
                return Err(anyhow!("bundle order mismatch: {} != {}", bt.name, spec.name));
            }
            if bt.data.len() != spec.numel() {
                return Err(anyhow!("{}: bundle size mismatch", spec.name));
            }
            tensors.push(bt.data);
        }
        Ok(Self { manifest, tensors })
    }

    /// Total element count.
    pub fn numel(&self) -> usize {
        self.tensors.iter().map(|t| t.len()).sum()
    }

    /// A tensor's values by name.
    pub fn get(&self, name: &str) -> Option<&[f32]> {
        let i = self.manifest.index_of(name)?;
        Some(&self.tensors[i])
    }

    /// `self - other`, the differential update ΔW of Eq. (1).
    pub fn delta_from(&self, prev: &ParamSet) -> Delta {
        let mut out = Delta::zeros(self.manifest.clone());
        self.delta_from_into(prev, &mut out);
        out
    }

    /// [`Self::delta_from`] into a caller-owned buffer (steady-state FL
    /// rounds reuse one `Delta` per client instead of allocating).
    pub fn delta_from_into(&self, prev: &ParamSet, out: &mut Delta) {
        debug_assert!(Arc::ptr_eq(&self.manifest, &out.manifest) || self.manifest == out.manifest);
        for ((o, a), b) in out.tensors.iter_mut().zip(&self.tensors).zip(&prev.tensors) {
            for ((d, &x), &y) in o.iter_mut().zip(a).zip(b) {
                *d = x - y;
            }
        }
    }

    /// Overwrite `self` with `other`'s values without reallocating the
    /// tensor storage (both must share a manifest).
    pub fn copy_from(&mut self, other: &ParamSet) {
        debug_assert_eq!(self.tensors.len(), other.tensors.len());
        for (t, o) in self.tensors.iter_mut().zip(&other.tensors) {
            t.copy_from_slice(o);
        }
    }

    /// `self += delta` (client sync / server apply).
    pub fn add_delta(&mut self, delta: &Delta) {
        for (t, d) in self.tensors.iter_mut().zip(&delta.tensors) {
            for (x, y) in t.iter_mut().zip(d) {
                *x += y;
            }
        }
    }

    /// Indices of tensors in a training group (wire order).
    pub fn group_indices(&self, group: Group) -> Vec<usize> {
        self.manifest.group_indices(group)
    }
}

/// A differential update ΔW — same layout as [`ParamSet`], but semantically
/// a difference; the unit that is sparsified, quantized and transmitted.
#[derive(Debug, Clone, PartialEq)]
pub struct Delta {
    /// The model contract this difference conforms to.
    pub manifest: Arc<Manifest>,
    /// Flat difference values, in manifest (wire) order.
    pub tensors: Vec<Vec<f32>>,
}

impl Delta {
    /// All-zero difference for a manifest.
    pub fn zeros(manifest: Arc<Manifest>) -> Self {
        let tensors = manifest.tensors.iter().map(|t| vec![0.0; t.numel()]).collect();
        Self { manifest, tensors }
    }

    /// Total element count.
    pub fn numel(&self) -> usize {
        self.tensors.iter().map(|t| t.len()).sum()
    }

    /// Fraction of exactly-zero elements across all update tensors
    /// (Fig. 4's sparsity metric).
    pub fn sparsity(&self) -> f64 {
        let total = self.numel();
        if total == 0 {
            return 1.0;
        }
        let zeros: usize = self
            .tensors
            .iter()
            .map(|t| t.iter().filter(|&&x| x == 0.0).count())
            .sum();
        zeros as f64 / total as f64
    }

    /// Sparsity restricted to a tensor subset (e.g. the transmitted
    /// update tensors — frozen tensors are trivially zero).
    pub fn sparsity_of(&self, indices: &[usize]) -> f64 {
        let total: usize = indices.iter().map(|&i| self.tensors[i].len()).sum();
        if total == 0 {
            return 1.0;
        }
        let zeros: usize = indices
            .iter()
            .map(|&i| self.tensors[i].iter().filter(|&&x| x == 0.0).count())
            .sum();
        zeros as f64 / total as f64
    }

    /// Zero every element, keeping the allocated storage (buffer reuse
    /// across rounds — and the "no data leaks across tensors" half of the
    /// scratch-buffer contract).
    pub fn clear(&mut self) {
        for t in &mut self.tensors {
            t.iter_mut().for_each(|x| *x = 0.0);
        }
    }

    /// Overwrite `self` with `other`'s values without reallocating.
    pub fn copy_from(&mut self, other: &Delta) {
        debug_assert_eq!(self.tensors.len(), other.tensors.len());
        for (t, o) in self.tensors.iter_mut().zip(&other.tensors) {
            t.copy_from_slice(o);
        }
    }

    /// FNV-1a over the exact f32 bit patterns (tensor lengths mixed in).
    /// One allocation-free pass — the cheap stand-in for full `Delta`
    /// equality in debug assertions on the wire path.
    pub fn checksum(&self) -> u64 {
        const OFFSET: u64 = 0xcbf29ce484222325;
        const PRIME: u64 = 0x100000001b3;
        let mut h = OFFSET;
        for t in &self.tensors {
            h ^= t.len() as u64;
            h = h.wrapping_mul(PRIME);
            for &x in t {
                h ^= x.to_bits() as u64;
                h = h.wrapping_mul(PRIME);
            }
        }
        h
    }

    /// Elementwise accumulate (used by server-side averaging).
    pub fn accumulate(&mut self, other: &Delta) {
        for (t, o) in self.tensors.iter_mut().zip(&other.tensors) {
            for (x, y) in t.iter_mut().zip(o) {
                *x += y;
            }
        }
    }

    /// `self *= f` elementwise.
    pub fn scale(&mut self, f: f32) {
        for t in &mut self.tensors {
            for x in t.iter_mut() {
                *x *= f;
            }
        }
    }

    /// `self += other * f` without an intermediate clone.
    pub fn accumulate_scaled(&mut self, other: &Delta, f: f32) {
        for (t, o) in self.tensors.iter_mut().zip(&other.tensors) {
            for (x, y) in t.iter_mut().zip(o) {
                *x += y * f;
            }
        }
    }

    /// Euclidean norm over all elements.
    pub fn l2_norm(&self) -> f64 {
        self.tensors
            .iter()
            .flat_map(|t| t.iter())
            .map(|&x| (x as f64) * (x as f64))
            .sum::<f64>()
            .sqrt()
    }
}

/// Hand-built manifests for unit tests across the crate.
#[cfg(test)]
pub mod tests_support {
    use super::*;
    use crate::model::{Kind, TensorSpec};

    /// conv_w [3,3] (row-structured) + bias [4] (fine-quantized flat).
    pub fn manifest_conv_dense() -> Arc<Manifest> {
        let tensors = vec![
            TensorSpec {
                name: "c.w".into(),
                shape: vec![3, 3],
                kind: Kind::ConvW,
                group: Group::Weight,
                layer: "c".into(),
                out_ch: Some(3),
                scale_for: None,
            },
            TensorSpec {
                name: "c.b".into(),
                shape: vec![4],
                kind: Kind::Bias,
                group: Group::Weight,
                layer: "c".into(),
                out_ch: Some(4),
                scale_for: None,
            },
        ];
        Arc::new(Manifest {
            model: "test".into(),
            variant: "test".into(),
            classes: 2,
            input: vec![4, 4, 1],
            batch: 2,
            param_count: 13,
            scale_count: 0,
            tensors,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::{Kind, TensorSpec};

    pub(crate) fn test_manifest() -> Arc<Manifest> {
        let tensors = vec![
            TensorSpec {
                name: "c.w".into(),
                shape: vec![4, 9],
                kind: Kind::ConvW,
                group: Group::Weight,
                layer: "c".into(),
                out_ch: Some(4),
                scale_for: None,
            },
            TensorSpec {
                name: "c.s".into(),
                shape: vec![4],
                kind: Kind::Scale,
                group: Group::Scale,
                layer: "c".into(),
                out_ch: Some(4),
                scale_for: Some("c.w".into()),
            },
        ];
        Arc::new(Manifest {
            model: "test".into(),
            variant: "test".into(),
            classes: 2,
            input: vec![4, 4, 1],
            batch: 2,
            param_count: 40,
            scale_count: 4,
            tensors,
        })
    }

    #[test]
    fn delta_roundtrip() {
        let m = test_manifest();
        let a = ParamSet::new(m.clone(), vec![vec![1.0; 36], vec![1.0; 4]]).unwrap();
        let mut b = ParamSet::new(m, vec![vec![0.5; 36], vec![2.0; 4]]).unwrap();
        let d = a.delta_from(&b);
        assert_eq!(d.tensors[0][0], 0.5);
        assert_eq!(d.tensors[1][0], -1.0);
        b.add_delta(&d);
        assert_eq!(b, a);
    }

    #[test]
    fn reuse_helpers_match_allocating_paths() {
        let m = test_manifest();
        let a = ParamSet::new(m.clone(), vec![vec![1.5; 36], vec![0.25; 4]]).unwrap();
        let b = ParamSet::new(m.clone(), vec![vec![1.0; 36], vec![1.0; 4]]).unwrap();
        let fresh = a.delta_from(&b);
        let mut reused = Delta::zeros(m.clone());
        reused.tensors[0][7] = 99.0; // stale garbage must be overwritten
        a.delta_from_into(&b, &mut reused);
        assert_eq!(fresh, reused);
        let mut copy = Delta::zeros(m.clone());
        copy.copy_from(&fresh);
        assert_eq!(copy, fresh);
        copy.clear();
        assert_eq!(copy.sparsity(), 1.0);
        let mut p = ParamSet::new(m, vec![vec![0.0; 36], vec![0.0; 4]]).unwrap();
        p.copy_from(&a);
        assert_eq!(p, a);
    }

    #[test]
    fn checksum_tracks_content_and_layout() {
        let m = test_manifest();
        let mut d1 = Delta::zeros(m.clone());
        let mut d2 = Delta::zeros(m);
        assert_eq!(d1.checksum(), d2.checksum());
        d1.tensors[0][3] = 1.0e-3;
        assert_ne!(d1.checksum(), d2.checksum());
        d2.tensors[0][3] = 1.0e-3;
        assert_eq!(d1.checksum(), d2.checksum());
        // same value in a different slot must differ (position mixed in
        // via the running FNV state)
        let mut d3 = Delta::zeros(d1.manifest.clone());
        d3.tensors[0][4] = 1.0e-3;
        assert_ne!(d1.checksum(), d3.checksum());
    }

    #[test]
    fn sparsity_counts_zeros() {
        let m = test_manifest();
        let mut d = Delta::zeros(m);
        assert_eq!(d.sparsity(), 1.0);
        d.tensors[0][0] = 1.0;
        assert!((d.sparsity() - 39.0 / 40.0).abs() < 1e-12);
    }
}
