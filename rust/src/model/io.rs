//! Tensor-bundle binary I/O — the counterpart of python/compile/bundle.py.
//!
//! Layout (little-endian):
//! `b"FSTB" | u32 version | u32 count | { u32 name_len | name | u32 ndim |
//! u32*ndim dims | u32 dtype(0=f32) | f32*numel data }*`

use std::io::{Read, Write};
use std::path::Path;

use anyhow::{anyhow, Context, Result};

const MAGIC: &[u8; 4] = b"FSTB";
const VERSION: u32 = 1;
const DTYPE_F32: u32 = 0;

/// A named f32 tensor as stored in a bundle.
#[derive(Debug, Clone, PartialEq)]
pub struct BundleTensor {
    /// Tensor name (matches the manifest).
    pub name: String,
    /// Tensor shape.
    pub shape: Vec<usize>,
    /// Flat values, row-major.
    pub data: Vec<f32>,
}

fn read_u32(r: &mut impl Read) -> Result<u32> {
    let mut b = [0u8; 4];
    r.read_exact(&mut b)?;
    Ok(u32::from_le_bytes(b))
}

/// Read a tensor bundle from any byte source (the FSTB codec itself;
/// also embedded inside session snapshots — see `crate::session`).
pub fn read_bundle_from(f: &mut impl Read) -> Result<Vec<BundleTensor>> {
    let mut magic = [0u8; 4];
    f.read_exact(&mut magic)?;
    if &magic != MAGIC {
        return Err(anyhow!("bad bundle magic {magic:?}"));
    }
    let version = read_u32(f)?;
    if version != VERSION {
        return Err(anyhow!("unsupported bundle version {version}"));
    }
    let count = read_u32(f)? as usize;
    let mut out = Vec::with_capacity(count.min(1 << 16));
    for _ in 0..count {
        let nlen = read_u32(f)? as usize;
        let mut nb = vec![0u8; nlen];
        f.read_exact(&mut nb)?;
        let name = String::from_utf8(nb).context("tensor name not utf-8")?;
        let ndim = read_u32(f)? as usize;
        let mut shape = Vec::with_capacity(ndim.min(1 << 8));
        for _ in 0..ndim {
            shape.push(read_u32(f)? as usize);
        }
        let dtype = read_u32(f)?;
        if dtype != DTYPE_F32 {
            return Err(anyhow!("{name}: unsupported dtype {dtype}"));
        }
        let numel: usize = shape.iter().product::<usize>().max(1);
        let mut raw = vec![0u8; numel * 4];
        f.read_exact(&mut raw)?;
        let data = raw
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect();
        out.push(BundleTensor { name, shape, data });
    }
    Ok(out)
}

/// Read a tensor bundle (e.g. `init.bin`) from disk.
pub fn read_bundle(path: impl AsRef<Path>) -> Result<Vec<BundleTensor>> {
    let path = path.as_ref();
    let mut f = std::io::BufReader::new(
        std::fs::File::open(path).with_context(|| format!("opening {}", path.display()))?,
    );
    read_bundle_from(&mut f).with_context(|| format!("reading bundle {}", path.display()))
}

/// Write a tensor bundle to any byte sink (inverse of
/// [`read_bundle_from`]).
pub fn write_bundle_to(f: &mut impl Write, tensors: &[BundleTensor]) -> Result<()> {
    f.write_all(MAGIC)?;
    f.write_all(&VERSION.to_le_bytes())?;
    f.write_all(&(tensors.len() as u32).to_le_bytes())?;
    for t in tensors {
        let numel: usize = t.shape.iter().product::<usize>().max(1);
        if numel != t.data.len() {
            return Err(anyhow!("{}: shape/data mismatch", t.name));
        }
        f.write_all(&(t.name.len() as u32).to_le_bytes())?;
        f.write_all(t.name.as_bytes())?;
        f.write_all(&(t.shape.len() as u32).to_le_bytes())?;
        for &d in &t.shape {
            f.write_all(&(d as u32).to_le_bytes())?;
        }
        f.write_all(&DTYPE_F32.to_le_bytes())?;
        for &v in &t.data {
            f.write_all(&v.to_le_bytes())?;
        }
    }
    Ok(())
}

/// Write a tensor bundle to disk (the inverse of [`read_bundle`]).
pub fn write_bundle(path: impl AsRef<Path>, tensors: &[BundleTensor]) -> Result<()> {
    let mut f = std::io::BufWriter::new(std::fs::File::create(path.as_ref())?);
    write_bundle_to(&mut f, tensors)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip() {
        let dir = std::env::temp_dir().join("fsfl_bundle_test");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("t.bin");
        let tensors = vec![
            BundleTensor {
                name: "a.w".into(),
                shape: vec![2, 3],
                data: vec![1.0, -2.5, 3.0, 0.0, 5.5, -6.0],
            },
            BundleTensor {
                name: "b".into(),
                shape: vec![4],
                data: vec![0.1, 0.2, 0.3, 0.4],
            },
        ];
        write_bundle(&p, &tensors).unwrap();
        let back = read_bundle(&p).unwrap();
        assert_eq!(back, tensors);
    }

    #[test]
    fn bad_magic_rejected() {
        let dir = std::env::temp_dir().join("fsfl_bundle_test2");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("bad.bin");
        std::fs::write(&p, b"NOPE\x01\x00\x00\x00\x00\x00\x00\x00").unwrap();
        assert!(read_bundle(&p).is_err());
    }
}
