//! Minimal JSON reader for bench artifacts (no external deps by
//! design, like [`crate::benchkit::Report`] on the writing side).
//!
//! Supports exactly what the bench schemas need: objects (insertion
//! order preserved, so key-path enumeration is stable), arrays,
//! strings with the escapes [`crate::benchkit`] emits, finite numbers,
//! booleans and `null`. Strict: trailing garbage, duplicate structure
//! errors and unknown escapes are rejected rather than guessed at —
//! this parser is the schema gate, not a lenient consumer.

use anyhow::{anyhow, Result};

/// One parsed JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Value {
    /// `null`.
    Null,
    /// `true` / `false`.
    Bool(bool),
    /// Any JSON number (parsed as `f64`; the schemas never need more
    /// than 53 bits of integer precision).
    Num(f64),
    /// A string, unescaped.
    Str(String),
    /// An array.
    Arr(Vec<Value>),
    /// An object, as key/value pairs in source order.
    Obj(Vec<(String, Value)>),
}

impl Value {
    /// Look up a key in an object value.
    pub fn get(&self, key: &str) -> Option<&Value> {
        match self {
            Value::Obj(pairs) => pairs.iter().find(|(k, _)| k == key).map(|(_, v)| v),
            _ => None,
        }
    }

    /// The number inside a [`Value::Num`].
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Value::Num(n) => Some(*n),
            _ => None,
        }
    }

    /// The string inside a [`Value::Str`].
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Value::Str(s) => Some(s),
            _ => None,
        }
    }

    /// The bool inside a [`Value::Bool`].
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Value::Bool(b) => Some(*b),
            _ => None,
        }
    }

    /// The items of a [`Value::Arr`].
    pub fn as_arr(&self) -> Option<&[Value]> {
        match self {
            Value::Arr(items) => Some(items),
            _ => None,
        }
    }

    /// The pairs of a [`Value::Obj`].
    pub fn as_obj(&self) -> Option<&[(String, Value)]> {
        match self {
            Value::Obj(pairs) => Some(pairs),
            _ => None,
        }
    }

    /// Canonical re-rendering (used for structural equality in the
    /// seed-reproducibility checks). Numbers render via Rust's shortest
    /// `f64` formatting, matching what [`crate::benchkit::Report`]
    /// wrote, so parse→render round-trips the bench artifacts.
    pub fn render(&self) -> String {
        match self {
            Value::Null => "null".into(),
            Value::Bool(b) => b.to_string(),
            Value::Num(n) => {
                if n.is_finite() {
                    format!("{n}")
                } else {
                    "null".into()
                }
            }
            Value::Str(s) => {
                let mut out = String::with_capacity(s.len() + 2);
                out.push('"');
                for c in s.chars() {
                    match c {
                        '"' => out.push_str("\\\""),
                        '\\' => out.push_str("\\\\"),
                        '\n' => out.push_str("\\n"),
                        '\t' => out.push_str("\\t"),
                        c if (c as u32) < 0x20 => {
                            out.push_str(&format!("\\u{:04x}", c as u32));
                        }
                        c => out.push(c),
                    }
                }
                out.push('"');
                out
            }
            Value::Arr(items) => {
                let inner: Vec<String> = items.iter().map(Value::render).collect();
                format!("[{}]", inner.join(", "))
            }
            Value::Obj(pairs) => {
                let inner: Vec<String> = pairs
                    .iter()
                    .map(|(k, v)| format!("{}: {}", Value::Str(k.clone()).render(), v.render()))
                    .collect();
                format!("{{{}}}", inner.join(", "))
            }
        }
    }
}

/// Parse one complete JSON document. Trailing non-whitespace is an
/// error.
pub fn parse(input: &str) -> Result<Value> {
    let mut p = Parser {
        bytes: input.as_bytes(),
        pos: 0,
    };
    p.skip_ws();
    let v = p.value()?;
    p.skip_ws();
    if p.pos != p.bytes.len() {
        return Err(anyhow!(
            "trailing garbage at byte {} of JSON document",
            p.pos
        ));
    }
    Ok(v)
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl Parser<'_> {
    fn skip_ws(&mut self) {
        while let Some(b) = self.bytes.get(self.pos) {
            if matches!(b, b' ' | b'\t' | b'\n' | b'\r') {
                self.pos += 1;
            } else {
                break;
            }
        }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn expect(&mut self, b: u8) -> Result<()> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            Err(anyhow!(
                "expected {:?} at byte {} (found {:?})",
                b as char,
                self.pos,
                self.peek().map(|c| c as char)
            ))
        }
    }

    fn literal(&mut self, word: &str, v: Value) -> Result<Value> {
        if self.bytes[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(v)
        } else {
            Err(anyhow!("invalid literal at byte {}", self.pos))
        }
    }

    fn value(&mut self) -> Result<Value> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Value::Str(self.string()?)),
            Some(b't') => self.literal("true", Value::Bool(true)),
            Some(b'f') => self.literal("false", Value::Bool(false)),
            Some(b'n') => self.literal("null", Value::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            other => Err(anyhow!(
                "unexpected {:?} at byte {}",
                other.map(|c| c as char),
                self.pos
            )),
        }
    }

    fn object(&mut self) -> Result<Value> {
        self.expect(b'{')?;
        let mut pairs = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Value::Obj(pairs));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let v = self.value()?;
            pairs.push((key, v));
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Value::Obj(pairs));
                }
                _ => return Err(anyhow!("expected ',' or '}}' at byte {}", self.pos)),
            }
        }
    }

    fn array(&mut self) -> Result<Value> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Value::Arr(items));
        }
        loop {
            self.skip_ws();
            items.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Value::Arr(items));
                }
                _ => return Err(anyhow!("expected ',' or ']' at byte {}", self.pos)),
            }
        }
    }

    fn string(&mut self) -> Result<String> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err(anyhow!("unterminated string")),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    match self.peek() {
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        Some(b'/') => out.push('/'),
                        Some(b'n') => out.push('\n'),
                        Some(b't') => out.push('\t'),
                        Some(b'r') => out.push('\r'),
                        Some(b'b') => out.push('\u{8}'),
                        Some(b'f') => out.push('\u{c}'),
                        Some(b'u') => {
                            let hex = self
                                .bytes
                                .get(self.pos + 1..self.pos + 5)
                                .ok_or_else(|| anyhow!("truncated \\u escape"))?;
                            let code = u32::from_str_radix(std::str::from_utf8(hex)?, 16)?;
                            out.push(
                                char::from_u32(code)
                                    .ok_or_else(|| anyhow!("invalid \\u escape {code:#x}"))?,
                            );
                            self.pos += 4;
                        }
                        other => {
                            return Err(anyhow!("unknown escape {:?}", other.map(|c| c as char)))
                        }
                    }
                    self.pos += 1;
                }
                Some(_) => {
                    // multi-byte UTF-8 passes through unchanged
                    let s = std::str::from_utf8(&self.bytes[self.pos..])?;
                    let c = s.chars().next().unwrap();
                    out.push(c);
                    self.pos += c.len_utf8();
                }
            }
        }
    }

    /// Strict JSON number grammar:
    /// `-? (0 | [1-9][0-9]*) ('.' [0-9]+)? ([eE] [+-]? [0-9]+)?`.
    /// The scanner used to slurp any run of `[0-9+-.eE]` and lean on
    /// `f64::parse` for rejection, so shapes like `1-2` or a lone `-`
    /// surfaced as a confusing parse-float error (or, worse, as a
    /// trailing-garbage error far from the real defect). Now every
    /// malformed number fails HERE, with the byte offset where it
    /// starts.
    fn number(&mut self) -> Result<Value> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        match self.peek() {
            Some(b'0') => {
                self.pos += 1;
                if matches!(self.peek(), Some(b'0'..=b'9')) {
                    return Err(anyhow!(
                        "malformed number at byte {start}: leading zero"
                    ));
                }
            }
            Some(b'1'..=b'9') => {
                while matches!(self.peek(), Some(b'0'..=b'9')) {
                    self.pos += 1;
                }
            }
            _ => {
                return Err(anyhow!(
                    "malformed number at byte {start}: expected a digit"
                ))
            }
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            if !matches!(self.peek(), Some(b'0'..=b'9')) {
                return Err(anyhow!(
                    "malformed number at byte {start}: fraction needs digits"
                ));
            }
            while matches!(self.peek(), Some(b'0'..=b'9')) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e') | Some(b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+') | Some(b'-')) {
                self.pos += 1;
            }
            if !matches!(self.peek(), Some(b'0'..=b'9')) {
                return Err(anyhow!(
                    "malformed number at byte {start}: exponent needs digits"
                ));
            }
            while matches!(self.peek(), Some(b'0'..=b'9')) {
                self.pos += 1;
            }
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos])?;
        let n: f64 = text
            .parse()
            .map_err(|e| anyhow!("bad number {text:?} at byte {start}: {e}"))?;
        Ok(Value::Num(n))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::benchkit::Report;

    #[test]
    fn parses_report_output_round_trip() {
        let mut inner = Report::new();
        inner.num("p50", 1.5).num("empty", f64::NAN);
        let mut r = Report::new();
        r.str("schema", "fsfl-bench-run")
            .int("v", 1)
            .bool("ok", true)
            .nums("round_ms", &[1.0, 2.25])
            .obj("stats", inner);
        let v = parse(&r.render()).unwrap();
        assert_eq!(v.get("schema").and_then(Value::as_str), Some("fsfl-bench-run"));
        assert_eq!(v.get("v").and_then(Value::as_f64), Some(1.0));
        assert_eq!(v.get("ok").and_then(Value::as_bool), Some(true));
        assert_eq!(
            v.get("round_ms").and_then(Value::as_arr).map(|a| a.len()),
            Some(2)
        );
        assert!(matches!(
            v.get("stats").and_then(|s| s.get("empty")),
            Some(Value::Null)
        ));
        // canonical re-render parses back to the same tree
        assert_eq!(parse(&v.render()).unwrap(), v);
    }

    #[test]
    fn rejects_trailing_garbage_and_truncation() {
        assert!(parse("{\"a\": 1} x").is_err());
        assert!(parse("{\"a\": ").is_err());
        assert!(parse("[1, 2").is_err());
        assert!(parse("\"unterminated").is_err());
        assert!(parse("{\"a\" 1}").is_err());
    }

    #[test]
    fn string_escapes_and_unicode() {
        let v = parse("\"a\\n\\t\\\"\\u0041é\"").unwrap();
        assert_eq!(v.as_str(), Some("a\n\t\"Aé"));
        assert!(parse("\"\\x\"").is_err());
    }

    #[test]
    fn numbers_including_negatives_and_exponents() {
        assert_eq!(parse("-12.5e2").unwrap().as_f64(), Some(-1250.0));
        assert_eq!(parse("0").unwrap().as_f64(), Some(0.0));
        assert_eq!(parse("-0.5").unwrap().as_f64(), Some(-0.5));
        assert_eq!(parse("1e-7").unwrap().as_f64(), Some(1e-7));
        assert_eq!(parse("2E+3").unwrap().as_f64(), Some(2000.0));
        assert!(parse("1.2.3").is_err());
    }

    #[test]
    fn malformed_numbers_fail_in_the_scanner_with_a_byte_offset() {
        // shapes the old [0-9+-.eE] slurp accepted into f64::parse
        for bad in ["1-2", "1e", "1E+", "1.", "-", "01", "1.2.3", "--1", "1e5e2"] {
            let err = format!("{:#}", parse(bad).unwrap_err());
            assert!(
                err.contains("byte"),
                "{bad:?} must fail with a byte offset, got: {err}"
            );
        }
        // the offset points at the malformed number, not the document
        // start — byte 7 is where `1e` begins inside the object
        let err = format!("{:#}", parse("{\"ok\": 1e}").unwrap_err());
        assert!(err.contains("byte 7"), "wrong offset: {err}");
        // `+1` was already rejected at value dispatch; keep it that way
        assert!(parse("+1").is_err());
    }
}
