//! `/proc/<pid>` resource sampling for child benchmark processes.
//!
//! Pure std: reads `/proc/<pid>/status` for the peak resident set
//! (`VmHWM`, falling back to tracking the max of `VmRSS` when the
//! kernel omits it) and `/proc/<pid>/stat` for user+system CPU ticks.
//! On platforms without procfs every read fails quietly and the
//! sampled fields come back `None` — the run JSON renders them as
//! `null` rather than inventing numbers.

use std::path::PathBuf;

/// Final resource usage of one (possibly finished) child process.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct ProcUsage {
    /// Peak resident set size in KiB, if procfs was readable.
    pub rss_peak_kb: Option<u64>,
    /// Total CPU time (user + system, all threads) in milliseconds, if
    /// procfs was readable.
    pub cpu_ms: Option<u64>,
}

impl ProcUsage {
    /// Combine usage of two sequential children of the same logical run
    /// (e.g. a killed run and its `--resume`): RSS peaks take the max,
    /// CPU times add.
    pub fn merge(self, other: ProcUsage) -> ProcUsage {
        let max_opt = |a: Option<u64>, b: Option<u64>| match (a, b) {
            (Some(a), Some(b)) => Some(a.max(b)),
            (a, b) => a.or(b),
        };
        let add_opt = |a: Option<u64>, b: Option<u64>| match (a, b) {
            (Some(a), Some(b)) => Some(a + b),
            (a, b) => a.or(b),
        };
        ProcUsage {
            rss_peak_kb: max_opt(self.rss_peak_kb, other.rss_peak_kb),
            cpu_ms: add_opt(self.cpu_ms, other.cpu_ms),
        }
    }
}

/// Polls one PID's procfs entries while the driver's monitor loop spins
/// (every sample is a snapshot; [`ProcSampler::finish`] folds them into
/// a [`ProcUsage`]). The process disappearing between samples is normal
/// — the last successful sample stands.
#[derive(Debug)]
pub struct ProcSampler {
    status_path: PathBuf,
    stat_path: PathBuf,
    rss_peak_kb: Option<u64>,
    cpu_ticks: Option<u64>,
    tick_hz: u64,
}

impl ProcSampler {
    /// Sampler for `pid`. `USER_HZ` is effectively always 100 on Linux;
    /// override with the `FSFL_TICK_HZ` environment variable on exotic
    /// kernels.
    pub fn new(pid: u32) -> Self {
        let tick_hz = std::env::var("FSFL_TICK_HZ")
            .ok()
            .and_then(|v| v.parse().ok())
            .filter(|&hz| hz > 0)
            .unwrap_or(100);
        ProcSampler {
            status_path: PathBuf::from(format!("/proc/{pid}/status")),
            stat_path: PathBuf::from(format!("/proc/{pid}/stat")),
            rss_peak_kb: None,
            cpu_ticks: None,
            tick_hz,
        }
    }

    /// Take one snapshot (cheap enough for a ~10 ms poll loop).
    pub fn sample(&mut self) {
        if let Ok(status) = std::fs::read_to_string(&self.status_path) {
            // VmHWM is the kernel-tracked high-water mark; VmRSS is the
            // instantaneous value we max over as a fallback.
            let field = |name: &str| -> Option<u64> {
                status
                    .lines()
                    .find(|l| l.starts_with(name))
                    .and_then(|l| l.split_whitespace().nth(1))
                    .and_then(|v| v.parse().ok())
            };
            if let Some(kb) = field("VmHWM:").or_else(|| field("VmRSS:")) {
                self.rss_peak_kb = Some(self.rss_peak_kb.unwrap_or(0).max(kb));
            }
        }
        if let Ok(stat) = std::fs::read_to_string(&self.stat_path) {
            // Fields after the parenthesised comm (which may itself
            // contain spaces): split at the last ')'.
            if let Some(rest) = stat.rsplit_once(')').map(|(_, r)| r) {
                let fields: Vec<&str> = rest.split_whitespace().collect();
                // rest[0] is field 3 (state); utime/stime are fields
                // 14/15 of the full line ⇒ rest indices 11/12.
                if let (Some(ut), Some(st)) = (
                    fields.get(11).and_then(|v| v.parse::<u64>().ok()),
                    fields.get(12).and_then(|v| v.parse::<u64>().ok()),
                ) {
                    self.cpu_ticks = Some(ut + st);
                }
            }
        }
    }

    /// Fold the samples into the final usage record.
    pub fn finish(self) -> ProcUsage {
        ProcUsage {
            rss_peak_kb: self.rss_peak_kb,
            cpu_ms: self.cpu_ticks.map(|t| t * 1000 / self.tick_hz),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn samples_own_process_on_linux() {
        let mut s = ProcSampler::new(std::process::id());
        s.sample();
        let usage = s.finish();
        if cfg!(target_os = "linux") {
            assert!(
                usage.rss_peak_kb.unwrap_or(0) > 0,
                "a live Rust test process has a nonzero RSS"
            );
            assert!(usage.cpu_ms.is_some());
        }
    }

    #[test]
    fn missing_pid_yields_nulls_not_zeros() {
        // PID near the u32 ceiling: never a live procfs entry.
        let mut s = ProcSampler::new(u32::MAX - 1);
        s.sample();
        let usage = s.finish();
        assert_eq!(usage, ProcUsage::default());
    }

    #[test]
    fn merge_maxes_rss_and_adds_cpu() {
        let a = ProcUsage {
            rss_peak_kb: Some(100),
            cpu_ms: Some(40),
        };
        let b = ProcUsage {
            rss_peak_kb: Some(70),
            cpu_ms: Some(5),
        };
        let m = a.merge(b);
        assert_eq!(m.rss_peak_kb, Some(100));
        assert_eq!(m.cpu_ms, Some(45));
        assert_eq!(a.merge(ProcUsage::default()), a);
    }
}
