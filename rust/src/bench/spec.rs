//! Scenario specifications: the Suite A deterministic grid and the
//! seeded Suite B stochastic legs.
//!
//! Everything here is a *pure function of its inputs*: [`suite_a`] of
//! the smoke flag, [`suite_b`] of `(seed, smoke)`. The driver never
//! draws randomness of its own, so two `fsfl bench --suite b --seed N`
//! invocations run byte-identical scenario lists — arrival schedules,
//! payload mixes, straggler parameters and chaos scripts included.
//! That is the seed-reproducibility contract the integration tests pin
//! (identical per-run JSON apart from [`super::summary::TIMING_FIELDS`]).

use crate::data::XorShiftRng;
use crate::fl::TransportKind;

/// Which suite a scenario belongs to.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SuiteKind {
    /// Deterministic grid (fixed seed, full participation).
    A,
    /// Seeded stochastic legs (arrivals, mixes, stragglers, chaos).
    B,
    /// 100k-client scale cells (cold-state paging budget + tree
    /// fan-in); run explicitly via `--suite scale`, never part of
    /// `all`.
    Scale,
}

impl SuiteKind {
    /// Lowercase tag used in scenario ids and JSON.
    pub fn name(self) -> &'static str {
        match self {
            SuiteKind::A => "a",
            SuiteKind::B => "b",
            SuiteKind::Scale => "scale",
        }
    }
}

/// Synthetic model size for a scenario (`fsfl run --synth-model`).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ModelSize {
    /// [`crate::fl::synth::demo_manifest`] (~300 parameters).
    Small,
    /// [`crate::fl::synth::large_manifest`] (~100k parameters).
    Large,
}

impl ModelSize {
    /// The `--synth-model` flag value.
    pub fn name(self) -> &'static str {
        match self {
            ModelSize::Small => "small",
            ModelSize::Large => "large",
        }
    }
}

/// A chaos script the driver applies to the child process.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum ChaosLeg {
    /// SIGKILL the run after it has emitted `after_rounds` round lines,
    /// then `fsfl run --resume` it to completion (requires the scenario
    /// to checkpoint; the driver adds `--checkpoint-dir` itself).
    KillResume {
        /// Minimum completed rounds before the kill lands.
        after_rounds: usize,
    },
    /// Elastically resize the shard set mid-run via
    /// `--elastic-resize round:to_shards` (under `--shard-procs`, so
    /// the surplus workers are real OS processes admitted from the
    /// listener backlog).
    Resize {
        /// Round boundary the resize fires before.
        round: usize,
        /// New shard count.
        to_shards: usize,
    },
}

impl ChaosLeg {
    /// Compact label recorded in the run JSON (`"kill@1"`,
    /// `"resize@2:3"`).
    pub fn label(&self) -> String {
        match self {
            ChaosLeg::KillResume { after_rounds } => format!("kill@{after_rounds}"),
            ChaosLeg::Resize { round, to_shards } => format!("resize@{round}:{to_shards}"),
        }
    }
}

/// One benchmark scenario: everything needed to build the child
/// command line, plus the stochastic schedules Suite B derives from its
/// seed.
#[derive(Debug, Clone, PartialEq)]
pub struct Scenario {
    /// Unique id within the suite run (JSON `scenario` field and
    /// scratch-directory name).
    pub id: String,
    /// Owning suite.
    pub suite: SuiteKind,
    /// Shard transport under test.
    pub transport: TransportKind,
    /// Pipelined (true) vs staged (false) round schedule.
    pub pipelined: bool,
    /// Compute shard count.
    pub shards: usize,
    /// Synthetic model size.
    pub model: ModelSize,
    /// Protocol flag value (`fsfl`, `fedavg`, …).
    pub protocol: String,
    /// Client count.
    pub clients: usize,
    /// Round count.
    pub rounds: usize,
    /// Experiment seed (`--seed`).
    pub seed: u64,
    /// Participation fraction per round.
    pub participation: f64,
    /// Cold-state resident budget (`--resident-clients`; 0 = paging
    /// off, every client stays resident).
    pub resident_clients: usize,
    /// Leaf shards per mid-tier aggregator (`--tree-children`; 0 =
    /// flat fan-in).
    pub tree_children: usize,
    /// Run shards as separate OS processes (`--shard-procs`).
    pub shard_procs: bool,
    /// Non-empty ⇒ serve-mode scenario: the driver runs `fsfl serve`
    /// and launches one `fsfl shard-worker` per entry, each after its
    /// Poisson-derived delay (ms from the coordinator's listen line).
    /// Length always equals `shards`.
    pub arrivals_ms: Vec<u64>,
    /// Straggler injection `(every, ms)`: clients with
    /// `id % every == 0` sleep `ms` per train call
    /// (via [`crate::fl::synth::STRAGGLE_ENV`]).
    pub straggle: Option<(usize, u64)>,
    /// Chaos script, if any.
    pub chaos: Option<ChaosLeg>,
}

impl Scenario {
    /// A plain Suite A cell (no arrivals, stragglers or chaos).
    pub fn cell(
        transport: TransportKind,
        pipelined: bool,
        shards: usize,
        model: ModelSize,
        clients: usize,
        rounds: usize,
        seed: u64,
    ) -> Self {
        let schedule = if pipelined { "pipelined" } else { "staged" };
        Scenario {
            id: format!(
                "a-{}-{}-s{}-{}",
                transport.name(),
                schedule,
                shards,
                model.name()
            ),
            suite: SuiteKind::A,
            transport,
            pipelined,
            shards,
            model,
            protocol: "fsfl".into(),
            clients,
            rounds,
            seed,
            participation: 1.0,
            resident_clients: 0,
            tree_children: 0,
            // TCP cells exercise the real multi-process deployment.
            shard_procs: transport == TransportKind::Tcp,
            arrivals_ms: Vec::new(),
            straggle: None,
            chaos: None,
        }
    }

    /// Schedule tag for JSON output.
    pub fn schedule_name(&self) -> &'static str {
        if self.pipelined {
            "pipelined"
        } else {
            "staged"
        }
    }
}

/// Fixed Suite A seed: the grid is deterministic by construction, so
/// it never takes a `--seed`.
pub const SUITE_A_SEED: u64 = 42;

const TRANSPORTS: [TransportKind; 3] = [
    TransportKind::Mpsc,
    TransportKind::Loopback,
    TransportKind::Tcp,
];

/// The Suite A deterministic grid.
///
/// Full: transport × {staged, pipelined} × shards 1–4 × {small, large}
/// (48 cells, 8 rounds each). Smoke: transport × staged × shards
/// {1, 2} × small (6 cells, 2 rounds) — the per-PR CI gate.
pub fn suite_a(smoke: bool) -> Vec<Scenario> {
    let mut out = Vec::new();
    let (schedules, shard_counts, models, clients, rounds): (
        &[bool],
        &[usize],
        &[ModelSize],
        usize,
        usize,
    ) = if smoke {
        (&[false], &[1, 2], &[ModelSize::Small], 4, 2)
    } else {
        (
            &[false, true],
            &[1, 2, 3, 4],
            &[ModelSize::Small, ModelSize::Large],
            8,
            8,
        )
    };
    for &transport in &TRANSPORTS {
        for &pipelined in schedules {
            for &shards in shard_counts {
                for &model in models {
                    out.push(Scenario::cell(
                        transport,
                        pipelined,
                        shards,
                        model,
                        clients,
                        rounds,
                        SUITE_A_SEED,
                    ));
                }
            }
        }
    }
    out
}

/// Cumulative Poisson arrival schedule: `n` arrival offsets in
/// milliseconds, with exponential inter-arrival times at rate
/// `lambda_per_sec` (inverse-CDF sampling off the scenario RNG).
pub fn poisson_arrivals(rng: &mut XorShiftRng, n: usize, lambda_per_sec: f64) -> Vec<u64> {
    let mut t_ms = 0.0f64;
    (0..n)
        .map(|_| {
            let u = rng.next_f32() as f64; // [0, 1)
            let dt_secs = -(1.0 - u).ln() / lambda_per_sec;
            t_ms += dt_secs * 1e3;
            t_ms as u64
        })
        .collect()
}

fn pick<T: Copy>(rng: &mut XorShiftRng, options: &[T]) -> T {
    options[rng.below(options.len())]
}

fn range(rng: &mut XorShiftRng, lo: u64, hi: u64) -> u64 {
    lo + rng.below((hi - lo + 1) as usize) as u64
}

/// The Suite B stochastic legs, derived entirely from `seed`.
///
/// * **arrivals** — `fsfl serve` over TCP with shard workers launched
///   at seeded Poisson offsets (elastic-admission latency under churny
///   joins).
/// * **mix** — heterogeneous payloads: random protocol × model size ×
///   client count × participation × transport × schedule.
/// * **straggle** — straggler injection on the multi-process TCP path.
/// * **kill** — SIGKILL mid-run + `--resume` (checkpointed, in-process
///   loopback so the SIGKILL takes the whole deployment down).
/// * **resize** — mid-run elastic shard resize under straggler load,
///   with real worker processes admitted from the listener backlog.
///
/// Smoke runs one scenario per leg with small rounds; full runs widen
/// the mix/straggle/arrival pools.
pub fn suite_b(seed: u64, smoke: bool) -> Vec<Scenario> {
    let mut rng = XorShiftRng::new(seed ^ 0xB0B5_CE9A_71ED_5EED);
    let mut out = Vec::new();
    let rounds = if smoke { 2 } else { 6 };
    let chaos_rounds = if smoke { 3 } else { 6 };

    // Leg 1: Poisson arrivals against `fsfl serve`.
    for i in 0..if smoke { 1 } else { 3 } {
        let shards = range(&mut rng, 2, 3) as usize;
        let lambda = range(&mut rng, 4, 12) as f64; // workers/sec
        let arrivals_ms = poisson_arrivals(&mut rng, shards, lambda);
        out.push(Scenario {
            id: format!("b-arrival-{i}"),
            suite: SuiteKind::B,
            transport: TransportKind::Tcp,
            pipelined: false,
            shards,
            model: ModelSize::Small,
            protocol: "fsfl".into(),
            clients: range(&mut rng, 4, 8) as usize,
            rounds,
            seed: rng.next_u64(),
            participation: 1.0,
            resident_clients: 0,
            tree_children: 0,
            shard_procs: false, // workers are the driver's children
            arrivals_ms,
            straggle: None,
            chaos: None,
        });
    }

    // Leg 2: heterogeneous payload mixes.
    for i in 0..if smoke { 2 } else { 6 } {
        let transport = pick(&mut rng, &TRANSPORTS);
        out.push(Scenario {
            id: format!("b-mix-{i}"),
            suite: SuiteKind::B,
            transport,
            pipelined: rng.below(2) == 1,
            shards: range(&mut rng, 1, 3) as usize,
            model: pick(&mut rng, &[ModelSize::Small, ModelSize::Large]),
            protocol: pick(&mut rng, &["fsfl", "fedavg", "stc"]).to_string(),
            clients: range(&mut rng, 3, 8) as usize,
            rounds,
            seed: rng.next_u64(),
            participation: pick(&mut rng, &[0.5, 0.75, 1.0]),
            resident_clients: 0,
            tree_children: 0,
            shard_procs: transport == TransportKind::Tcp,
            arrivals_ms: Vec::new(),
            straggle: None,
            chaos: None,
        });
    }

    // Leg 3: straggler injection on the multi-process path.
    for i in 0..if smoke { 1 } else { 3 } {
        out.push(Scenario {
            id: format!("b-straggle-{i}"),
            suite: SuiteKind::B,
            transport: TransportKind::Tcp,
            pipelined: false,
            shards: range(&mut rng, 2, 3) as usize,
            model: ModelSize::Small,
            protocol: "fsfl".into(),
            clients: range(&mut rng, 4, 8) as usize,
            rounds,
            seed: rng.next_u64(),
            participation: 1.0,
            resident_clients: 0,
            tree_children: 0,
            shard_procs: true,
            arrivals_ms: Vec::new(),
            straggle: Some((range(&mut rng, 2, 4) as usize, range(&mut rng, 10, 40))),
            chaos: None,
        });
    }

    // Leg 4: SIGKILL + --resume. In-process loopback: killing the
    // coordinator PID takes the whole deployment down at once, which is
    // the crash the durable-session plane promises to absorb.
    for i in 0..if smoke { 1 } else { 2 } {
        out.push(Scenario {
            id: format!("b-kill-{i}"),
            suite: SuiteKind::B,
            transport: TransportKind::Loopback,
            pipelined: false,
            shards: 2,
            model: ModelSize::Small,
            protocol: "fsfl".into(),
            clients: range(&mut rng, 4, 6) as usize,
            rounds: chaos_rounds,
            seed: rng.next_u64(),
            participation: 1.0,
            resident_clients: 0,
            tree_children: 0,
            shard_procs: false,
            arrivals_ms: Vec::new(),
            straggle: None,
            chaos: Some(ChaosLeg::KillResume {
                after_rounds: range(&mut rng, 1, chaos_rounds as u64 - 1) as usize,
            }),
        });
    }

    // Leg 5: elastic resize mid-run under straggler load, real worker
    // processes (the surplus waits in the listener backlog until its
    // boundary admits it).
    {
        let round = range(&mut rng, 1, chaos_rounds as u64 - 1) as usize;
        out.push(Scenario {
            id: "b-resize-0".into(),
            suite: SuiteKind::B,
            transport: TransportKind::Tcp,
            pipelined: false,
            shards: 2,
            model: ModelSize::Small,
            protocol: "fsfl".into(),
            clients: range(&mut rng, 4, 6) as usize,
            rounds: chaos_rounds,
            seed: rng.next_u64(),
            participation: 1.0,
            resident_clients: 0,
            tree_children: 0,
            shard_procs: true,
            arrivals_ms: Vec::new(),
            straggle: Some((2, range(&mut rng, 5, 20))),
            chaos: Some(ChaosLeg::Resize {
                round,
                to_shards: 3,
            }),
        });
    }

    out
}

/// The scale suite: 100k-client synthetic cells demonstrating that the
/// coordinator survives the "millions of users" shape on one machine.
/// Two cells, both with a cold-state resident budget
/// (`--resident-clients`) far below the client count:
///
/// * **flat** — mpsc, flat fan-in (the baseline shape).
/// * **tree** — loopback with `--tree-children`, so lanes reduce
///   through mid-tier aggregators before reaching the coordinator.
///
/// Deterministic like Suite A (fixed seed, no chaos); the headline
/// metrics are `clients_per_sec` and `rss_peak_kb` (the CI `scale` job
/// asserts a ceiling on the latter). Deliberately **not** part of
/// `--suite all`: at 100k clients a cell is orders of magnitude bigger
/// than a smoke grid and runs in its own CI job.
pub fn suite_scale(smoke: bool) -> Vec<Scenario> {
    // Low participation is the realistic cross-device regime (and what
    // makes paging matter: the cohort is tiny vs the population).
    let (rounds, participation) = if smoke { (2, 0.005) } else { (4, 0.01) };
    let make = |id: &str, transport, tree_children| {
        let mut s = Scenario::cell(
            transport,
            false,
            2,
            ModelSize::Small,
            100_000,
            rounds,
            SUITE_A_SEED,
        );
        s.id = id.into();
        s.suite = SuiteKind::Scale;
        s.participation = participation;
        s.resident_clients = 512;
        s.tree_children = tree_children;
        s
    };
    vec![
        make("scale-100k-flat", TransportKind::Mpsc, 0),
        make("scale-100k-tree", TransportKind::Loopback, 2),
    ]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn suite_a_smoke_and_full_grid_shapes() {
        let smoke = suite_a(true);
        assert_eq!(smoke.len(), 3 * 1 * 2 * 1);
        assert!(smoke.iter().all(|s| s.rounds == 2 && s.chaos.is_none()));
        let full = suite_a(false);
        assert_eq!(full.len(), 3 * 2 * 4 * 2);
        // ids unique
        let mut ids: Vec<&str> = full.iter().map(|s| s.id.as_str()).collect();
        ids.sort();
        ids.dedup();
        assert_eq!(ids.len(), full.len());
        // tcp cells run as real processes
        assert!(full
            .iter()
            .all(|s| s.shard_procs == (s.transport == TransportKind::Tcp)));
    }

    #[test]
    fn suite_b_is_a_pure_function_of_the_seed() {
        assert_eq!(suite_b(7, true), suite_b(7, true));
        assert_eq!(suite_b(7, false), suite_b(7, false));
        assert_ne!(suite_b(7, true), suite_b(8, true));
    }

    #[test]
    fn suite_b_covers_every_leg_with_consistent_shapes() {
        for smoke in [true, false] {
            let b = suite_b(123, smoke);
            assert!(b.iter().any(|s| !s.arrivals_ms.is_empty()));
            assert!(b.iter().any(|s| s.straggle.is_some()));
            assert!(b
                .iter()
                .any(|s| matches!(s.chaos, Some(ChaosLeg::KillResume { .. }))));
            assert!(b
                .iter()
                .any(|s| matches!(s.chaos, Some(ChaosLeg::Resize { .. }))));
            for s in &b {
                if !s.arrivals_ms.is_empty() {
                    assert_eq!(s.arrivals_ms.len(), s.shards, "{}", s.id);
                    assert!(!s.shard_procs, "{}: driver launches the workers", s.id);
                }
                if let Some(ChaosLeg::KillResume { after_rounds }) = &s.chaos {
                    assert!(*after_rounds < s.rounds, "{}", s.id);
                }
                if let Some(ChaosLeg::Resize { round, to_shards }) = &s.chaos {
                    assert!(*round >= 1 && *round < s.rounds, "{}", s.id);
                    assert!(s.shard_procs && *to_shards != s.shards, "{}", s.id);
                }
            }
        }
    }

    #[test]
    fn scale_suite_pins_the_100k_shape() {
        for smoke in [true, false] {
            let cells = suite_scale(smoke);
            assert_eq!(cells.len(), 2);
            for s in &cells {
                assert_eq!(s.suite, SuiteKind::Scale, "{}", s.id);
                assert_eq!(s.clients, 100_000, "{}", s.id);
                assert!(
                    s.resident_clients > 0 && s.resident_clients < s.clients,
                    "{}: the budget must actually bound residency",
                    s.id
                );
                assert!(s.chaos.is_none() && s.arrivals_ms.is_empty());
            }
            // one flat baseline, one tree fan-in cell
            assert!(cells.iter().any(|s| s.tree_children == 0));
            assert!(cells.iter().any(|s| s.tree_children > 0));
        }
        // deterministic: same flag, same cells
        assert_eq!(suite_scale(true), suite_scale(true));
    }

    #[test]
    fn poisson_schedule_is_monotone_and_seed_stable() {
        let mut a = XorShiftRng::new(9);
        let mut b = XorShiftRng::new(9);
        let s1 = poisson_arrivals(&mut a, 8, 10.0);
        let s2 = poisson_arrivals(&mut b, 8, 10.0);
        assert_eq!(s1, s2);
        assert!(s1.windows(2).all(|w| w[0] <= w[1]));
    }
}
