//! Cross-scenario benchmark harness (`fsfl bench`).
//!
//! The repo's other test planes pin *correctness* (byte-identical
//! bitstreams across every deployment shape); this module pins the
//! *performance trajectory*. It drives the **release binary** — not
//! in-process functions — through two suites of scenarios and records
//! one JSON line per run, then merges the lines into percentile-focused
//! summaries committed as `BENCH_scenarios.json` (and, for the codec
//! micro-bench, `BENCH_fl_round.json` via `benches/fl_round.rs`, which
//! shares the same schema header).
//!
//! * **Suite A — deterministic grid** ([`spec::suite_a`]): transport
//!   (mpsc × loopback × tcp) × schedule (staged / pipelined) × shard
//!   count (1–4) × synthetic model size (small / large), fixed seed.
//!   Every cell is an ordinary `fsfl run --synth` invocation.
//! * **Suite B — stochastic legs** ([`spec::suite_b`]): seeded Poisson
//!   client (shard-worker) arrivals against `fsfl serve`, heterogeneous
//!   payload mixes, straggler injection
//!   ([`crate::fl::synth::STRAGGLE_ENV`]), and chaos runs that SIGKILL
//!   the child mid-run and `--resume` it, or elastically resize the
//!   shard set mid-suite. Suite B is wall-clock stochastic but
//!   **reproducible by seed**: the same `--seed` derives the same
//!   scenario list, arrival schedules and straggler parameters, so two
//!   runs differ only in the timing fields
//!   ([`summary::TIMING_FIELDS`]).
//! * **Scale suite** ([`spec::suite_scale`], `--suite scale`): the
//!   100k-client cells (flat fan-in vs `--tree-children`, both under a
//!   `--resident-clients` budget) recording `clients_per_sec` and peak
//!   RSS. Run explicitly — never part of `--suite all` — by the CI
//!   `scale` job, which asserts an RSS ceiling on the result.
//!
//! The measurement channel is a line protocol on the child's stdout:
//! every machine-readable line starts with [`METRIC_PREFIX`] (emitted
//! by `fsfl run/serve --emit-metrics`), and the driver
//! ([`driver`]) parses round latencies, `RunLog::wire` byte counts and
//! the supervisor-incident history from it while sampling RSS/CPU from
//! `/proc/<pid>` ([`sampler`]). Rust's stdout handle is line-buffered
//! even through a pipe, so round lines arrive live — which is what lets
//! the chaos leg SIGKILL a child *after* it has provably finished k
//! rounds.
//!
//! Schemas (validated by [`summary::validate_run_line`] /
//! [`summary::validate_summary`], parsed by the dependency-free
//! [`json`] reader) are versioned via [`SCHEMA_VERSION`]; CI diffs the
//! produced summary's key structure against the committed `BENCH_*`
//! files so drift fails the bench gate instead of silently rewriting
//! the trajectory.

pub mod driver;
pub mod json;
pub mod sampler;
pub mod spec;
pub mod summary;

/// Prefix of every machine-readable metric line a child emits on stdout
/// under `--emit-metrics`. Lines look like
/// `#fsfl-metric round r=3 wall_ms=12.5 up=1024 down=512 participants=3`
/// — a kind token followed by `key=value` pairs, no spaces inside
/// values. Everything not starting with this prefix is human-readable
/// progress output and ignored by the driver.
pub const METRIC_PREFIX: &str = "#fsfl-metric ";

/// `schema` tag of one per-run JSON line (`bench_runs.jsonl`).
pub const RUN_SCHEMA: &str = "fsfl-bench-run";

/// `schema` tag of a merged summary file (`BENCH_*.json`).
pub const SUMMARY_SCHEMA: &str = "fsfl-bench-summary";

/// Version of both the run-line and summary schemas. Bump on any
/// structural change and re-bless the committed `BENCH_*.json` files.
/// v2: `resident_clients`/`tree_children` scenario fields,
/// `participants`/`clients_per_sec` throughput metrics, and the
/// `suite_scale` summary section.
pub const SCHEMA_VERSION: u64 = 2;

// ---------------------------------------------------------------------------
// Metric-line formatters
//
// The emitting side of the stdout protocol. `fsfl` (main.rs) prints
// these under --emit-metrics; `driver::parse_into` reads them back.
// Keeping both sides in this crate means one unit test can pin the
// vocabulary end to end.
// ---------------------------------------------------------------------------

/// `listening` line: the bound socket a `fsfl serve` child accepts
/// shard-worker joins on. Must be flushed before serving so the driver
/// can launch workers against it.
pub fn line_listening(addr: &str) -> String {
    format!("{METRIC_PREFIX}listening addr={addr}")
}

/// `run` banner: experiment shape, emitted once before round 0.
/// `params` is the synthetic manifest's parameter count (`None` for
/// real PJRT runs, rendered `-`); whitespace in the name is flattened
/// so the line stays token-splittable.
pub fn line_run(name: &str, rounds: usize, clients: usize, params: Option<usize>) -> String {
    let name: String = name
        .chars()
        .map(|c| if c.is_whitespace() { '_' } else { c })
        .collect();
    let p = params.map(|p| p.to_string()).unwrap_or_else(|| "-".into());
    format!("{METRIC_PREFIX}run name={name} rounds={rounds} clients={clients} params={p}")
}

/// Live per-round line, printed from the round-event callback the
/// moment the round completes. `wall_ms` is the caller's wall clock
/// since the previous round line (scheduling overhead included — this
/// is the latency an operator would observe, not just compute time).
pub fn line_round(m: &crate::metrics::RoundMetrics, wall_ms: f64) -> String {
    format!(
        "{METRIC_PREFIX}round r={} wall_ms={:.3} up={} down={} participants={}",
        m.round,
        wall_ms,
        m.up_bytes,
        m.down_bytes,
        m.client_sparsity.len()
    )
}

/// End-of-run lines: totals (always), measured wire bytes (wire
/// transports only) and the compact supervisor-incident history.
pub fn lines_finish(log: &crate::metrics::RunLog) -> Vec<String> {
    let mut out = vec![format!(
        "{METRIC_PREFIX}totals rounds={} up={} down={} best_acc={:.6}",
        log.rounds.len(),
        log.total_bytes(true),
        log.rounds.iter().map(|r| r.down_bytes).sum::<usize>(),
        log.best_accuracy()
    )];
    if let Some(w) = log.wire {
        out.push(format!(
            "{METRIC_PREFIX}wire sent={} recv={}",
            w.sent(),
            w.received()
        ));
    }
    out.push(format!(
        "{METRIC_PREFIX}events n={} seq={}",
        log.events.len(),
        log.events_compact()
    ));
    out
}

/// `registry` line: the live metrics-registry totals, emitted after the
/// `totals`/`wire` lines whenever a telemetry handle was attached to
/// the run. The registry accumulates through an independent path
/// (atomic counters bumped as rounds seal and frames cross the wire)
/// from the `RunLog` the other lines are derived from, so the driver
/// cross-checks the two and fails the run if they disagree.
pub fn line_registry(reg: &crate::obs::MetricsRegistry) -> String {
    use std::sync::atomic::Ordering;
    let w = reg.wire_snapshot();
    format!(
        "{METRIC_PREFIX}registry rounds={} up={} down={} wire_sent={} wire_recv={}",
        reg.rounds_total.load(Ordering::Relaxed),
        reg.up_bytes_total.load(Ordering::Relaxed),
        reg.down_bytes_total.load(Ordering::Relaxed),
        w.sent(),
        w.received(),
    )
}
