//! The process driver: runs one [`Scenario`] against the release
//! binary, parses its [`super::METRIC_PREFIX`] stdout lines, samples
//! `/proc/<pid>`, and folds everything into a [`RunRecord`] /
//! JSON-lines output plus the merged [`summarize`] report.
//!
//! Chaos handling lives here too: the `KillResume` leg SIGKILLs the
//! child only after the required number of *live* round lines arrived
//! (so the kill provably lands mid-run, past a checkpoint), then runs
//! `fsfl run --resume` on the same session directory; the arrival leg
//! runs `fsfl serve` and launches `fsfl shard-worker` children at the
//! scenario's seeded Poisson offsets.

use std::collections::BTreeMap;
use std::io::{BufRead, BufReader, Write};
use std::path::{Path, PathBuf};
use std::process::{Child, Command, Stdio};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Duration;

use anyhow::{anyhow, Result};

use crate::benchkit::Report;
use crate::fl::synth::STRAGGLE_ENV;
use crate::supervise::{Clock, MonotonicClock};

use super::sampler::{ProcSampler, ProcUsage};
use super::spec::{ChaosLeg, Scenario, SuiteKind};
use super::summary::{self, Hist};
use super::{METRIC_PREFIX, RUN_SCHEMA, SCHEMA_VERSION};

/// Hard per-child wall-clock ceiling: a hung scenario is killed and
/// recorded as failed instead of wedging the whole suite.
pub const CHILD_TIMEOUT: Duration = Duration::from_secs(300);

/// Everything the driver needs to run scenarios: the `fsfl` binary to
/// drive, a scratch directory for per-scenario run dirs (kept on
/// failure for post-mortem, removed on success), and the time source
/// every driver-side measurement reads (a [`MonotonicClock`] in
/// production; fakeable like the supervision plane's).
#[derive(Clone)]
pub struct BenchCtx {
    /// Path to the release `fsfl` binary.
    pub exe: PathBuf,
    /// Scratch root for per-scenario output/checkpoint dirs.
    pub scratch: PathBuf,
    /// Driver time source: child timeouts, worker arrival offsets and
    /// scenario wall clocks all read this instead of raw `Instant`.
    pub clock: Arc<dyn Clock>,
}

/// Result of one scenario run — the source of one JSON line.
#[derive(Debug, Clone)]
pub struct RunRecord {
    /// The scenario that produced this record.
    pub scenario: Scenario,
    /// Whether the run completed every round and the child(ren) exited
    /// cleanly.
    pub ok: bool,
    /// Failure description when `!ok`.
    pub error: Option<String>,
    /// Driver-side wall clock for the whole scenario, ms (spawn to
    /// final exit, resume included).
    pub wall_ms: f64,
    /// Live per-round wall-clock latencies, ms, in round order.
    pub round_ms: Vec<f64>,
    /// Total upstream payload bytes over the run (codec accounting).
    pub up_bytes: u64,
    /// Total downstream payload bytes over the run.
    pub down_bytes: u64,
    /// Measured frame-layer bytes coordinator→shards (wire transports
    /// only).
    pub wire_sent: Option<u64>,
    /// Measured frame-layer bytes shards→coordinator.
    pub wire_recv: Option<u64>,
    /// Synthetic model parameter count (for the dense-f32 baseline).
    pub params: Option<u64>,
    /// Dense-f32 upstream baseline: Σ rounds participants × params × 4
    /// (extrapolated over rounds whose live line a SIGKILL swallowed).
    pub dense_bytes: u64,
    /// Total client-rounds processed (Σ per-round participants,
    /// extrapolated over rounds whose live line a SIGKILL swallowed,
    /// like `dense_bytes`).
    pub participants: u64,
    /// Peak RSS of the child(ren), KiB.
    pub rss_peak_kb: Option<u64>,
    /// Total child CPU time, ms.
    pub cpu_ms: Option<u64>,
    /// Compact supervisor-incident history
    /// ([`crate::metrics::RunLog::events_compact`]).
    pub events: String,
    /// Whether a `--resume` leg ran.
    pub resumed: bool,
    /// Rounds the final log contained.
    pub rounds_done: usize,
}

impl RunRecord {
    fn skeleton(scenario: Scenario) -> Self {
        RunRecord {
            scenario,
            ok: false,
            error: None,
            wall_ms: 0.0,
            round_ms: Vec::new(),
            up_bytes: 0,
            down_bytes: 0,
            wire_sent: None,
            wire_recv: None,
            params: None,
            dense_bytes: 0,
            participants: 0,
            rss_peak_kb: None,
            cpu_ms: None,
            events: "-".into(),
            resumed: false,
            rounds_done: 0,
        }
    }

    /// Completed rounds per second of driver wall clock.
    pub fn rounds_per_sec(&self) -> f64 {
        if self.wall_ms > 0.0 {
            self.rounds_done as f64 * 1e3 / self.wall_ms
        } else {
            0.0
        }
    }

    /// Client-rounds processed per second of driver wall clock — the
    /// scale suite's headline number (how fast a deployment chews
    /// through its cohort), meaningful for every suite.
    pub fn clients_per_sec(&self) -> f64 {
        if self.wall_ms > 0.0 {
            self.participants as f64 * 1e3 / self.wall_ms
        } else {
            0.0
        }
    }

    /// Upstream compression ratio vs the dense-f32 baseline.
    pub fn compression_x(&self) -> Option<f64> {
        if self.dense_bytes > 0 && self.up_bytes > 0 {
            Some(self.dense_bytes as f64 / self.up_bytes as f64)
        } else {
            None
        }
    }

    fn round_hist(&self) -> Hist {
        let mut h = Hist::new();
        for &ms in &self.round_ms {
            h.push(ms);
        }
        h
    }

    /// Render this record as one JSON line of the
    /// [`super::RUN_SCHEMA`] schema (the exact field set
    /// [`summary::RUN_FIELDS`] pins).
    pub fn to_json_line(&self) -> String {
        fn opt_int(r: &mut Report, key: &str, v: Option<u64>) {
            match v {
                Some(v) => {
                    r.int(key, v);
                }
                None => {
                    r.null(key);
                }
            }
        }
        fn opt_num(r: &mut Report, key: &str, v: Option<f64>) {
            match v {
                Some(v) => {
                    r.num(key, v);
                }
                None => {
                    r.null(key);
                }
            }
        }
        fn opt_str(r: &mut Report, key: &str, v: Option<&str>) {
            match v {
                Some(v) => {
                    r.str(key, v);
                }
                None => {
                    r.null(key);
                }
            }
        }
        let s = &self.scenario;
        let h = self.round_hist();
        let mut r = Report::new();
        r.str("schema", RUN_SCHEMA)
            .int("v", SCHEMA_VERSION)
            .str("suite", s.suite.name())
            .str("scenario", &s.id)
            .str("transport", s.transport.name())
            .str("schedule", s.schedule_name())
            .int("shards", s.shards as u64)
            .str("model", s.model.name())
            .str("protocol", &s.protocol)
            .int("clients", s.clients as u64)
            .int("rounds", s.rounds as u64)
            .int("seed", s.seed)
            .num("participation", s.participation)
            .int("resident_clients", s.resident_clients as u64)
            .int("tree_children", s.tree_children as u64)
            .bool("shard_procs", s.shard_procs)
            .bool("ok", self.ok);
        opt_str(&mut r, "error", self.error.as_deref());
        r.bool("resumed", self.resumed)
            .int("rounds_done", self.rounds_done as u64)
            .num("wall_ms", self.wall_ms)
            .num("rounds_per_sec", self.rounds_per_sec())
            .int("participants", self.participants)
            .num("clients_per_sec", self.clients_per_sec())
            .nums("round_ms", &self.round_ms);
        opt_num(&mut r, "round_ms_p50", h.percentile(50.0));
        opt_num(&mut r, "round_ms_p95", h.percentile(95.0));
        opt_num(&mut r, "round_ms_p99", h.percentile(99.0));
        r.int("up_bytes", self.up_bytes)
            .int("down_bytes", self.down_bytes);
        opt_int(&mut r, "wire_sent", self.wire_sent);
        opt_int(&mut r, "wire_recv", self.wire_recv);
        opt_int(&mut r, "params", self.params);
        r.int("dense_bytes", self.dense_bytes);
        opt_num(&mut r, "compression_x", self.compression_x());
        opt_int(&mut r, "rss_peak_kb", self.rss_peak_kb);
        opt_int(&mut r, "cpu_ms", self.cpu_ms);
        let arrivals: Vec<f64> = s.arrivals_ms.iter().map(|&ms| ms as f64).collect();
        r.nums("arrivals_ms", &arrivals);
        opt_str(
            &mut r,
            "straggle",
            s.straggle.map(|(e, ms)| format!("{e}:{ms}")).as_deref(),
        );
        opt_str(&mut r, "chaos", s.chaos.as_ref().map(ChaosLeg::label).as_deref());
        r.str("events", &self.events);
        r.render()
    }

    /// One-line human outcome for the progress log.
    pub fn outcome_line(&self) -> String {
        match &self.error {
            Some(e) => format!("FAILED: {e}"),
            None => format!(
                "ok: {:.2} rounds/s, up {} B, wire {}, compression {}, events {}",
                self.rounds_per_sec(),
                self.up_bytes,
                match (self.wire_sent, self.wire_recv) {
                    (Some(s), Some(r)) => format!("{} B", s + r),
                    _ => "-".into(),
                },
                self.compression_x()
                    .map(|x| format!("{x:.1}x"))
                    .unwrap_or_else(|| "-".into()),
                self.events
            ),
        }
    }
}

// ---------------------------------------------------------------------------
// Metric-line parsing
// ---------------------------------------------------------------------------

#[derive(Debug, Clone, Copy)]
struct RoundObs {
    wall_ms: f64,
    up: u64,
    down: u64,
    participants: u64,
}

#[derive(Debug, Default)]
struct Parsed {
    rounds: BTreeMap<usize, RoundObs>,
    totals: Option<(usize, u64, u64)>,
    wire: Option<(u64, u64)>,
    params: Option<u64>,
    events: Option<String>,
    /// `registry` line: (rounds, up, down, wire_sent, wire_recv) as the
    /// child's live metrics registry counted them — an accounting path
    /// independent of the `totals`/`wire` lines, cross-checked by
    /// [`run_scenario`].
    registry: Option<(u64, u64, u64, u64, u64)>,
}

/// Parse every [`METRIC_PREFIX`] line in `lines` into `parsed`.
/// `lenient` tolerates malformed metric lines (a SIGKILL can land
/// mid-write, truncating the child's final line); strict mode treats
/// them as protocol errors.
fn parse_into(parsed: &mut Parsed, lines: &[String], lenient: bool) -> Result<()> {
    for line in lines {
        let Some(rest) = line.strip_prefix(METRIC_PREFIX) else {
            continue;
        };
        let mut toks = rest.split_whitespace();
        let kind = toks.next().unwrap_or("");
        let kvs: Vec<(&str, &str)> = toks.filter_map(|t| t.split_once('=')).collect();
        let get = |k: &str| kvs.iter().find(|(key, _)| *key == k).map(|&(_, v)| v);
        let res: Result<()> = (|| {
            let want = |k: &str| get(k).ok_or_else(|| anyhow!("metric line missing {k}: {line}"));
            match kind {
                "round" => {
                    let r: usize = want("r")?.parse()?;
                    parsed.rounds.insert(
                        r,
                        RoundObs {
                            wall_ms: want("wall_ms")?.parse()?,
                            up: want("up")?.parse()?,
                            down: want("down")?.parse()?,
                            participants: want("participants")?.parse()?,
                        },
                    );
                }
                "totals" => {
                    parsed.totals = Some((
                        want("rounds")?.parse()?,
                        want("up")?.parse()?,
                        want("down")?.parse()?,
                    ));
                }
                "wire" => {
                    parsed.wire = Some((want("sent")?.parse()?, want("recv")?.parse()?));
                }
                "registry" => {
                    parsed.registry = Some((
                        want("rounds")?.parse()?,
                        want("up")?.parse()?,
                        want("down")?.parse()?,
                        want("wire_sent")?.parse()?,
                        want("wire_recv")?.parse()?,
                    ));
                }
                "run" => {
                    if let Some(p) = get("params").filter(|v| *v != "-") {
                        parsed.params = Some(p.parse()?);
                    }
                }
                "events" => {
                    parsed.events = Some(want("seq")?.to_string());
                }
                "listening" => {}
                other => return Err(anyhow!("unknown metric line kind {other:?}: {line}")),
            }
            Ok(())
        })();
        if let Err(e) = res {
            if !lenient {
                return Err(e);
            }
        }
    }
    Ok(())
}

// ---------------------------------------------------------------------------
// Child process supervision
// ---------------------------------------------------------------------------

/// What the monitor loop does beyond waiting for exit.
enum Watch<'a> {
    /// Just wait.
    Plain,
    /// SIGKILL the child once it has emitted this many live round
    /// lines.
    KillAfterRounds(usize),
    /// Watch for the `listening addr=` line, then launch one
    /// `shard-worker` child per delay entry (ms after the listen line).
    Workers {
        exe: &'a Path,
        delays_ms: &'a [u64],
    },
}

struct ChildOut {
    lines: Vec<String>,
    success: bool,
    killed: bool,
    usage: ProcUsage,
}

fn spawn_worker(exe: &Path, addr: &str) -> Result<Child> {
    Command::new(exe)
        .args(["shard-worker", "--connect", addr])
        .stdin(Stdio::null())
        .stdout(Stdio::null())
        .spawn()
        .map_err(|e| anyhow!("spawning shard-worker: {e}"))
}

/// Spawn `cmd`, pump its stdout through a reader thread, poll
/// `/proc/<pid>` while executing the watch plan, and reap everything.
/// All waits and deadlines read `clock`, never raw `Instant`.
fn drive_child(
    mut cmd: Command,
    watch: Watch<'_>,
    timeout: Duration,
    clock: &dyn Clock,
) -> Result<ChildOut> {
    let program = format!("{:?}", cmd.get_program());
    cmd.stdin(Stdio::null()).stdout(Stdio::piped());
    let mut child = cmd
        .spawn()
        .map_err(|e| anyhow!("spawning {program}: {e}"))?;
    let stdout = child.stdout.take().expect("stdout was piped");
    let lines = Arc::new(Mutex::new(Vec::<String>::new()));
    let round_lines = Arc::new(AtomicUsize::new(0));
    let reader = {
        let lines = lines.clone();
        let round_lines = round_lines.clone();
        std::thread::spawn(move || {
            for line in BufReader::new(stdout).lines() {
                let Ok(line) = line else { break };
                if line
                    .strip_prefix(METRIC_PREFIX)
                    .is_some_and(|r| r.starts_with("round "))
                {
                    round_lines.fetch_add(1, Ordering::SeqCst);
                }
                lines.lock().unwrap().push(line);
            }
        })
    };
    let mut sampler = ProcSampler::new(child.id());
    let mut workers: Vec<Child> = Vec::new();
    let mut next_worker = 0usize;
    let mut listen: Option<(Duration, String)> = None;
    let mut killed = false;
    let t0 = clock.now();
    let reap_workers = |workers: &mut Vec<Child>| {
        for w in workers.iter_mut() {
            if matches!(w.try_wait(), Ok(None)) {
                let _ = w.kill();
            }
            let _ = w.wait();
        }
    };
    let status = loop {
        // Sample *before* try_wait: try_wait reaps an exited child,
        // destroying its /proc entry. A child that died between polls
        // is a zombie here — its `stat` still carries the final CPU
        // ticks (the `status` Vm* lines are already gone, so RSS must
        // have been caught while it was live) — which is exactly the
        // "one final sample before reaping" the short-lived smoke and
        // chaos children need to not report stale or null usage.
        sampler.sample();
        if let Some(status) = child.try_wait()? {
            break status;
        }
        if clock.now().saturating_sub(t0) > timeout {
            // Final snapshot while the process is still live: after
            // the kill it only ever degrades to a zombie (no Vm*).
            sampler.sample();
            let _ = child.kill();
            let _ = child.wait();
            reap_workers(&mut workers);
            let _ = reader.join();
            return Err(anyhow!("child timed out after {timeout:?}"));
        }
        match &watch {
            Watch::Plain => {}
            Watch::KillAfterRounds(k) => {
                if !killed && round_lines.load(Ordering::SeqCst) >= *k {
                    // Final pre-kill snapshot (see the loop head): RSS
                    // is unreadable once the child is a zombie.
                    sampler.sample();
                    let _ = child.kill();
                    killed = true;
                }
            }
            Watch::Workers { exe, delays_ms } => {
                if listen.is_none() {
                    let held = lines.lock().unwrap();
                    if let Some(addr) = held.iter().find_map(|l| {
                        l.strip_prefix(METRIC_PREFIX)
                            .and_then(|r| r.strip_prefix("listening addr="))
                    }) {
                        listen = Some((clock.now(), addr.to_string()));
                    }
                }
                if let Some((t_listen, addr)) = &listen {
                    while next_worker < delays_ms.len()
                        && clock.now().saturating_sub(*t_listen)
                            >= Duration::from_millis(delays_ms[next_worker])
                    {
                        workers.push(spawn_worker(exe, addr)?);
                        next_worker += 1;
                    }
                }
            }
        }
        clock.sleep(Duration::from_millis(5));
    };
    let _ = reader.join();
    reap_workers(&mut workers);
    let lines = Arc::try_unwrap(lines)
        .expect("reader thread joined")
        .into_inner()
        .unwrap();
    Ok(ChildOut {
        lines,
        success: status.success(),
        killed,
        usage: sampler.finish(),
    })
}

// ---------------------------------------------------------------------------
// Scenario execution
// ---------------------------------------------------------------------------

fn base_cmd(ctx: &BenchCtx, s: &Scenario, rundir: &Path, serve: bool) -> Command {
    let mut cmd = Command::new(&ctx.exe);
    cmd.arg(if serve { "serve" } else { "run" });
    if serve {
        cmd.args(["--listen", "127.0.0.1:0"]);
    }
    cmd.arg("--synth")
        .arg("--emit-metrics")
        .arg("--out")
        .arg(rundir)
        .args(["--synth-model", s.model.name()])
        .args(["--protocol", &s.protocol])
        .args(["--clients", &s.clients.to_string()])
        .args(["--rounds", &s.rounds.to_string()])
        .args(["--seed", &s.seed.to_string()])
        .args(["--participation", &s.participation.to_string()])
        .args(["--compute-shards", &s.shards.to_string()])
        .args(["--transport", s.transport.name()]);
    if s.resident_clients > 0 {
        cmd.args(["--resident-clients", &s.resident_clients.to_string()]);
    }
    if s.tree_children > 0 {
        cmd.args(["--tree-children", &s.tree_children.to_string()]);
    }
    if s.pipelined {
        cmd.arg("--pipelined");
    }
    if s.shard_procs && !serve {
        cmd.arg("--shard-procs");
    }
    if let Some((every, ms)) = s.straggle {
        cmd.env(STRAGGLE_ENV, format!("{every}:{ms}"));
    }
    if let Some(ChaosLeg::Resize { round, to_shards }) = &s.chaos {
        cmd.args(["--elastic-resize", &format!("{round}:{to_shards}")]);
    }
    if matches!(s.chaos, Some(ChaosLeg::KillResume { .. })) {
        cmd.arg("--checkpoint-dir")
            .arg(rundir.join("ckpt"))
            .args(["--checkpoint-every", "1"]);
    }
    cmd
}

fn run_scenario_inner(ctx: &BenchCtx, s: &Scenario, rec: &mut RunRecord) -> Result<()> {
    let rundir = ctx.scratch.join(&s.id);
    let _ = std::fs::remove_dir_all(&rundir);
    std::fs::create_dir_all(&rundir)
        .map_err(|e| anyhow!("creating {}: {e}", rundir.display()))?;
    let mut parsed = Parsed::default();
    let mut usage = ProcUsage::default();

    if !s.arrivals_ms.is_empty() {
        // `fsfl serve` + Poisson-scheduled shard-worker children.
        let out = drive_child(
            base_cmd(ctx, s, &rundir, true),
            Watch::Workers {
                exe: &ctx.exe,
                delays_ms: &s.arrivals_ms,
            },
            CHILD_TIMEOUT,
            ctx.clock.as_ref(),
        )?;
        usage = usage.merge(out.usage);
        parse_into(&mut parsed, &out.lines, false)?;
        if !out.success {
            return Err(anyhow!("serve child exited with failure"));
        }
    } else if let Some(ChaosLeg::KillResume { after_rounds }) = &s.chaos {
        // Phase 1: run until `after_rounds` live round lines, SIGKILL.
        let out = drive_child(
            base_cmd(ctx, s, &rundir, false),
            Watch::KillAfterRounds(*after_rounds),
            CHILD_TIMEOUT,
            ctx.clock.as_ref(),
        )?;
        usage = usage.merge(out.usage);
        // A SIGKILL can truncate the final stdout line mid-write.
        parse_into(&mut parsed, &out.lines, out.killed)?;
        if !out.killed && !out.success {
            return Err(anyhow!("chaos child failed before the kill landed"));
        }
        // Phase 2: resume from the newest valid snapshot.
        rec.resumed = true;
        let mut resume = Command::new(&ctx.exe);
        resume
            .arg("run")
            .arg("--resume")
            .arg(rundir.join("ckpt"))
            .arg("--emit-metrics")
            .arg("--out")
            .arg(&rundir);
        if let Some((every, ms)) = s.straggle {
            resume.env(STRAGGLE_ENV, format!("{every}:{ms}"));
        }
        let out = drive_child(resume, Watch::Plain, CHILD_TIMEOUT, ctx.clock.as_ref())?;
        usage = usage.merge(out.usage);
        parse_into(&mut parsed, &out.lines, false)?;
        if !out.success {
            return Err(anyhow!("resume child exited with failure"));
        }
    } else {
        let out = drive_child(
            base_cmd(ctx, s, &rundir, false),
            Watch::Plain,
            CHILD_TIMEOUT,
            ctx.clock.as_ref(),
        )?;
        usage = usage.merge(out.usage);
        parse_into(&mut parsed, &out.lines, false)?;
        if !out.success {
            return Err(anyhow!("child exited with failure"));
        }
    }

    let (rounds_done, up, down) = parsed
        .totals
        .ok_or_else(|| anyhow!("child emitted no totals metric line"))?;
    rec.rounds_done = rounds_done;
    rec.up_bytes = up;
    rec.down_bytes = down;
    rec.round_ms = parsed.rounds.values().map(|r| r.wall_ms).collect();
    rec.wire_sent = parsed.wire.map(|w| w.0);
    rec.wire_recv = parsed.wire.map(|w| w.1);
    rec.params = parsed.params;
    rec.events = parsed.events.unwrap_or_else(|| "-".into());
    rec.rss_peak_kb = usage.rss_peak_kb;
    rec.cpu_ms = usage.cpu_ms;
    let observed: u64 = parsed.rounds.values().map(|r| r.participants).sum();
    if !parsed.rounds.is_empty() {
        // Extrapolate over rounds whose live line the SIGKILL
        // swallowed (participant counts are near-uniform per round).
        let scale = rounds_done as f64 / parsed.rounds.len() as f64;
        rec.participants = (observed as f64 * scale) as u64;
        if let Some(params) = parsed.params {
            rec.dense_bytes = (observed as f64 * scale * params as f64 * 4.0) as u64;
        }
    }
    if rounds_done != s.rounds {
        return Err(anyhow!(
            "run completed {rounds_done} of {} rounds",
            s.rounds
        ));
    }
    // Telemetry cross-check: the `registry` line reports the child's
    // live metrics-registry counters, accumulated independently of the
    // RunLog the `totals`/`wire` lines derive from. Disagreement means
    // the observability plane miscounts — fail the run. A resumed run
    // restores its round history from the snapshot while the registry
    // only saw the rounds the resume process executed, so the chaos leg
    // skips the check.
    if !rec.resumed {
        if let Some((r_rounds, r_up, r_down, r_sent, r_recv)) = parsed.registry {
            if (r_rounds, r_up, r_down) != (rounds_done as u64, up, down) {
                return Err(anyhow!(
                    "metrics registry disagrees with RunLog totals: registry \
                     rounds={r_rounds} up={r_up} down={r_down} vs totals \
                     rounds={rounds_done} up={up} down={down}"
                ));
            }
            if let Some((sent, recv)) = parsed.wire {
                if (r_sent, r_recv) != (sent, recv) {
                    return Err(anyhow!(
                        "metrics registry disagrees with measured wire bytes: \
                         registry {r_sent}/{r_recv} vs frame layer {sent}/{recv}"
                    ));
                }
            }
        }
    }
    rec.ok = true;
    let _ = std::fs::remove_dir_all(&rundir);
    Ok(())
}

/// Run one scenario end to end. Never panics the suite: failures come
/// back as `ok = false` records with the error recorded (and the
/// scenario's scratch dir left in place for post-mortem).
pub fn run_scenario(ctx: &BenchCtx, s: &Scenario) -> RunRecord {
    let mut rec = RunRecord::skeleton(s.clone());
    let t0 = ctx.clock.now();
    if let Err(e) = run_scenario_inner(ctx, s, &mut rec) {
        rec.ok = false;
        rec.error = Some(format!("{e:#}"));
    }
    rec.wall_ms = ctx.clock.now().saturating_sub(t0).as_secs_f64() * 1e3;
    rec
}

/// Run every scenario sequentially (timings must not contend with each
/// other), streaming one JSON line per run into
/// `<out_dir>/bench_runs.jsonl` and a progress line to stdout.
pub fn run_all(exe: &Path, scenarios: &[Scenario], out_dir: &Path) -> Result<Vec<RunRecord>> {
    std::fs::create_dir_all(out_dir)?;
    let ctx = BenchCtx {
        exe: exe.to_path_buf(),
        scratch: out_dir.join("scratch"),
        clock: Arc::new(MonotonicClock::new()),
    };
    let jsonl_path = out_dir.join("bench_runs.jsonl");
    let mut jsonl = std::io::BufWriter::new(std::fs::File::create(&jsonl_path)?);
    let mut records = Vec::with_capacity(scenarios.len());
    for (i, s) in scenarios.iter().enumerate() {
        println!("[{}/{}] {}", i + 1, scenarios.len(), s.id);
        let rec = run_scenario(&ctx, s);
        writeln!(jsonl, "{}", rec.to_json_line())?;
        jsonl.flush()?;
        println!("    {}", rec.outcome_line());
        records.push(rec);
    }
    println!("runs → {}", jsonl_path.display());
    Ok(records)
}

/// Merge run records into the `BENCH_scenarios.json` summary report:
/// the shared file envelope, pooled percentile statistics per suite,
/// and one compact entry per scenario.
pub fn summarize(records: &[RunRecord], mode: &str, seed: u64) -> Report {
    let mut r = Report::new();
    summary::file_header(&mut r, "scenarios", mode);
    r.int("seed", seed)
        .int("runs", records.len() as u64)
        .int("failures", records.iter().filter(|x| !x.ok).count() as u64);
    for (suite, key) in [
        (SuiteKind::A, "suite_a"),
        (SuiteKind::B, "suite_b"),
        (SuiteKind::Scale, "suite_scale"),
    ] {
        let subset: Vec<&RunRecord> = records
            .iter()
            .filter(|x| x.scenario.suite == suite)
            .collect();
        let mut round_ms = Hist::new();
        let mut rounds_per_sec = Hist::new();
        let mut clients_per_sec = Hist::new();
        let mut wall_ms = Hist::new();
        let mut wire_total = Hist::new();
        let mut compression = Hist::new();
        let mut rss = Hist::new();
        let mut cpu = Hist::new();
        for rec in subset.iter().filter(|x| x.ok) {
            round_ms.merge(&rec.round_hist());
            rounds_per_sec.push(rec.rounds_per_sec());
            clients_per_sec.push(rec.clients_per_sec());
            wall_ms.push(rec.wall_ms);
            if let (Some(s), Some(v)) = (rec.wire_sent, rec.wire_recv) {
                wire_total.push((s + v) as f64);
            }
            if let Some(x) = rec.compression_x() {
                compression.push(x);
            }
            if let Some(kb) = rec.rss_peak_kb {
                rss.push(kb as f64);
            }
            if let Some(ms) = rec.cpu_ms {
                cpu.push(ms as f64);
            }
        }
        let mut sub = Report::new();
        sub.int("runs", subset.len() as u64)
            .obj("round_ms", round_ms.report())
            .obj("rounds_per_sec", rounds_per_sec.report())
            .obj("clients_per_sec", clients_per_sec.report())
            .obj("wall_ms", wall_ms.report())
            .obj("wire_total_bytes", wire_total.report())
            .obj("compression_x", compression.report())
            .obj("rss_peak_kb", rss.report())
            .obj("cpu_ms", cpu.report());
        r.obj(key, sub);
    }
    let mut scenarios = Report::new();
    for rec in records {
        let h = rec.round_hist();
        let mut e = Report::new();
        e.bool("ok", rec.ok)
            .int("rounds_done", rec.rounds_done as u64)
            .num("rounds_per_sec", rec.rounds_per_sec())
            .num("clients_per_sec", rec.clients_per_sec())
            .num("round_ms_p50", h.percentile(50.0).unwrap_or(f64::NAN))
            .num("round_ms_p95", h.percentile(95.0).unwrap_or(f64::NAN))
            .num("round_ms_p99", h.percentile(99.0).unwrap_or(f64::NAN))
            .int("up_bytes", rec.up_bytes);
        match (rec.wire_sent, rec.wire_recv) {
            (Some(s), Some(v)) => {
                e.int("wire_total_bytes", s + v);
            }
            _ => {
                e.null("wire_total_bytes");
            }
        }
        match rec.compression_x() {
            Some(x) => {
                e.num("compression_x", x);
            }
            None => {
                e.null("compression_x");
            }
        }
        match rec.rss_peak_kb {
            Some(kb) => {
                e.int("rss_peak_kb", kb);
            }
            None => {
                e.null("rss_peak_kb");
            }
        }
        match rec.cpu_ms {
            Some(ms) => {
                e.int("cpu_ms", ms);
            }
            None => {
                e.null("cpu_ms");
            }
        }
        scenarios.obj(&rec.scenario.id, e);
    }
    r.obj("scenarios", scenarios);
    r
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bench::json;
    use crate::bench::spec::{ModelSize, SuiteKind};
    use crate::fl::TransportKind;

    fn record() -> RunRecord {
        let mut rec = RunRecord::skeleton(Scenario::cell(
            TransportKind::Loopback,
            false,
            2,
            ModelSize::Small,
            4,
            2,
            42,
        ));
        rec.ok = true;
        rec.wall_ms = 100.0;
        rec.rounds_done = 2;
        rec.round_ms = vec![40.0, 50.0];
        rec.up_bytes = 2_000;
        rec.down_bytes = 800;
        rec.participants = 8;
        rec.wire_sent = Some(5_000);
        rec.wire_recv = Some(6_000);
        rec.params = Some(1_000);
        rec.dense_bytes = 32_000;
        rec
    }

    #[test]
    fn json_line_round_trips_through_the_schema_gate() {
        let rec = record();
        let v = json::parse(&rec.to_json_line()).unwrap();
        summary::validate_run_line(&v).unwrap();
        assert_eq!(v.get("compression_x").and_then(json::Value::as_f64), Some(16.0));
        assert_eq!(v.get("rounds_per_sec").and_then(json::Value::as_f64), Some(20.0));
        assert_eq!(v.get("clients_per_sec").and_then(json::Value::as_f64), Some(80.0));
        assert_eq!(v.get("resident_clients").and_then(json::Value::as_f64), Some(0.0));
        // nullable slots render as null, not as absent keys
        assert!(matches!(v.get("rss_peak_kb"), Some(json::Value::Null)));
        assert!(matches!(v.get("chaos"), Some(json::Value::Null)));
    }

    #[test]
    fn failed_record_still_emits_a_valid_line() {
        let mut rec = RunRecord::skeleton(Scenario::cell(
            TransportKind::Mpsc,
            false,
            1,
            ModelSize::Small,
            2,
            2,
            1,
        ));
        rec.error = Some("child exited with failure".into());
        let v = json::parse(&rec.to_json_line()).unwrap();
        summary::validate_run_line(&v).unwrap();
        assert_eq!(v.get("ok").and_then(json::Value::as_bool), Some(false));
        assert!(matches!(v.get("compression_x"), Some(json::Value::Null)));
    }

    #[test]
    fn summary_merges_records_and_validates() {
        let records = vec![record(), record()];
        let rep = summarize(&records, "smoke", 7);
        let v = json::parse(&rep.render()).unwrap();
        summary::validate_summary(&v).unwrap();
        let suite_a = v.get("suite_a").unwrap();
        assert_eq!(
            suite_a
                .get("round_ms")
                .and_then(|h| h.get("count"))
                .and_then(json::Value::as_f64),
            Some(4.0)
        );
        assert!(v
            .get("scenarios")
            .and_then(|s| s.get("a-loopback-staged-s2-small"))
            .is_some());
        // suite_b is present (schema-complete) even with zero B runs
        assert!(matches!(
            v.get("suite_b").and_then(|s| s.get("round_ms")).and_then(|h| h.get("p50")),
            Some(json::Value::Null)
        ));
    }

    #[test]
    fn metric_line_parser_handles_the_full_vocabulary() {
        let lines: Vec<String> = [
            "#fsfl-metric run name=synth-fsfl rounds=2 clients=4 params=1049",
            "round 0: acc 0.5", // human line, ignored
            "#fsfl-metric round r=0 wall_ms=12.5 up=100 down=50 participants=4",
            "#fsfl-metric round r=1 wall_ms=11.0 up=90 down=40 participants=4",
            "#fsfl-metric wire sent=1000 recv=2000",
            "#fsfl-metric registry rounds=2 up=190 down=90 wire_sent=1000 wire_recv=2000",
            "#fsfl-metric events n=0 seq=-",
            "#fsfl-metric totals rounds=2 up=190 down=90 best_acc=0.5",
        ]
        .iter()
        .map(|s| s.to_string())
        .collect();
        let mut p = Parsed::default();
        parse_into(&mut p, &lines, false).unwrap();
        assert_eq!(p.totals, Some((2, 190, 90)));
        assert_eq!(p.wire, Some((1000, 2000)));
        assert_eq!(p.registry, Some((2, 190, 90, 1000, 2000)));
        assert_eq!(p.params, Some(1049));
        assert_eq!(p.events.as_deref(), Some("-"));
        assert_eq!(p.rounds.len(), 2);
        assert_eq!(p.rounds[&0].participants, 4);

        // strict mode rejects a truncated metric line; lenient skips it
        let bad = vec!["#fsfl-metric round r=0 wall_ms=".to_string()];
        let mut p = Parsed::default();
        assert!(parse_into(&mut p, &bad, false).is_err());
        parse_into(&mut p, &bad, true).unwrap();
        assert!(p.rounds.is_empty());
    }

    #[test]
    fn emitters_and_parser_agree() {
        use crate::metrics::{RoundMetrics, RunLog, WireStats};
        let mut log = RunLog::new("bench cell");
        log.push(RoundMetrics {
            round: 0,
            up_bytes: 120,
            down_bytes: 60,
            accuracy: 0.25,
            client_sparsity: vec![0.5, 0.5, 0.5],
            ..Default::default()
        });
        log.push(RoundMetrics {
            round: 1,
            up_bytes: 110,
            down_bytes: 55,
            accuracy: 0.75,
            client_sparsity: vec![0.5, 0.5],
            ..Default::default()
        });
        log.wire = Some(WireStats::from_totals(900, 1800));
        let mut lines = vec![
            crate::bench::line_listening("127.0.0.1:4040"),
            crate::bench::line_run("bench cell", 2, 3, Some(298)),
            crate::bench::line_round(&log.rounds[0], 12.5),
            crate::bench::line_round(&log.rounds[1], 11.25),
        ];
        lines.extend(crate::bench::lines_finish(&log));
        // The registry accumulates through its own path; feeding it the
        // same rounds must yield a line the parser reads back equal.
        let reg = crate::obs::MetricsRegistry::default();
        for m in &log.rounds {
            reg.record_round(m);
        }
        lines.push(crate::bench::line_registry(&reg));
        let mut p = Parsed::default();
        parse_into(&mut p, &lines, false).unwrap();
        assert_eq!(p.params, Some(298));
        assert_eq!(p.totals, Some((2, 230, 115)));
        assert_eq!(p.wire, Some((900, 1800)));
        assert_eq!(p.registry, Some((2, 230, 115, 0, 0)));
        assert_eq!(p.events.as_deref(), Some("-"));
        assert_eq!(p.rounds[&0].participants, 3);
        assert_eq!(p.rounds[&1].participants, 2);
        assert_eq!(p.rounds[&0].wall_ms, 12.5);
    }

    #[test]
    fn suite_kind_partition_is_total() {
        // guards the summarize() suite split against new suite kinds
        for s in [SuiteKind::A, SuiteKind::B, SuiteKind::Scale] {
            assert!(["a", "b", "scale"].contains(&s.name()));
        }
    }

    #[test]
    fn near_instant_child_still_yields_cpu_ticks() {
        // A child that exits before (or between) polls is a zombie by
        // the time the monitor observes it; sampling before try_wait
        // reaps it must still recover its final CPU ticks instead of
        // reporting stale or null usage.
        let mut cmd = Command::new("/bin/sh");
        cmd.args(["-c", "exit 0"]);
        let clock = MonotonicClock::new();
        let out = drive_child(cmd, Watch::Plain, Duration::from_secs(30), &clock).unwrap();
        assert!(out.success);
        if cfg!(target_os = "linux") {
            assert!(
                out.usage.cpu_ms.is_some(),
                "a reap-raced child must still report CPU time"
            );
        }
    }
}
