//! Histogram / percentile math and the shared summary schema.
//!
//! Both `fsfl bench` (scenario summaries) and `benches/fl_round.rs`
//! (codec micro-bench) write their artifacts through [`file_header`] +
//! [`Hist::report`], so every committed `BENCH_*.json` carries the same
//! envelope and the CI schema diff can treat them uniformly.

use anyhow::{anyhow, Result};

use crate::benchkit::Report;

use super::json::Value;
use super::{RUN_SCHEMA, SCHEMA_VERSION, SUMMARY_SCHEMA};

/// A merge-able sample pool with nearest-rank percentiles.
///
/// Deliberately exact (keeps every sample) rather than bucketed: suite
/// sizes are hundreds of samples at most, and exactness makes the
/// single-sample and empty-suite edge cases trivially correct — an
/// empty pool reports `null` for every statistic, a single sample *is*
/// every percentile.
#[derive(Debug, Clone, Default)]
pub struct Hist {
    samples: Vec<f64>,
}

impl Hist {
    /// Empty pool.
    pub fn new() -> Self {
        Self::default()
    }

    /// Add one sample. Non-finite values are ignored (a failed run must
    /// not poison the percentiles of the runs that succeeded).
    pub fn push(&mut self, v: f64) {
        if v.is_finite() {
            self.samples.push(v);
        }
    }

    /// Fold another pool's samples into this one.
    pub fn merge(&mut self, other: &Hist) {
        self.samples.extend_from_slice(&other.samples);
    }

    /// Number of (finite) samples recorded.
    pub fn count(&self) -> usize {
        self.samples.len()
    }

    /// Smallest sample, `None` when empty.
    pub fn min(&self) -> Option<f64> {
        self.samples.iter().copied().reduce(f64::min)
    }

    /// Largest sample, `None` when empty.
    pub fn max(&self) -> Option<f64> {
        self.samples.iter().copied().reduce(f64::max)
    }

    /// Arithmetic mean, `None` when empty.
    pub fn mean(&self) -> Option<f64> {
        if self.samples.is_empty() {
            None
        } else {
            Some(self.samples.iter().sum::<f64>() / self.samples.len() as f64)
        }
    }

    /// Nearest-rank percentile (`p` in `[0, 100]`): the smallest sample
    /// such that at least `p`% of the pool is ≤ it. `None` when empty.
    pub fn percentile(&self, p: f64) -> Option<f64> {
        if self.samples.is_empty() {
            return None;
        }
        let mut sorted = self.samples.clone();
        sorted.sort_by(|a, b| a.total_cmp(b));
        let n = sorted.len();
        let rank = ((p / 100.0) * n as f64).ceil() as usize;
        Some(sorted[rank.clamp(1, n) - 1])
    }

    /// Render as the standard statistic object:
    /// `{count, min, p50, p95, p99, max, mean}` — every value `null`
    /// when the pool is empty (the empty-suite case must still produce
    /// a schema-complete summary).
    pub fn report(&self) -> Report {
        let or_nan = |v: Option<f64>| v.unwrap_or(f64::NAN); // NaN renders as null
        let mut r = Report::new();
        r.int("count", self.count() as u64)
            .num("min", or_nan(self.min()))
            .num("p50", or_nan(self.percentile(50.0)))
            .num("p95", or_nan(self.percentile(95.0)))
            .num("p99", or_nan(self.percentile(99.0)))
            .num("max", or_nan(self.max()))
            .num("mean", or_nan(self.mean()));
        r
    }
}

/// Write the shared summary-file envelope (`schema`, `v`, `bench`,
/// `mode`) into `report`. Every `BENCH_*.json` writer must call this
/// first so [`validate_summary`] and the CI schema diff hold across
/// artifacts.
pub fn file_header(report: &mut Report, bench: &str, mode: &str) {
    report
        .str("schema", SUMMARY_SCHEMA)
        .int("v", SCHEMA_VERSION)
        .str("bench", bench)
        .str("mode", mode);
}

/// Expected type of one run-line field.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FieldKind {
    /// JSON string.
    Str,
    /// JSON number holding an integer.
    Int,
    /// JSON number.
    Num,
    /// JSON boolean.
    Bool,
    /// JSON number or `null`.
    NumOrNull,
    /// JSON string or `null`.
    StrOrNull,
    /// JSON array of numbers (possibly empty).
    NumArr,
}

/// The complete per-run JSON-line schema: every key a line must carry,
/// with its type. [`validate_run_line`] enforces this list *exactly* —
/// missing keys, wrong types and unknown keys all fail — so any drift
/// in `driver::RunRecord::to_json_line` is caught by tier-1 tests
/// before it reaches a committed `BENCH_*.json`.
pub const RUN_FIELDS: &[(&str, FieldKind)] = &[
    ("schema", FieldKind::Str),
    ("v", FieldKind::Int),
    ("suite", FieldKind::Str),
    ("scenario", FieldKind::Str),
    ("transport", FieldKind::Str),
    ("schedule", FieldKind::Str),
    ("shards", FieldKind::Int),
    ("model", FieldKind::Str),
    ("protocol", FieldKind::Str),
    ("clients", FieldKind::Int),
    ("rounds", FieldKind::Int),
    ("seed", FieldKind::Int),
    ("participation", FieldKind::Num),
    ("resident_clients", FieldKind::Int),
    ("tree_children", FieldKind::Int),
    ("shard_procs", FieldKind::Bool),
    ("ok", FieldKind::Bool),
    ("error", FieldKind::StrOrNull),
    ("resumed", FieldKind::Bool),
    ("rounds_done", FieldKind::Int),
    ("wall_ms", FieldKind::Num),
    ("rounds_per_sec", FieldKind::Num),
    ("participants", FieldKind::Int),
    ("clients_per_sec", FieldKind::Num),
    ("round_ms", FieldKind::NumArr),
    ("round_ms_p50", FieldKind::NumOrNull),
    ("round_ms_p95", FieldKind::NumOrNull),
    ("round_ms_p99", FieldKind::NumOrNull),
    ("up_bytes", FieldKind::Int),
    ("down_bytes", FieldKind::Int),
    ("wire_sent", FieldKind::NumOrNull),
    ("wire_recv", FieldKind::NumOrNull),
    ("params", FieldKind::NumOrNull),
    ("dense_bytes", FieldKind::Int),
    ("compression_x", FieldKind::NumOrNull),
    ("rss_peak_kb", FieldKind::NumOrNull),
    ("cpu_ms", FieldKind::NumOrNull),
    ("arrivals_ms", FieldKind::NumArr),
    ("straggle", FieldKind::StrOrNull),
    ("chaos", FieldKind::StrOrNull),
    ("events", FieldKind::Str),
];

/// Run-line fields that are *expected* to differ between two runs of
/// the same seeded Suite B scenario (wall-clock measurements and
/// host-dependent resource usage). The seed-reproducibility contract —
/// same `--seed` ⇒ identical per-run JSON — is asserted on everything
/// *outside* this list; see [`reproducible_view`].
pub const TIMING_FIELDS: &[&str] = &[
    "wall_ms",
    "rounds_per_sec",
    "clients_per_sec",
    "round_ms",
    "round_ms_p50",
    "round_ms_p95",
    "round_ms_p99",
    "rss_peak_kb",
    "cpu_ms",
];

fn field_matches(kind: FieldKind, v: &Value) -> bool {
    match kind {
        FieldKind::Str => matches!(v, Value::Str(_)),
        FieldKind::Bool => matches!(v, Value::Bool(_)),
        FieldKind::Num => matches!(v, Value::Num(_)),
        FieldKind::Int => matches!(v, Value::Num(n) if n.fract() == 0.0),
        FieldKind::NumOrNull => matches!(v, Value::Num(_) | Value::Null),
        FieldKind::StrOrNull => matches!(v, Value::Str(_) | Value::Null),
        FieldKind::NumArr => match v {
            Value::Arr(items) => items.iter().all(|x| matches!(x, Value::Num(_))),
            _ => false,
        },
    }
}

/// Validate one parsed per-run JSON line against [`RUN_FIELDS`]:
/// object shape, exact key set, per-key types, and the
/// `schema`/`v` envelope values.
pub fn validate_run_line(v: &Value) -> Result<()> {
    let obj = v
        .as_obj()
        .ok_or_else(|| anyhow!("run line is not a JSON object"))?;
    for (key, kind) in RUN_FIELDS {
        let val = v
            .get(key)
            .ok_or_else(|| anyhow!("run line missing required key {key:?}"))?;
        if !field_matches(*kind, val) {
            return Err(anyhow!(
                "run line key {key:?} has wrong type (expected {kind:?}, got {val:?})"
            ));
        }
    }
    for (key, _) in obj {
        if !RUN_FIELDS.iter().any(|(k, _)| k == key) {
            return Err(anyhow!("run line carries unknown key {key:?}"));
        }
    }
    if v.get("schema").and_then(Value::as_str) != Some(RUN_SCHEMA) {
        return Err(anyhow!("run line schema tag is not {RUN_SCHEMA:?}"));
    }
    if v.get("v").and_then(Value::as_f64) != Some(SCHEMA_VERSION as f64) {
        return Err(anyhow!("run line schema version is not {SCHEMA_VERSION}"));
    }
    Ok(())
}

/// Validate a summary file's envelope: a JSON object whose
/// `schema`/`v` match this build and whose `bench`/`mode` tags are
/// present. Structural comparison against the committed baseline is
/// CI's job (key-path diff); this check is what the bench smoke tests
/// pin.
pub fn validate_summary(v: &Value) -> Result<()> {
    v.as_obj()
        .ok_or_else(|| anyhow!("summary is not a JSON object"))?;
    if v.get("schema").and_then(Value::as_str) != Some(SUMMARY_SCHEMA) {
        return Err(anyhow!("summary schema tag is not {SUMMARY_SCHEMA:?}"));
    }
    if v.get("v").and_then(Value::as_f64) != Some(SCHEMA_VERSION as f64) {
        return Err(anyhow!("summary schema version is not {SCHEMA_VERSION}"));
    }
    for key in ["bench", "mode"] {
        if v.get(key).and_then(Value::as_str).is_none() {
            return Err(anyhow!("summary missing string key {key:?}"));
        }
    }
    Ok(())
}

/// Project a parsed run line onto its seed-reproducible view: every
/// field except [`TIMING_FIELDS`], rendered canonically. When the run
/// had a chaos leg (`chaos` non-null) the `wire_*` fields are dropped
/// too — how many frame bytes moved before a SIGKILL landed depends on
/// where the kill raced the round pipeline, which is exactly the
/// non-determinism chaos legs exist to exercise.
pub fn reproducible_view(v: &Value) -> Vec<(String, String)> {
    let chaotic = matches!(v.get("chaos"), Some(Value::Str(_)));
    let mut out = Vec::new();
    if let Some(obj) = v.as_obj() {
        for (k, val) in obj {
            if TIMING_FIELDS.contains(&k.as_str()) {
                continue;
            }
            if chaotic && (k == "wire_sent" || k == "wire_recv") {
                continue;
            }
            out.push((k.clone(), val.render()));
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bench::json;

    #[test]
    fn empty_hist_reports_nulls_but_stays_schema_complete() {
        let h = Hist::new();
        assert_eq!(h.count(), 0);
        assert_eq!(h.percentile(50.0), None);
        assert_eq!(h.min(), None);
        assert_eq!(h.mean(), None);
        let rendered = h.report().render();
        let v = json::parse(&rendered).unwrap();
        assert_eq!(v.get("count").and_then(Value::as_f64), Some(0.0));
        assert!(matches!(v.get("p50"), Some(Value::Null)));
        assert!(matches!(v.get("mean"), Some(Value::Null)));
    }

    #[test]
    fn single_sample_is_every_percentile() {
        let mut h = Hist::new();
        h.push(42.0);
        for p in [0.0, 1.0, 50.0, 95.0, 99.0, 100.0] {
            assert_eq!(h.percentile(p), Some(42.0), "p{p}");
        }
        assert_eq!(h.min(), Some(42.0));
        assert_eq!(h.max(), Some(42.0));
        assert_eq!(h.mean(), Some(42.0));
    }

    #[test]
    fn nearest_rank_percentiles_on_known_pool() {
        let mut h = Hist::new();
        for i in 1..=100 {
            h.push(i as f64);
        }
        assert_eq!(h.percentile(50.0), Some(50.0));
        assert_eq!(h.percentile(95.0), Some(95.0));
        assert_eq!(h.percentile(99.0), Some(99.0));
        assert_eq!(h.percentile(100.0), Some(100.0));
        assert_eq!(h.percentile(0.0), Some(1.0));
    }

    #[test]
    fn merge_pools_and_ignore_non_finite() {
        let mut a = Hist::new();
        a.push(1.0);
        a.push(f64::NAN);
        a.push(f64::INFINITY);
        let mut b = Hist::new();
        b.push(3.0);
        a.merge(&b);
        assert_eq!(a.count(), 2);
        assert_eq!(a.min(), Some(1.0));
        assert_eq!(a.max(), Some(3.0));
        assert_eq!(a.mean(), Some(2.0));
    }

    #[test]
    fn summary_envelope_validates() {
        let mut r = Report::new();
        file_header(&mut r, "scenarios", "smoke");
        let v = json::parse(&r.render()).unwrap();
        validate_summary(&v).unwrap();

        // wrong version fails
        let bad = json::parse(
            "{\"schema\": \"fsfl-bench-summary\", \"v\": 999, \
             \"bench\": \"x\", \"mode\": \"smoke\"}",
        )
        .unwrap();
        assert!(validate_summary(&bad).is_err());
    }

    #[test]
    fn reproducible_view_drops_timing_and_chaotic_wire() {
        let line = "{\"chaos\": \"kill@1\", \"wall_ms\": 12.0, \
                    \"wire_sent\": 10, \"up_bytes\": 7, \"ok\": true}";
        let v = json::parse(line).unwrap();
        let view = reproducible_view(&v);
        let keys: Vec<&str> = view.iter().map(|(k, _)| k.as_str()).collect();
        assert_eq!(keys, vec!["chaos", "up_bytes", "ok"]);

        // without chaos, wire fields survive
        let line = "{\"chaos\": null, \"wall_ms\": 12.0, \"wire_sent\": 10}";
        let v = json::parse(line).unwrap();
        let keys: Vec<String> = reproducible_view(&v).into_iter().map(|(k, _)| k).collect();
        assert_eq!(keys, vec!["chaos", "wire_sent"]);
    }
}
