//! Unit tests for protocol presets, config plumbing and server-side
//! aggregation math (no PJRT runtime needed).

use std::sync::Arc;

use crate::compression::SparsifyMode;
use crate::data::TaskKind;
use crate::fl::config::{ExperimentConfig, Protocol};
use crate::model::params::Delta;
use crate::model::{Group, Kind, Manifest, TensorSpec};

fn tiny_manifest() -> Arc<Manifest> {
    Arc::new(Manifest {
        model: "t".into(),
        variant: "t".into(),
        classes: 2,
        input: vec![2, 2, 1],
        batch: 1,
        param_count: 6,
        scale_count: 2,
        tensors: vec![
            TensorSpec {
                name: "w".into(),
                shape: vec![2, 2],
                kind: Kind::ConvW,
                group: Group::Weight,
                layer: "l".into(),
                out_ch: Some(2),
                scale_for: None,
            },
            TensorSpec {
                name: "s".into(),
                shape: vec![2],
                kind: Kind::Scale,
                group: Group::Scale,
                layer: "l".into(),
                out_ch: Some(2),
                scale_for: Some("w".into()),
            },
        ],
    })
}

#[test]
fn protocol_presets_match_paper_rows() {
    let sp = SparsifyMode::TopK { rate: 0.96 };
    let q = crate::compression::QuantConfig::default();
    let fedavg = Protocol::FedAvg.config(sp, q);
    assert!(fedavg.codec.is_none() && !fedavg.scaled && !fedavg.residuals);

    let fq = Protocol::FedAvgQ.config(sp, q);
    let c = fq.codec.unwrap();
    assert!(matches!(c.sparsify, SparsifyMode::None) && !c.ternary);

    let stc = Protocol::Stc.config(sp, q);
    assert!(stc.codec.unwrap().ternary && stc.residuals && !stc.scaled);

    let stc_s = Protocol::StcScaled.config(sp, q);
    assert!(stc_s.codec.unwrap().ternary && stc_s.residuals && stc_s.scaled);

    let sparse = Protocol::SparseOnly.config(sp, q);
    assert!(!sparse.codec.unwrap().ternary && !sparse.scaled && !sparse.residuals);

    let fsfl = Protocol::Fsfl.config(sp, q);
    assert!(fsfl.scaled && !fsfl.codec.unwrap().ternary && !fsfl.residuals);
}

#[test]
fn residuals_override_wins() {
    let mut cfg = ExperimentConfig::quick("tiny_cnn", TaskKind::CifarLike, Protocol::Fsfl);
    assert!(!cfg.protocol_config().residuals);
    cfg.residuals_override = Some(true);
    assert!(cfg.protocol_config().residuals);
    cfg.protocol = Protocol::Stc;
    cfg.residuals_override = Some(false);
    assert!(!cfg.protocol_config().residuals);
}

#[test]
fn downstream_codec_only_when_bidirectional() {
    let mut cfg = ExperimentConfig::quick("tiny_cnn", TaskKind::CifarLike, Protocol::Fsfl);
    assert!(cfg.downstream_codec().is_none());
    cfg.bidirectional = true;
    let dc = cfg.downstream_codec().unwrap();
    // paper Sec. 5.1: halved coarse step for the second quantization leg
    assert!(dc.quant.coarse_step < cfg.quant.coarse_step);
}

#[test]
fn protocol_parsing() {
    for (s, p) in [
        ("fedavg", Protocol::FedAvg),
        ("fedavg_q", Protocol::FedAvgQ),
        ("stc", Protocol::Stc),
        ("eqs23", Protocol::SparseOnly),
        ("stc_scaled", Protocol::StcScaled),
        ("FSFL", Protocol::Fsfl),
    ] {
        assert_eq!(s.parse::<Protocol>().unwrap(), p);
    }
    assert!("nope".parse::<Protocol>().is_err());
}

#[test]
fn server_aggregate_is_mean_and_applies() {
    use crate::fl::server::Server;
    use crate::model::ParamSet;
    let m = tiny_manifest();
    let params = ParamSet::new(m.clone(), vec![vec![0.0; 4], vec![1.0; 2]]).unwrap();
    let mut server = Server::new(params, None);
    let mut d1 = Delta::zeros(m.clone());
    d1.tensors[0] = vec![1.0, 2.0, 3.0, 4.0];
    let mut d2 = Delta::zeros(m.clone());
    d2.tensors[0] = vec![3.0, 2.0, 1.0, 0.0];
    let agg = server.aggregate(&[d1, d2]);
    assert_eq!(agg.broadcast.tensors[0], vec![2.0, 2.0, 2.0, 2.0]);
    assert_eq!(server.params.tensors[0], vec![2.0, 2.0, 2.0, 2.0]);
    // scales untouched
    assert_eq!(server.params.tensors[1], vec![1.0, 1.0]);
    // raw downstream accounting = full f32 update size
    assert_eq!(agg.down_bytes_each, 4 * 6);
}

#[test]
fn server_bidirectional_quantizes_broadcast() {
    use crate::compression::UpdateCodec;
    use crate::fl::server::Server;
    use crate::model::ParamSet;
    let m = tiny_manifest();
    let params = ParamSet::new(m.clone(), vec![vec![0.0; 4], vec![1.0; 2]]).unwrap();
    let mut server = Server::new(params, Some(UpdateCodec::quant_only()));
    let mut d = Delta::zeros(m.clone());
    d.tensors[0] = vec![1e-3, -2e-3, 0.0, 5e-4];
    let agg = server.aggregate(&[d]);
    // values snapped to the coarse grid
    let step = crate::compression::quantize::STEP_COARSE_UNI;
    for v in &agg.broadcast.tensors[0] {
        let q = v / step;
        assert!((q - q.round()).abs() < 1e-3, "{v} not on grid");
    }
    // header dominates a 6-element toy update; just sanity-bound it
    assert!(agg.down_bytes_each < 64);
}
