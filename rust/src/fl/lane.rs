//! Per-participant round state shared between the compute plane and the
//! codec plane.
//!
//! A [`RoundLane`] owns every buffer one client's round needs outside the
//! XLA step functions: the raw differential update, the encoded
//! bitstreams, the dequantized views, the server-side decode target and
//! the codec scratch. Lanes live in [`crate::fl::Experiment`] and are
//! recycled across rounds, so the whole codec path allocates nothing in
//! steady state. Crucially, a lane is `Send` and self-contained: the
//! codec stages ([`RoundLane::encode_upstream`], [`RoundLane::finish_round`])
//! borrow no client or server state, which is what lets the
//! [`crate::exec::WorkerPool`] fan them out across threads while the
//! thread-affine compute plane stays put.

use std::sync::Arc;

use crate::compression::cabac::codec::raw_bytes_of;
use crate::compression::{CodecScratch, EncodeStats, SparsifyMode, UpdateCodec};
use crate::fl::config::ProtocolConfig;
use crate::model::params::Delta;
use crate::model::Manifest;

/// All state one participant needs for one round, outside the runtime.
pub struct RoundLane {
    /// Which client this lane serves this round.
    pub client: usize,
    /// Raw differential update ΔW (+ injected residual), Eq. (1)/(5).
    pub raw: Delta,
    /// Sparsify/ternarize working copy (keeps `raw` intact for Eq. (5)
    /// residual bookkeeping when error accumulation is on).
    sparse: Delta,
    /// Dequantized transmitted update Δ̂ (W stream, then += S stream).
    pub update: Delta,
    /// Raw S-only delta from the scale sub-epochs (Algorithm 1 l. 20).
    pub sdelta: Delta,
    /// Dequantized S update (client-side view of the S stream).
    pub sdeq: Delta,
    /// Server-side decode target for the S stream (wire path).
    sdec: Delta,
    /// Server-side reconstruction of all streams (what aggregation uses).
    pub decoded: Delta,
    /// Encoded W-update stream (empty for plain FedAvg).
    pub stream_w: Vec<u8>,
    /// Encoded S-update stream (empty unless a scale update was kept).
    pub stream_s: Vec<u8>,
    /// Recycled codec buffers (see the scratch contract in
    /// [`crate::compression`]).
    pub scratch: CodecScratch,
    /// Size/occupancy statistics of the W encode.
    pub stats: EncodeStats,
    /// Total upstream wire bytes this round (W + S streams).
    pub up_bytes: usize,
    /// Whether the client kept a scale update (Algorithm 1 discard rule).
    pub scale_accepted: bool,
    has_w_stream: bool,
    has_s_stream: bool,
    /// Mean training loss over this round's local batches.
    pub train_loss: f64,
    /// Wall-clock milliseconds spent in local weight training.
    pub train_ms: u128,
    /// Wall-clock milliseconds spent in the scale sub-epochs.
    pub scale_ms: u128,
    /// Codec-stage failure (decode of a malformed stream), surfaced back
    /// on the driver thread after the parallel stage joins.
    pub error: Option<anyhow::Error>,
}

/// Borrowed wire image of one finished [`RoundLane`] — the fields a
/// shard transmits so the coordinator can reconstruct the lane (see
/// [`RoundLane::wire_parts`] / [`RoundLane::restore_wire`]).
pub struct LaneParts<'a> {
    /// Client id this lane served.
    pub client: usize,
    /// Encoded W-update bitstream (None for plain FedAvg).
    pub stream_w: Option<&'a [u8]>,
    /// Encoded S-update bitstream (None unless a scale update was kept
    /// alongside an encoded W stream).
    pub stream_s: Option<&'a [u8]>,
    /// The raw f32 update when no W stream exists (plain FedAvg's wire
    /// format; already includes any S contribution).
    pub raw: Option<&'a Delta>,
    /// Upstream wire-byte accounting for this lane.
    pub up_bytes: usize,
    /// Wall-clock milliseconds of local weight training.
    pub train_ms: u128,
    /// Wall-clock milliseconds of the scale sub-epochs.
    pub scale_ms: u128,
    /// Mean local training loss.
    pub train_loss: f64,
    /// Whether the client kept its scale update.
    pub scale_accepted: bool,
    /// W-encode size/occupancy statistics.
    pub stats: EncodeStats,
}

impl RoundLane {
    /// Allocate a lane's buffers once; reuse it for every later round.
    pub fn new(manifest: Arc<Manifest>) -> Self {
        Self {
            client: usize::MAX,
            raw: Delta::zeros(manifest.clone()),
            sparse: Delta::zeros(manifest.clone()),
            update: Delta::zeros(manifest.clone()),
            sdelta: Delta::zeros(manifest.clone()),
            sdeq: Delta::zeros(manifest.clone()),
            sdec: Delta::zeros(manifest.clone()),
            decoded: Delta::zeros(manifest),
            stream_w: Vec::new(),
            stream_s: Vec::new(),
            scratch: CodecScratch::default(),
            stats: EncodeStats::default(),
            up_bytes: 0,
            scale_accepted: false,
            has_w_stream: false,
            has_s_stream: false,
            train_loss: 0.0,
            train_ms: 0,
            scale_ms: 0,
            error: None,
        }
    }

    /// Reset per-round bookkeeping and bind the lane to a client. Buffer
    /// contents are *not* cleared here — every stage overwrites its
    /// outputs before any reader sees them (the scratch contract).
    pub fn begin(&mut self, client: usize) {
        self.client = client;
        self.stats = EncodeStats::default();
        self.up_bytes = 0;
        self.scale_accepted = false;
        self.has_w_stream = false;
        self.has_s_stream = false;
        self.train_loss = 0.0;
        self.train_ms = 0;
        self.scale_ms = 0;
        self.error = None;
    }

    /// Codec stage A (parallel, after local training): sparsify +
    /// quantize + DeepCABAC-encode the W update, or account the raw f32
    /// bytes for plain FedAvg. Pure function of lane state + `pcfg`.
    // fsfl-lint: hot
    pub fn encode_upstream(&mut self, pcfg: &ProtocolConfig, update_idx: &[usize]) {
        self.stream_w.clear();
        self.stream_s.clear();
        match pcfg.codec {
            None => {
                // plain FedAvg: "transmit" the exact raw update
                self.update.copy_from(&self.raw);
                self.stats = EncodeStats::default();
                self.up_bytes = raw_bytes_of(&self.raw.manifest, update_idx);
            }
            Some(codec) => {
                if pcfg.residuals {
                    // Eq. (5) needs the pre-sparsification update later;
                    // sparsify a copy (memcpy, no allocation).
                    self.sparse.copy_from(&self.raw);
                    self.stats = codec.encode_into(
                        &mut self.sparse,
                        update_idx,
                        &mut self.scratch,
                        &mut self.update,
                        &mut self.stream_w,
                    );
                } else {
                    self.stats = codec.encode_into(
                        &mut self.raw,
                        update_idx,
                        &mut self.scratch,
                        &mut self.update,
                        &mut self.stream_w,
                    );
                }
                self.has_w_stream = true;
                self.up_bytes = self.stream_w.len();
            }
        }
    }

    /// Codec stage B (parallel, after the scale sub-epochs): encode the
    /// fine-step S stream if the client kept a scale update, then decode
    /// every stream exactly as the server will (wire-path fidelity) and
    /// cross-check the reconstruction against the client-side view.
    pub fn finish_round(&mut self, pcfg: &ProtocolConfig, scale_idx: &[usize]) {
        if self.scale_accepted {
            // re-calculated differences considering S, quantized with the
            // fine step, transmitted as a second stream
            let base = pcfg.codec.unwrap_or(UpdateCodec::quant_only());
            let s_codec = UpdateCodec {
                sparsify: SparsifyMode::None,
                quant: base.quant,
                ternary: false,
            };
            s_codec.encode_into(
                &mut self.sdelta,
                scale_idx,
                &mut self.scratch,
                &mut self.sdeq,
                &mut self.stream_s,
            );
            self.update.accumulate(&self.sdeq);
            self.up_bytes += self.stream_s.len();
            self.has_s_stream = true;
        }

        // Server-side decode of the actual bitstreams.
        if let Err(e) = self.decode_wire() {
            self.error = Some(e);
            return;
        }
        // Wire-path integrity: the server's reconstruction must equal the
        // client's view. Full `Delta` equality is pointlessly expensive in
        // debug builds of large variants; a single-pass FNV checksum over
        // the exact f32 bit patterns catches any mismatch just as surely.
        debug_assert_eq!(
            self.decoded.checksum(),
            self.update.checksum(),
            "codec decode != client view (client {})",
            self.client
        );
    }

    fn decode_wire(&mut self) -> anyhow::Result<()> {
        if !self.has_w_stream && !self.has_s_stream {
            // plain FedAvg: the exact raw update crosses the wire
            self.decoded.copy_from(&self.update);
            return Ok(());
        }
        if self.has_w_stream {
            crate::compression::cabac::decode_update_with(
                &self.stream_w,
                &mut self.decoded,
                &mut self.scratch.decode,
            )?;
        } else {
            self.decoded.clear();
        }
        if self.has_s_stream {
            crate::compression::cabac::decode_update_with(
                &self.stream_s,
                &mut self.sdec,
                &mut self.scratch.decode,
            )?;
            self.decoded.accumulate(&self.sdec);
        }
        Ok(())
    }
    // fsfl-lint: end-hot

    /// The lane's wire image: exactly what a shard must transmit for the
    /// coordinator to reconstruct this round's contribution (see
    /// `net::wire`). Encoded protocols ship the actual bitstreams; plain
    /// FedAvg ships the raw f32 update (`raw` covers any S contribution
    /// already, so no separate S stream travels in that case).
    pub fn wire_parts(&self) -> LaneParts<'_> {
        let w = self.has_w_stream;
        LaneParts {
            client: self.client,
            stream_w: w.then(|| self.stream_w.as_slice()),
            stream_s: (w && self.has_s_stream).then(|| self.stream_s.as_slice()),
            raw: (!w).then_some(&self.update),
            up_bytes: self.up_bytes,
            train_ms: self.train_ms,
            scale_ms: self.scale_ms,
            train_loss: self.train_loss,
            scale_accepted: self.scale_accepted,
            stats: self.stats,
        }
    }

    /// Rebuild a coordinator-side lane from a received wire image.
    ///
    /// Caller contract (upheld by `net::wire::decode_round_done_into`):
    /// before this call, `stream_w`/`stream_s` hold the received
    /// bitstreams when `has_w`/`has_s` are set, and `decoded` holds the
    /// received raw f32 update when neither is. This method resets the
    /// per-round bookkeeping, installs the transmitted scalars, and —
    /// for encoded lanes — performs the server-side decode of the actual
    /// bitstreams into `decoded` (wire-path fidelity: aggregation
    /// consumes exactly the bytes that crossed the transport).
    #[allow(clippy::too_many_arguments)]
    pub fn restore_wire(
        &mut self,
        client: usize,
        has_w: bool,
        has_s: bool,
        up_bytes: usize,
        train_ms: u128,
        scale_ms: u128,
        train_loss: f64,
        scale_accepted: bool,
        stats: EncodeStats,
    ) -> anyhow::Result<()> {
        self.begin(client);
        self.has_w_stream = has_w;
        self.has_s_stream = has_s;
        self.up_bytes = up_bytes;
        self.train_ms = train_ms;
        self.scale_ms = scale_ms;
        self.train_loss = train_loss;
        self.scale_accepted = scale_accepted;
        self.stats = stats;
        if has_w || has_s {
            self.decode_wire()?;
        }
        // The client-side view equals the server-side reconstruction by
        // the codec invariant; restoring both keeps every downstream
        // consumer (metrics sparsity, aggregation) oblivious to whether
        // the lane crossed a wire.
        self.update.copy_from(&self.decoded);
        Ok(())
    }

    /// Encoded streams in wire order (W first, then S), for byte-level
    /// equivalence tests.
    pub fn streams(&self) -> Vec<&[u8]> {
        let mut v = Vec::new();
        if self.has_w_stream {
            v.push(self.stream_w.as_slice());
        }
        if self.has_s_stream {
            v.push(self.stream_s.as_slice());
        }
        v
    }
}
