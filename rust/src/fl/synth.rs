//! Deterministic synthetic compute plane.
//!
//! The transport/scheduler test planes need a compute plane whose output
//! is a pure function of `(client id, round seed)` — no PJRT backend, no
//! artifacts directory — so the *protocol* machinery (scheduling,
//! sharded fan-in, wire transports, multi-process workers) can be
//! exercised byte-for-byte everywhere, including CI on the vendored null
//! XLA backend. [`SyntheticPlane`] is that plane: what a client "trains"
//! is seeded noise shaped like a real differential update (coarse
//! magnitudes on row-structured tensors, fine magnitudes on
//! scale/bias/BN tensors), and scale updates are accepted by client-id
//! parity so the decision is independent of scheduling shape.
//!
//! The synthetic shard worker (see `coordinator`) pairs this with
//! [`synth_eval`]: a central-model "evaluation" derived from the FNV
//! checksum of the accumulated broadcasts. Any single-bit divergence in
//! any aggregated broadcast — i.e. in any transmitted bitstream —
//! changes the reported accuracy, which is what lets the differential
//! conformance tests pin bitstream identity through nothing but
//! `RunLog` equality.

use std::sync::Arc;

use anyhow::Result;

use crate::data::XorShiftRng;
use crate::fl::scheduler::ComputePlane;
use crate::fl::server::EvalReport;
use crate::fl::RoundLane;
use crate::model::params::Delta;
use crate::model::{Group, Manifest};

/// Fill `out` with a seeded synthetic differential update: every tensor
/// is overwritten with Gaussian noise at coarse (row-structured) or fine
/// (scale/bias/BN) magnitude, mimicking a real post-training ΔW.
pub fn synth_client_delta(m: &Arc<Manifest>, seed: u64, out: &mut Delta) {
    let mut rng = XorShiftRng::new(seed);
    for (t, spec) in out.tensors.iter_mut().zip(&m.tensors) {
        let scale = if spec.kind.is_fine_quantized() { 5e-6 } else { 8e-4 };
        for x in t.iter_mut() {
            *x = rng.normal() * scale;
        }
    }
}

/// Fill `out` with a seeded synthetic S-only delta: zeros everywhere
/// except the scale-group tensors (the shape `RoundLane::finish_round`
/// expects in `sdelta`).
pub fn synth_scale_delta(m: &Arc<Manifest>, seed: u64, out: &mut Delta) {
    let mut rng = XorShiftRng::new(seed ^ 0x5CA1E);
    out.clear();
    for &si in &m.group_indices(Group::Scale) {
        for x in out.tensors[si].iter_mut() {
            *x = rng.normal() * 1e-4;
        }
    }
}

/// Deterministic stand-in for central-model evaluation: quality metrics
/// derived from the FNV checksum of the accumulated broadcast deltas.
/// A pure function of every byte the server ever aggregated, so two
/// deployments report equal accuracy iff their broadcast history is
/// bit-identical.
pub fn synth_eval(broadcast_accum: &Delta) -> EvalReport {
    let h = broadcast_accum.checksum();
    let unit = |x: u64| (x % 1_000_000) as f64 / 1e6;
    EvalReport {
        loss: unit(h.rotate_left(17)),
        accuracy: unit(h),
        f1: unit(h.rotate_left(31)),
    }
}

/// A small self-contained model contract for PJRT-free synthetic runs
/// (`fsfl run --synth` and the session/transport CI planes): two
/// row-structured weight tensors with biases and a per-filter scale
/// vector, so every codec path (coarse rows, fine side-parameters, S
/// streams) is exercised without artifacts or a backend.
pub fn demo_manifest() -> Arc<Manifest> {
    use crate::model::{Kind, TensorSpec};
    let tensors = vec![
        TensorSpec {
            name: "conv1.w".into(),
            shape: vec![8, 27],
            kind: Kind::ConvW,
            group: Group::Weight,
            layer: "conv1".into(),
            out_ch: Some(8),
            scale_for: None,
        },
        TensorSpec {
            name: "conv1.b".into(),
            shape: vec![8],
            kind: Kind::Bias,
            group: Group::Weight,
            layer: "conv1".into(),
            out_ch: Some(8),
            scale_for: None,
        },
        TensorSpec {
            name: "conv1.s".into(),
            shape: vec![8],
            kind: Kind::Scale,
            group: Group::Scale,
            layer: "conv1".into(),
            out_ch: Some(8),
            scale_for: Some("conv1.w".into()),
        },
        TensorSpec {
            name: "head.w".into(),
            shape: vec![2, 32],
            kind: Kind::DenseW,
            group: Group::Weight,
            layer: "head".into(),
            out_ch: Some(2),
            scale_for: None,
        },
        TensorSpec {
            name: "head.b".into(),
            shape: vec![2],
            kind: Kind::Bias,
            group: Group::Weight,
            layer: "head".into(),
            out_ch: Some(2),
            scale_for: None,
        },
    ];
    let param_count = tensors.iter().map(|t| t.numel()).sum();
    let m = Manifest {
        model: "synth".into(),
        variant: "synth".into(),
        classes: 2,
        input: vec![4, 4, 1],
        batch: 1,
        param_count,
        scale_count: 8,
        tensors,
    };
    debug_assert!(m.validate().is_ok(), "demo manifest must validate");
    Arc::new(m)
}

/// A larger self-contained model contract (~96k parameters, 10 classes)
/// for the bench plane's `--synth-model large` cells: same tensor-kind
/// coverage as [`demo_manifest`] but with enough rows per tensor that
/// codec throughput and wire volume dominate fixed per-round overhead.
pub fn large_manifest() -> Arc<Manifest> {
    use crate::model::{Kind, TensorSpec};
    let tensors = vec![
        TensorSpec {
            name: "conv1.w".into(),
            shape: vec![64, 27],
            kind: Kind::ConvW,
            group: Group::Weight,
            layer: "conv1".into(),
            out_ch: Some(64),
            scale_for: None,
        },
        TensorSpec {
            name: "conv1.b".into(),
            shape: vec![64],
            kind: Kind::Bias,
            group: Group::Weight,
            layer: "conv1".into(),
            out_ch: Some(64),
            scale_for: None,
        },
        TensorSpec {
            name: "conv1.s".into(),
            shape: vec![64],
            kind: Kind::Scale,
            group: Group::Scale,
            layer: "conv1".into(),
            out_ch: Some(64),
            scale_for: Some("conv1.w".into()),
        },
        TensorSpec {
            name: "conv2.w".into(),
            shape: vec![128, 576],
            kind: Kind::ConvW,
            group: Group::Weight,
            layer: "conv2".into(),
            out_ch: Some(128),
            scale_for: None,
        },
        TensorSpec {
            name: "conv2.b".into(),
            shape: vec![128],
            kind: Kind::Bias,
            group: Group::Weight,
            layer: "conv2".into(),
            out_ch: Some(128),
            scale_for: None,
        },
        TensorSpec {
            name: "conv2.s".into(),
            shape: vec![128],
            kind: Kind::Scale,
            group: Group::Scale,
            layer: "conv2".into(),
            out_ch: Some(128),
            scale_for: Some("conv2.w".into()),
        },
        TensorSpec {
            name: "head.w".into(),
            shape: vec![10, 2048],
            kind: Kind::DenseW,
            group: Group::Weight,
            layer: "head".into(),
            out_ch: Some(10),
            scale_for: None,
        },
        TensorSpec {
            name: "head.b".into(),
            shape: vec![10],
            kind: Kind::Bias,
            group: Group::Weight,
            layer: "head".into(),
            out_ch: Some(10),
            scale_for: None,
        },
    ];
    let param_count = tensors.iter().map(|t| t.numel()).sum();
    let m = Manifest {
        model: "synth-large".into(),
        variant: "synth".into(),
        classes: 10,
        input: vec![8, 8, 3],
        batch: 1,
        param_count,
        scale_count: 192,
        tensors,
    };
    debug_assert!(m.validate().is_ok(), "large manifest must validate");
    Arc::new(m)
}

/// Environment variable carrying the synthetic straggler schedule as
/// `EVERY:MS` (every EVERY-th client sleeps MS milliseconds in `train`).
/// An env var rather than a CLI flag so the setting propagates to
/// `shard-worker` child processes spawned by `--shard-procs` without
/// widening the worker handshake.
pub const STRAGGLE_ENV: &str = "FSFL_SYNTH_STRAGGLE";

/// Parse [`STRAGGLE_ENV`] into `(every, sleep_ms)`. Unset, empty, or
/// malformed values mean "no stragglers" — bench drivers set it, nothing
/// else should notice it exists.
pub fn straggle_from_env() -> Option<(usize, u64)> {
    let raw = std::env::var(STRAGGLE_ENV).ok()?;
    let (every, ms) = raw.split_once(':')?;
    let every: usize = every.trim().parse().ok()?;
    let ms: u64 = ms.trim().parse().ok()?;
    if every == 0 {
        return None;
    }
    Some((every, ms))
}

/// A [`ComputePlane`] whose training output is a pure function of
/// `(round_seed, client id)`. The driver sets [`Self::round_seed`]
/// before each round (the synthetic shard worker derives it from the
/// experiment seed and a per-round counter, identically on every shard).
pub struct SyntheticPlane {
    /// Model contract the synthetic deltas conform to.
    pub manifest: Arc<Manifest>,
    /// Per-round seed; combined with the client id per lane.
    pub round_seed: u64,
    /// Whether scale sub-epochs run (even-id clients keep an S update).
    pub scaled: bool,
    /// Straggler injection: every N-th client sleeps the given number of
    /// milliseconds in `train` (bench plane only; see [`STRAGGLE_ENV`]).
    /// Wall-clock only — the emitted delta bytes are unaffected, so
    /// bitstream fingerprints stay deterministic under stragglers.
    pub straggle: Option<(usize, u64)>,
}

impl ComputePlane for SyntheticPlane {
    fn train(&mut self, lane: &mut RoundLane) -> Result<()> {
        if let Some((every, ms)) = self.straggle {
            if lane.client % every == 0 {
                std::thread::sleep(std::time::Duration::from_millis(ms));
            }
        }
        synth_client_delta(
            &self.manifest,
            self.round_seed + lane.client as u64,
            &mut lane.raw,
        );
        Ok(())
    }

    fn scale(&mut self, lane: &mut RoundLane) -> Result<()> {
        // Client-intrinsic acceptance (by id parity, not round slot), so
        // the decision is independent of scheduling shape.
        if self.scaled && lane.client % 2 == 0 {
            synth_scale_delta(
                &self.manifest,
                self.round_seed + lane.client as u64,
                &mut lane.sdelta,
            );
            lane.scale_accepted = true;
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn large_manifest_validates_and_dwarfs_demo() {
        let small = demo_manifest();
        let large = large_manifest();
        assert!(large.validate().is_ok());
        assert!(large.param_count > 50 * small.param_count);
        assert_eq!(large.scale_count, 64 + 128);
    }

    #[test]
    fn straggle_env_parses_and_rejects_garbage() {
        // One test owns the variable end to end: process env is shared
        // across the test harness's threads.
        std::env::set_var(STRAGGLE_ENV, "3:25");
        assert_eq!(straggle_from_env(), Some((3, 25)));
        std::env::set_var(STRAGGLE_ENV, " 2 : 40 ");
        assert_eq!(straggle_from_env(), Some((2, 40)));
        for bad in ["", "3", "0:10", "a:b", "3:10:2", "-1:5"] {
            std::env::set_var(STRAGGLE_ENV, bad);
            assert_eq!(straggle_from_env(), None, "input {bad:?}");
        }
        std::env::remove_var(STRAGGLE_ENV);
        assert_eq!(straggle_from_env(), None);
    }
}
