//! Deterministic synthetic compute plane.
//!
//! The transport/scheduler test planes need a compute plane whose output
//! is a pure function of `(client id, round seed)` — no PJRT backend, no
//! artifacts directory — so the *protocol* machinery (scheduling,
//! sharded fan-in, wire transports, multi-process workers) can be
//! exercised byte-for-byte everywhere, including CI on the vendored null
//! XLA backend. [`SyntheticPlane`] is that plane: what a client "trains"
//! is seeded noise shaped like a real differential update (coarse
//! magnitudes on row-structured tensors, fine magnitudes on
//! scale/bias/BN tensors), and scale updates are accepted by client-id
//! parity so the decision is independent of scheduling shape.
//!
//! The synthetic shard worker (see `coordinator`) pairs this with
//! [`synth_eval`]: a central-model "evaluation" derived from the FNV
//! checksum of the accumulated broadcasts. Any single-bit divergence in
//! any aggregated broadcast — i.e. in any transmitted bitstream —
//! changes the reported accuracy, which is what lets the differential
//! conformance tests pin bitstream identity through nothing but
//! `RunLog` equality.

use std::sync::Arc;

use anyhow::Result;

use crate::data::XorShiftRng;
use crate::fl::scheduler::ComputePlane;
use crate::fl::server::EvalReport;
use crate::fl::RoundLane;
use crate::model::params::Delta;
use crate::model::{Group, Manifest};

/// Fill `out` with a seeded synthetic differential update: every tensor
/// is overwritten with Gaussian noise at coarse (row-structured) or fine
/// (scale/bias/BN) magnitude, mimicking a real post-training ΔW.
pub fn synth_client_delta(m: &Arc<Manifest>, seed: u64, out: &mut Delta) {
    let mut rng = XorShiftRng::new(seed);
    for (t, spec) in out.tensors.iter_mut().zip(&m.tensors) {
        let scale = if spec.kind.is_fine_quantized() { 5e-6 } else { 8e-4 };
        for x in t.iter_mut() {
            *x = rng.normal() * scale;
        }
    }
}

/// Fill `out` with a seeded synthetic S-only delta: zeros everywhere
/// except the scale-group tensors (the shape `RoundLane::finish_round`
/// expects in `sdelta`).
pub fn synth_scale_delta(m: &Arc<Manifest>, seed: u64, out: &mut Delta) {
    let mut rng = XorShiftRng::new(seed ^ 0x5CA1E);
    out.clear();
    for &si in &m.group_indices(Group::Scale) {
        for x in out.tensors[si].iter_mut() {
            *x = rng.normal() * 1e-4;
        }
    }
}

/// Deterministic stand-in for central-model evaluation: quality metrics
/// derived from the FNV checksum of the accumulated broadcast deltas.
/// A pure function of every byte the server ever aggregated, so two
/// deployments report equal accuracy iff their broadcast history is
/// bit-identical.
pub fn synth_eval(broadcast_accum: &Delta) -> EvalReport {
    let h = broadcast_accum.checksum();
    let unit = |x: u64| (x % 1_000_000) as f64 / 1e6;
    EvalReport {
        loss: unit(h.rotate_left(17)),
        accuracy: unit(h),
        f1: unit(h.rotate_left(31)),
    }
}

/// A small self-contained model contract for PJRT-free synthetic runs
/// (`fsfl run --synth` and the session/transport CI planes): two
/// row-structured weight tensors with biases and a per-filter scale
/// vector, so every codec path (coarse rows, fine side-parameters, S
/// streams) is exercised without artifacts or a backend.
pub fn demo_manifest() -> Arc<Manifest> {
    use crate::model::{Kind, TensorSpec};
    let tensors = vec![
        TensorSpec {
            name: "conv1.w".into(),
            shape: vec![8, 27],
            kind: Kind::ConvW,
            group: Group::Weight,
            layer: "conv1".into(),
            out_ch: Some(8),
            scale_for: None,
        },
        TensorSpec {
            name: "conv1.b".into(),
            shape: vec![8],
            kind: Kind::Bias,
            group: Group::Weight,
            layer: "conv1".into(),
            out_ch: Some(8),
            scale_for: None,
        },
        TensorSpec {
            name: "conv1.s".into(),
            shape: vec![8],
            kind: Kind::Scale,
            group: Group::Scale,
            layer: "conv1".into(),
            out_ch: Some(8),
            scale_for: Some("conv1.w".into()),
        },
        TensorSpec {
            name: "head.w".into(),
            shape: vec![2, 32],
            kind: Kind::DenseW,
            group: Group::Weight,
            layer: "head".into(),
            out_ch: Some(2),
            scale_for: None,
        },
        TensorSpec {
            name: "head.b".into(),
            shape: vec![2],
            kind: Kind::Bias,
            group: Group::Weight,
            layer: "head".into(),
            out_ch: Some(2),
            scale_for: None,
        },
    ];
    let param_count = tensors.iter().map(|t| t.numel()).sum();
    let m = Manifest {
        model: "synth".into(),
        variant: "synth".into(),
        classes: 2,
        input: vec![4, 4, 1],
        batch: 1,
        param_count,
        scale_count: 8,
        tensors,
    };
    debug_assert!(m.validate().is_ok(), "demo manifest must validate");
    Arc::new(m)
}

/// A [`ComputePlane`] whose training output is a pure function of
/// `(round_seed, client id)`. The driver sets [`Self::round_seed`]
/// before each round (the synthetic shard worker derives it from the
/// experiment seed and a per-round counter, identically on every shard).
pub struct SyntheticPlane {
    /// Model contract the synthetic deltas conform to.
    pub manifest: Arc<Manifest>,
    /// Per-round seed; combined with the client id per lane.
    pub round_seed: u64,
    /// Whether scale sub-epochs run (even-id clients keep an S update).
    pub scaled: bool,
}

impl ComputePlane for SyntheticPlane {
    fn train(&mut self, lane: &mut RoundLane) -> Result<()> {
        synth_client_delta(
            &self.manifest,
            self.round_seed + lane.client as u64,
            &mut lane.raw,
        );
        Ok(())
    }

    fn scale(&mut self, lane: &mut RoundLane) -> Result<()> {
        // Client-intrinsic acceptance (by id parity, not round slot), so
        // the decision is independent of scheduling shape.
        if self.scaled && lane.client % 2 == 0 {
            synth_scale_delta(
                &self.manifest,
                self.round_seed + lane.client as u64,
                &mut lane.sdelta,
            );
            lane.scale_accepted = true;
        }
        Ok(())
    }
}
