//! FL server: federated averaging of (decoded) client updates, optional
//! downstream compression, and central-model evaluation.

use anyhow::Result;

use crate::compression::UpdateCodec;
use crate::data::Batch;
use crate::metrics::Confusion;
use crate::model::params::Delta;
use crate::model::ParamSet;
use crate::runtime::ModelRuntime;

pub struct Server {
    pub params: ParamSet,
    pub downstream: Option<UpdateCodec>,
    update_idx: Vec<usize>,
}

/// Result of one aggregation.
pub struct AggregateOutput {
    /// The delta every client must apply (dequantized if bidirectional).
    pub broadcast: Delta,
    /// Downstream bytes **per client**.
    pub down_bytes_each: usize,
}

impl Server {
    pub fn new(params: ParamSet, downstream: Option<UpdateCodec>) -> Self {
        let update_idx = params.manifest.update_indices();
        Self {
            params,
            downstream,
            update_idx,
        }
    }

    /// Decode client bitstreams (the wire path every compressed protocol
    /// exercises). Plain-FedAvg outputs carry the update directly.
    pub fn decode_client(&self, out: &crate::fl::client::ClientRoundOutput) -> Result<Delta> {
        if out.streams.is_empty() {
            return Ok(out.update.clone());
        }
        let mut total = Delta::zeros(self.params.manifest.clone());
        for s in &out.streams {
            let d = crate::compression::decode_update(s, &self.params.manifest)?;
            total.accumulate(&d);
        }
        Ok(total)
    }

    /// FedAvg (line 24): ΔW_S = 1/|I| Σ Δ̂W_i, then optional downstream
    /// compression, then apply to the server model (line 25).
    pub fn aggregate(&mut self, updates: &[Delta]) -> AggregateOutput {
        assert!(!updates.is_empty());
        let mut avg = Delta::zeros(self.params.manifest.clone());
        let w = 1.0 / updates.len() as f32;
        for u in updates {
            avg.accumulate_scaled(u, w);
        }
        let (broadcast, down_bytes_each) = match &self.downstream {
            Some(codec) => {
                let (bytes, deq, _) = codec.encode(avg, &self.update_idx);
                (deq, bytes.len())
            }
            None => {
                let bytes = crate::compression::cabac::codec::raw_bytes(&self.params, &self.update_idx);
                (avg, bytes)
            }
        };
        self.params.add_delta(&broadcast);
        AggregateOutput {
            broadcast,
            down_bytes_each,
        }
    }

    /// Central-model evaluation: loss, top-1 accuracy and (via predictions)
    /// binary F1 for 2-class tasks.
    pub fn evaluate(&self, mr: &ModelRuntime, test: &[Batch]) -> Result<EvalReport> {
        let mut loss = 0.0f64;
        let mut correct = 0.0f64;
        let mut total = 0usize;
        let mut confusion = Confusion::default();
        let classes = self.params.manifest.classes;
        for b in test {
            let out = mr.eval_step(&self.params, &b.x, &b.y)?;
            loss += out.loss as f64 * b.size as f64;
            correct += out.correct as f64;
            total += b.size;
            if classes == 2 {
                let preds = mr.predict_step(&self.params, &b.x)?;
                for (bi, &p) in preds.iter().enumerate() {
                    let label = b.y[bi * classes..(bi + 1) * classes]
                        .iter()
                        .position(|&v| v == 1.0)
                        .unwrap_or(0);
                    confusion.add(p as usize, label, 0);
                }
            }
        }
        Ok(EvalReport {
            loss: if total == 0 { 0.0 } else { loss / total as f64 },
            accuracy: if total == 0 {
                0.0
            } else {
                correct / total as f64
            },
            f1: confusion.f1(),
        })
    }
}

#[derive(Debug, Clone, Copy, Default)]
pub struct EvalReport {
    pub loss: f64,
    pub accuracy: f64,
    pub f1: f64,
}
