//! FL server: federated averaging of (decoded) client updates, optional
//! downstream compression, and central-model evaluation.
//!
//! Aggregation runs through persistent buffers ([`Server::aggregate_into`]):
//! the running average, the downstream bitstream and the codec scratch
//! are all recycled, so the server side of a round allocates nothing in
//! steady state either.

use std::borrow::Borrow;

use anyhow::Result;

use crate::compression::cabac::codec::raw_bytes_of;
use crate::compression::{CodecScratch, UpdateCodec};
use crate::data::Batch;
use crate::metrics::Confusion;
use crate::model::params::Delta;
use crate::model::ParamSet;
use crate::runtime::ModelRuntime;

/// The central FL server: model state + aggregation machinery.
pub struct Server {
    /// The central model (every synced client replica equals this).
    pub params: ParamSet,
    /// Optional server→client broadcast codec (bidirectional setups).
    pub downstream: Option<UpdateCodec>,
    update_idx: Vec<usize>,
    /// Recycled FedAvg accumulator.
    avg: Delta,
    /// Recycled downstream bitstream + codec scratch.
    down_stream: Vec<u8>,
    scratch: CodecScratch,
}

/// Result of one aggregation.
pub struct AggregateOutput {
    /// The delta every client must apply (dequantized if bidirectional).
    pub broadcast: Delta,
    /// Downstream bytes **per client**.
    pub down_bytes_each: usize,
}

impl Server {
    /// Wrap the initial model state; `downstream` enables bidirectional
    /// (server→client) compression of the broadcast.
    pub fn new(params: ParamSet, downstream: Option<UpdateCodec>) -> Self {
        let update_idx = params.manifest.update_indices();
        let avg = Delta::zeros(params.manifest.clone());
        Self {
            params,
            downstream,
            update_idx,
            avg,
            down_stream: Vec::new(),
            scratch: CodecScratch::default(),
        }
    }

    /// FedAvg (line 24): ΔW_S = 1/|I| Σ Δ̂W_i, then optional downstream
    /// compression, then apply to the server model (line 25). The
    /// broadcast delta lands in the caller-owned `broadcast` buffer;
    /// returns downstream bytes per client. Accepts `&[Delta]` or
    /// `&[&Delta]` (the round loop aggregates straight out of the lanes).
    pub fn aggregate_into<D: Borrow<Delta>>(
        &mut self,
        updates: &[D],
        broadcast: &mut Delta,
    ) -> usize {
        assert!(!updates.is_empty());
        self.avg.clear();
        let w = 1.0 / updates.len() as f32;
        for u in updates {
            self.avg.accumulate_scaled(u.borrow(), w);
        }
        let down_bytes_each = match self.downstream {
            Some(codec) => {
                codec.encode_into(
                    &mut self.avg,
                    &self.update_idx,
                    &mut self.scratch,
                    broadcast,
                    &mut self.down_stream,
                );
                self.down_stream.len()
            }
            None => {
                broadcast.copy_from(&self.avg);
                raw_bytes_of(&self.params.manifest, &self.update_idx)
            }
        };
        self.params.add_delta(broadcast);
        down_bytes_each
    }

    /// The downstream bitstream produced by the most recent
    /// [`Server::aggregate_into`] (bidirectional setups only). This is
    /// the encode-once APPLY payload: the coordinator fans these exact
    /// bytes out to every shard instead of re-serializing the dense f32
    /// broadcast per shard, and shards decode them back into the
    /// identical dequantized delta (the codec round-trip invariant).
    pub fn downstream_bytes(&self) -> Option<&[u8]> {
        self.downstream.map(|_| self.down_stream.as_slice())
    }

    /// Allocating wrapper around [`Server::aggregate_into`].
    pub fn aggregate<D: Borrow<Delta>>(&mut self, updates: &[D]) -> AggregateOutput {
        let mut broadcast = Delta::zeros(self.params.manifest.clone());
        let down_bytes_each = self.aggregate_into(updates, &mut broadcast);
        AggregateOutput {
            broadcast,
            down_bytes_each,
        }
    }

    /// Central-model evaluation: loss, top-1 accuracy and (via predictions)
    /// binary F1 for 2-class tasks.
    pub fn evaluate(&self, mr: &ModelRuntime, test: &[Batch]) -> Result<EvalReport> {
        evaluate_params(mr, &self.params, test)
    }
}

/// Central-model evaluation of an arbitrary parameter set. A free
/// function (rather than a [`Server`] method) because in sharded
/// deployments evaluation runs on whichever compute thread owns a PJRT
/// runtime — against its synced client replica — while the server state
/// lives on the coordinator thread.
pub fn evaluate_params(mr: &ModelRuntime, params: &ParamSet, test: &[Batch]) -> Result<EvalReport> {
    let mut loss = 0.0f64;
    let mut correct = 0.0f64;
    let mut total = 0usize;
    let mut confusion = Confusion::default();
    let classes = params.manifest.classes;
    for b in test {
        let out = mr.eval_step(params, &b.x, &b.y)?;
        loss += out.loss as f64 * b.size as f64;
        correct += out.correct as f64;
        total += b.size;
        if classes == 2 {
            let preds = mr.predict_step(params, &b.x)?;
            for (bi, &p) in preds.iter().enumerate() {
                let label = b.y[bi * classes..(bi + 1) * classes]
                    .iter()
                    .position(|&v| v == 1.0)
                    .unwrap_or(0);
                confusion.add(p as usize, label, 0);
            }
        }
    }
    Ok(EvalReport {
        loss: if total == 0 { 0.0 } else { loss / total as f64 },
        accuracy: if total == 0 {
            0.0
        } else {
            correct / total as f64
        },
        f1: confusion.f1(),
    })
}

/// Central-model quality after one round.
#[derive(Debug, Clone, Copy, Default)]
pub struct EvalReport {
    /// Mean test loss.
    pub loss: f64,
    /// Top-1 test accuracy.
    pub accuracy: f64,
    /// Binary F1 (0.0 for tasks with more than two classes).
    pub f1: f64,
}
