//! The federated learning system: configuration, schedules, client/server
//! roles, and the [`Experiment`] driver that runs a full FL process and
//! produces a [`RunLog`].

pub mod client;
pub mod config;
pub mod schedule;
pub mod server;
#[cfg(test)]
mod tests;

pub use client::{Client, ClientRoundOutput};
pub use config::{ExperimentConfig, Protocol, ProtocolConfig};
pub use schedule::{LrSchedule, ScheduleKind};
pub use server::{EvalReport, Server};

use anyhow::{anyhow, Result};

use crate::data::{batches, iid_split, Batch, Dataset, TaskSpec};
use crate::metrics::{RoundMetrics, RunLog, ScaleStats};
use crate::model::Group;
use crate::runtime::{ModelRuntime, OptState, Runtime};

/// A fully-wired FL experiment over one model variant + task + protocol.
pub struct Experiment<'rt> {
    pub cfg: ExperimentConfig,
    pub mr: ModelRuntime<'rt>,
    pub server: Server,
    pub clients: Vec<Client>,
    pub train_data: Dataset,
    pub test_batches: Vec<Batch>,
}

impl<'rt> Experiment<'rt> {
    /// Build everything: runtime artifacts, synthetic task, client splits,
    /// initial synchronization (server and clients share init.bin).
    pub fn build(rt: &'rt Runtime, cfg: ExperimentConfig) -> Result<Self> {
        let mr = ModelRuntime::open(rt, &cfg.artifacts_root, &cfg.variant)?;
        let man = mr.manifest.clone();
        if man.classes != cfg.task.classes() {
            return Err(anyhow!(
                "variant {} has {} classes but task needs {}",
                cfg.variant,
                man.classes,
                cfg.task.classes()
            ));
        }
        let (h, _w, c) = (man.input[0], man.input[1], man.input[2]);
        let spec = TaskSpec::new(cfg.task, h, c, cfg.seed.wrapping_add(1));

        let per_client = cfg.train_per_client + cfg.val_per_client;
        let train_data = Dataset::generate(&spec, per_client * cfg.clients, 0);
        let test_data = Dataset::generate(&spec, cfg.test_samples, 1);
        let test_order: Vec<usize> = (0..test_data.len()).collect();
        let test_batches = batches(&test_data, &test_order, man.batch);

        let val_frac = cfg.val_per_client as f64 / per_client as f64;
        let split = match cfg.dirichlet_alpha {
            Some(alpha) => {
                crate::data::dirichlet_split(&train_data, cfg.clients, alpha, val_frac, cfg.seed)
            }
            None => iid_split(&train_data, cfg.clients, val_frac, cfg.seed),
        };

        let mut init = mr.init_params()?;

        // Optional warmup (pretraining substitute): a few server-side steps
        // on held-out data so FL starts from a non-random model.
        if cfg.warmup_steps > 0 {
            let warm = Dataset::generate(&spec, cfg.warmup_steps * man.batch, 2);
            let order: Vec<usize> = (0..warm.len()).collect();
            let mut wopt = OptState::zeros(&man, Group::Weight);
            for b in batches(&warm, &order, man.batch) {
                mr.train_step(&mut init, &mut wopt, cfg.optimizer, cfg.lr, &b.x, &b.y)?;
            }
        }

        let pcfg = cfg.protocol_config();
        let batches_per_epoch = (cfg.train_per_client / man.batch).max(1);
        let total_scale_steps = cfg.rounds * cfg.scale_epochs * batches_per_epoch;
        let period = cfg.scale_epochs * batches_per_epoch;

        let clients = split
            .train
            .iter()
            .zip(&split.val)
            .enumerate()
            .map(|(id, (tr, va))| {
                Client::new(
                    id,
                    init.clone(),
                    tr.clone(),
                    va.clone(),
                    LrSchedule::new(cfg.schedule, cfg.scale_lr, total_scale_steps, period),
                    pcfg.residuals,
                    cfg.seed ^ (id as u64 + 1),
                )
            })
            .collect();

        let server = Server::new(init, cfg.downstream_codec());
        Ok(Self {
            cfg,
            mr,
            server,
            clients,
            train_data,
            test_batches,
        })
    }

    /// Run the full FL process (Algorithm 1 outer loop), returning the
    /// per-round log all harnesses consume.
    pub fn run(&mut self) -> Result<RunLog> {
        self.run_with(|_| {})
    }

    /// Like [`Self::run`] but invoking `on_round` after every round (for
    /// live progress printing in the CLI/examples).
    pub fn run_with(&mut self, mut on_round: impl FnMut(&RoundMetrics)) -> Result<RunLog> {
        let pcfg = self.cfg.protocol_config();
        let mut log = RunLog::new(self.cfg.name.clone());
        for t in 0..self.cfg.rounds {
            let m = self.run_round(t, &pcfg)?;
            on_round(&m);
            let acc = m.accuracy;
            log.push(m);
            if let Some(target) = self.cfg.target_accuracy {
                if acc >= target {
                    break;
                }
            }
        }
        Ok(log)
    }

    fn run_round(&mut self, t: usize, pcfg: &ProtocolConfig) -> Result<RoundMetrics> {
        let mut updates = Vec::with_capacity(self.clients.len());
        let mut m = RoundMetrics {
            round: t,
            ..Default::default()
        };
        let mut sparsity_sum = 0.0;
        let mut rows_sum = 0.0;
        // Partial participation: a deterministic per-round subset.
        let n = self.clients.len();
        let take = ((self.cfg.participation * n as f64).round() as usize).clamp(1, n);
        let mut order: Vec<usize> = (0..n).collect();
        if take < n {
            let mut rng = crate::data::XorShiftRng::new(self.cfg.seed ^ (t as u64 + 0xF00D));
            rng.shuffle(&mut order);
        }
        let participants: Vec<usize> = order[..take].to_vec();
        for &ci in &participants {
            let client = &mut self.clients[ci];
            let out = client.run_round(&self.mr, &self.train_data, &self.cfg, pcfg)?;
            m.up_bytes += out.up_bytes;
            m.train_ms += out.train_ms;
            m.scale_ms += out.scale_ms;
            m.scale_accepted += out.scale_accepted as usize;
            let sp = out
                .update
                .sparsity_of(&self.server.params.manifest.update_indices());
            m.client_sparsity.push(sp);
            sparsity_sum += sp;
            if out.stats.rows_total > 0 {
                rows_sum += out.stats.rows_skipped as f64 / out.stats.rows_total as f64;
            }
            // the server decodes the actual bitstreams (wire-path fidelity)
            let decoded = self.server.decode_client(&out)?;
            debug_assert_eq!(decoded, out.update, "codec decode != client view");
            updates.push(decoded);
        }
        m.update_sparsity = sparsity_sum / participants.len() as f64;
        m.rows_skipped = rows_sum / participants.len() as f64;

        let agg = self.server.aggregate(&updates);
        m.down_bytes = agg.down_bytes_each * self.clients.len();
        for client in &mut self.clients {
            client.apply_broadcast(&agg.broadcast);
        }

        let report = self.server.evaluate(&self.mr, &self.test_batches)?;
        m.accuracy = report.accuracy;
        m.f1 = report.f1;
        m.test_loss = report.loss;

        // Fig. 3: per-layer scale statistics from client 0's replica
        if pcfg.scaled {
            m.scale_stats = self.clients[0]
                .scale_values()
                .into_iter()
                .map(|(layer, vals)| ScaleStats::from_values(&layer, &vals))
                .collect();
        }
        Ok(m)
    }

    /// Consistency invariant: every client replica must equal the server
    /// state after synchronization (checked by integration tests).
    pub fn replicas_in_sync(&self) -> bool {
        self.clients
            .iter()
            .all(|c| c.global == self.server.params)
    }
}
