//! The federated learning system: configuration, schedules, client/server
//! roles, and the [`Experiment`] driver that runs a full FL process and
//! produces a [`RunLog`].
//!
//! # Round pipeline: compute plane × codec plane
//!
//! Every round is staged so that the **compute plane** (PJRT step
//! execution — thread-affine, serial on the thread that owns the XLA
//! client) and the **codec plane** (per-client sparsify → quantize →
//! DeepCABAC encode, plus server-side decode — pure CPU, embarrassingly
//! parallel across clients) never block each other's scaling:
//!
//! ```text
//! stage 1  compute  local weight training per participant      (serial)
//! stage 2  codec    encode W updates                           (worker pool)
//! stage 3  compute  residual bookkeeping + scale sub-epochs    (serial)
//! stage 4  codec    encode S updates + wire decode + checksum  (worker pool)
//! stage 5  control  metrics, FedAvg, broadcast, central eval   (serial)
//! ```
//!
//! Codec work items are independent per client and deterministic, so
//! bitstreams and `RunLog` metrics are **identical for every pool size**
//! (pinned by `tests/integration_parallel.rs`). All per-round buffers
//! live in recycled [`RoundLane`]s — the codec path allocates nothing in
//! steady state.

pub mod client;
pub mod config;
pub mod lane;
pub mod schedule;
pub mod server;
#[cfg(test)]
mod tests;

pub use client::Client;
pub use config::{ExperimentConfig, Protocol, ProtocolConfig};
pub use lane::RoundLane;
pub use schedule::{LrSchedule, ScheduleKind};
pub use server::{EvalReport, Server};

use anyhow::{anyhow, Result};

use crate::data::{batches, iid_split, Batch, Dataset, TaskSpec};
use crate::exec::WorkerPool;
use crate::metrics::{RoundMetrics, RunLog, ScaleStats};
use crate::model::params::Delta;
use crate::model::Group;
use crate::runtime::{ModelRuntime, OptState, Runtime};

/// A fully-wired FL experiment over one model variant + task + protocol.
pub struct Experiment<'rt> {
    pub cfg: ExperimentConfig,
    pub mr: ModelRuntime<'rt>,
    pub server: Server,
    pub clients: Vec<Client>,
    pub train_data: Dataset,
    pub test_batches: Vec<Batch>,
    /// Codec-plane worker pool (width from `cfg.codec_workers`).
    pool: WorkerPool,
    /// One recycled lane per round participant.
    lanes: Vec<RoundLane>,
    /// Recycled broadcast-delta buffer.
    broadcast: Delta,
    /// Cached manifest index sets (computed once, not per round/client).
    update_idx: Vec<usize>,
    scale_idx: Vec<usize>,
    /// Recycled participant-selection buffer.
    order: Vec<usize>,
}

impl<'rt> Experiment<'rt> {
    /// Build everything: runtime artifacts, synthetic task, client splits,
    /// initial synchronization (server and clients share init.bin).
    pub fn build(rt: &'rt Runtime, cfg: ExperimentConfig) -> Result<Self> {
        let mr = ModelRuntime::open(rt, &cfg.artifacts_root, &cfg.variant)?;
        let man = mr.manifest.clone();
        if man.classes != cfg.task.classes() {
            return Err(anyhow!(
                "variant {} has {} classes but task needs {}",
                cfg.variant,
                man.classes,
                cfg.task.classes()
            ));
        }
        let (h, _w, c) = (man.input[0], man.input[1], man.input[2]);
        let spec = TaskSpec::new(cfg.task, h, c, cfg.seed.wrapping_add(1));

        let per_client = cfg.train_per_client + cfg.val_per_client;
        let train_data = Dataset::generate(&spec, per_client * cfg.clients, 0);
        let test_data = Dataset::generate(&spec, cfg.test_samples, 1);
        let test_order: Vec<usize> = (0..test_data.len()).collect();
        let test_batches = batches(&test_data, &test_order, man.batch);

        let val_frac = cfg.val_per_client as f64 / per_client as f64;
        let split = match cfg.dirichlet_alpha {
            Some(alpha) => {
                crate::data::dirichlet_split(&train_data, cfg.clients, alpha, val_frac, cfg.seed)
            }
            None => iid_split(&train_data, cfg.clients, val_frac, cfg.seed),
        };

        let mut init = mr.init_params()?;

        // Optional warmup (pretraining substitute): a few server-side steps
        // on held-out data so FL starts from a non-random model.
        if cfg.warmup_steps > 0 {
            let warm = Dataset::generate(&spec, cfg.warmup_steps * man.batch, 2);
            let order: Vec<usize> = (0..warm.len()).collect();
            let mut wopt = OptState::zeros(&man, Group::Weight);
            for b in batches(&warm, &order, man.batch) {
                mr.train_step(&mut init, &mut wopt, cfg.optimizer, cfg.lr, &b.x, &b.y)?;
            }
        }

        let pcfg = cfg.protocol_config();
        let batches_per_epoch = (cfg.train_per_client / man.batch).max(1);
        let total_scale_steps = cfg.rounds * cfg.scale_epochs * batches_per_epoch;
        let period = cfg.scale_epochs * batches_per_epoch;

        let clients: Vec<Client> = split
            .train
            .iter()
            .zip(&split.val)
            .enumerate()
            .map(|(id, (tr, va))| {
                Client::new(
                    id,
                    init.clone(),
                    tr.clone(),
                    va.clone(),
                    LrSchedule::new(cfg.schedule, cfg.scale_lr, total_scale_steps, period),
                    pcfg.residuals,
                    cfg.seed ^ (id as u64 + 1),
                )
            })
            .collect();

        // Participant count is constant given the config; size the lane
        // set once so rounds recycle buffers instead of allocating.
        let n = clients.len();
        let take = ((cfg.participation * n as f64).round() as usize).clamp(1, n);
        let lanes = (0..take).map(|_| RoundLane::new(man.clone())).collect();

        let server = Server::new(init, cfg.downstream_codec());
        Ok(Self {
            pool: WorkerPool::new(cfg.codec_workers),
            lanes,
            broadcast: Delta::zeros(man.clone()),
            update_idx: man.update_indices(),
            scale_idx: man.group_indices(Group::Scale),
            order: Vec::with_capacity(n),
            cfg,
            mr,
            server,
            clients,
            train_data,
            test_batches,
        })
    }

    /// Codec-plane pool width actually in use.
    pub fn codec_workers(&self) -> usize {
        self.pool.workers()
    }

    /// Run the full FL process (Algorithm 1 outer loop), returning the
    /// per-round log all harnesses consume.
    pub fn run(&mut self) -> Result<RunLog> {
        self.run_with(|_| {})
    }

    /// Like [`Self::run`] but invoking `on_round` after every round (for
    /// live progress printing in the CLI/examples).
    pub fn run_with(&mut self, mut on_round: impl FnMut(&RoundMetrics)) -> Result<RunLog> {
        let pcfg = self.cfg.protocol_config();
        let mut log = RunLog::new(self.cfg.name.clone());
        for t in 0..self.cfg.rounds {
            let m = self.run_round(t, &pcfg)?;
            on_round(&m);
            let acc = m.accuracy;
            log.push(m);
            if let Some(target) = self.cfg.target_accuracy {
                if acc >= target {
                    break;
                }
            }
        }
        Ok(log)
    }

    fn run_round(&mut self, t: usize, pcfg: &ProtocolConfig) -> Result<RoundMetrics> {
        let mut m = RoundMetrics {
            round: t,
            ..Default::default()
        };
        // Partial participation: a deterministic per-round subset.
        let n = self.clients.len();
        let take = self.lanes.len();
        self.order.clear();
        self.order.extend(0..n);
        if take < n {
            let mut rng = crate::data::XorShiftRng::new(self.cfg.seed ^ (t as u64 + 0xF00D));
            rng.shuffle(&mut self.order);
        }

        // ---- stage 1 · compute plane: local weight training (serial —
        //      the PJRT executables are thread-affine) ----
        for k in 0..take {
            let ci = self.order[k];
            self.lanes[k].begin(ci);
            self.clients[ci].train_round(&self.mr, &self.train_data, &self.cfg, &mut self.lanes[k])?;
        }

        // ---- stage 2 · codec plane: sparsify + quantize + encode the W
        //      updates, fanned out across the worker pool ----
        {
            let update_idx = &self.update_idx;
            self.pool.run_mut(&mut self.lanes[..take], |_, lane| {
                lane.encode_upstream(pcfg, update_idx)
            });
        }

        // ---- stage 3 · compute plane: residual bookkeeping + scale
        //      sub-epochs on Ŵ = W + Δ̂ (serial) ----
        for k in 0..take {
            let ci = self.lanes[k].client;
            self.clients[ci].scale_round(&self.mr, &self.train_data, &self.cfg, pcfg, &mut self.lanes[k])?;
        }

        // ---- stage 4 · codec plane: encode S streams + decode the actual
        //      bitstreams server-side (wire-path fidelity), in parallel ----
        {
            let scale_idx = &self.scale_idx;
            self.pool.run_mut(&mut self.lanes[..take], |_, lane| {
                lane.finish_round(pcfg, scale_idx)
            });
        }
        for lane in &mut self.lanes[..take] {
            if let Some(e) = lane.error.take() {
                return Err(e);
            }
        }

        // ---- stage 5 · control plane: metrics, FedAvg, broadcast, eval ----
        let mut sparsity_sum = 0.0;
        let mut rows_sum = 0.0;
        for lane in &self.lanes[..take] {
            m.up_bytes += lane.up_bytes;
            m.train_ms += lane.train_ms;
            m.scale_ms += lane.scale_ms;
            m.scale_accepted += lane.scale_accepted as usize;
            let sp = lane.update.sparsity_of(&self.update_idx);
            m.client_sparsity.push(sp);
            sparsity_sum += sp;
            if lane.stats.rows_total > 0 {
                rows_sum += lane.stats.rows_skipped as f64 / lane.stats.rows_total as f64;
            }
        }
        m.update_sparsity = sparsity_sum / take as f64;
        m.rows_skipped = rows_sum / take as f64;

        let updates: Vec<&Delta> = self.lanes[..take].iter().map(|l| &l.decoded).collect();
        let down_bytes_each = self.server.aggregate_into(&updates, &mut self.broadcast);
        m.down_bytes = down_bytes_each * self.clients.len();
        for client in &mut self.clients {
            client.apply_broadcast(&self.broadcast);
        }

        let report = self.server.evaluate(&self.mr, &self.test_batches)?;
        m.accuracy = report.accuracy;
        m.f1 = report.f1;
        m.test_loss = report.loss;

        // Fig. 3: per-layer scale statistics from client 0's replica
        if pcfg.scaled {
            m.scale_stats = self.clients[0]
                .scale_values()
                .into_iter()
                .map(|(layer, vals)| ScaleStats::from_values(&layer, &vals))
                .collect();
        }
        Ok(m)
    }

    /// Consistency invariant: every client replica must equal the server
    /// state after synchronization (checked by integration tests).
    pub fn replicas_in_sync(&self) -> bool {
        self.clients
            .iter()
            .all(|c| c.global == self.server.params)
    }
}
