//! The federated learning system: configuration, schedules, client/server
//! roles, the round [`scheduler`], and the [`Experiment`] driver that runs
//! a full FL process and produces a [`RunLog`].
//!
//! # Round pipeline: compute plane × codec plane × scheduler
//!
//! Every round consists of **compute plane** work (PJRT step execution —
//! thread-affine, serial on the thread that owns the XLA client) and
//! **codec plane** work (per-client sparsify → quantize → DeepCABAC
//! encode, plus server-side decode — pure CPU, embarrassingly parallel
//! across clients). The [`scheduler`] decides how the two interleave:
//!
//! ```text
//! staged    stage 1  compute  local weight training per participant  (serial)
//!           stage 2  codec    encode W updates                       (worker pool)
//!           stage 3  compute  residual bookkeeping + scale epochs    (serial)
//!           stage 4  codec    encode S + wire decode + checksum      (worker pool)
//!           stage 5  control  metrics, FedAvg, broadcast, eval       (serial)
//!
//! pipelined client k's codec stages overlap client k+1's compute
//!           stages (same stage 5); see `fl/scheduler.rs` for the
//!           timeline diagram
//! ```
//!
//! Codec work items are independent per client and deterministic, so
//! bitstreams and `RunLog` metrics are **identical for every pool size,
//! both schedule modes, and every shard count** (pinned by
//! `tests/integration_parallel.rs`). All per-round buffers live in
//! recycled [`RoundLane`]s — the codec path allocates nothing in steady
//! state (pipelined mode adds a handful of small queue/ticket
//! allocations per round, never model-sized buffers).
//!
//! Multi-tenant scale: `coordinator::run_experiment_sharded` shards
//! clients across N compute workers (one PJRT client per shard —
//! threads over in-process channels, or OS processes speaking the
//! framed wire protocol in `crate::net`) and fans their lanes back
//! into the same ordered reduction; see `ARCHITECTURE.md`.

pub mod client;
pub mod config;
pub mod lane;
pub mod schedule;
pub mod scheduler;
pub mod server;
pub mod synth;
#[cfg(test)]
mod tests;

pub use client::{Client, ClientState, OptSnapshot};
pub use config::{ExperimentConfig, OnShardLoss, Protocol, ProtocolConfig, RoundPolicy, SessionConfig, TransportKind};
pub use lane::{LaneParts, RoundLane};
pub use schedule::{LrSchedule, ScheduleKind};
pub use scheduler::{ComputePlane, ScheduleMode};
pub use server::{evaluate_params, EvalReport, Server};
pub use synth::SyntheticPlane;

use anyhow::{anyhow, Result};

use crate::data::{batches, iid_split, Batch, Dataset, TaskSpec};
use crate::exec::WorkerPool;
use crate::metrics::{RoundMetrics, RunLog, ScaleStats};
use crate::model::params::Delta;
use crate::model::{Group, ParamSet};
use crate::runtime::{ModelRuntime, OptState, Runtime};

/// A fully-wired FL experiment over one model variant + task + protocol.
pub struct Experiment<'rt> {
    /// The experiment description this instance was built from.
    pub cfg: ExperimentConfig,
    /// Compiled step executables for the model variant (thread-affine).
    pub mr: ModelRuntime<'rt>,
    /// Central server state (FedAvg accumulator + broadcast codec).
    pub server: Server,
    /// All clients, indexed by client id.
    pub clients: Vec<Client>,
    /// The pooled training data every client split indexes into.
    pub train_data: Dataset,
    /// Central evaluation batches (fixed across rounds).
    pub test_batches: Vec<Batch>,
    /// Codec-plane worker pool (width from `cfg.codec_workers`).
    pool: WorkerPool,
    /// One recycled lane per round participant.
    lanes: Vec<RoundLane>,
    /// Recycled broadcast-delta buffer.
    broadcast: Delta,
    /// Cached manifest index sets (computed once, not per round/client).
    update_idx: Vec<usize>,
    scale_idx: Vec<usize>,
    /// Recycled participant-selection buffer.
    order: Vec<usize>,
    /// Telemetry handle (strictly passive; `None` keeps the round loop
    /// allocation-free and branch-cheap).
    obs: crate::obs::Obs,
}

/// The deterministic substrate every FL deployment shape shares: task
/// spec, datasets, client splits, and the (optionally warmed-up) initial
/// model plus client set. Extracted from [`Experiment::build`] so the
/// sharded coordinator constructs byte-identical state per shard —
/// `keep` filters which client ids this process actually instantiates.
pub(crate) struct ExperimentSetup {
    pub train_data: Dataset,
    pub test_batches: Vec<Batch>,
    pub init: ParamSet,
    /// The kept clients, ascending by (global) client id.
    pub clients: Vec<Client>,
}

/// Build the shared experiment substrate. Everything here is a pure
/// function of `cfg` (datasets, splits, schedules) plus the runtime's
/// deterministic init/warmup, so two calls with the same `cfg` — in the
/// same process or across shard threads — produce identical state.
pub(crate) fn build_setup(
    mr: &ModelRuntime,
    cfg: &ExperimentConfig,
    keep: impl Fn(usize) -> bool,
) -> Result<ExperimentSetup> {
    let man = mr.manifest.clone();
    if man.classes != cfg.task.classes() {
        return Err(anyhow!(
            "variant {} has {} classes but task needs {}",
            cfg.variant,
            man.classes,
            cfg.task.classes()
        ));
    }
    let (h, _w, c) = (man.input[0], man.input[1], man.input[2]);
    let spec = TaskSpec::new(cfg.task, h, c, cfg.seed.wrapping_add(1));

    let per_client = cfg.train_per_client + cfg.val_per_client;
    let train_data = Dataset::generate(&spec, per_client * cfg.clients, 0);
    let test_data = Dataset::generate(&spec, cfg.test_samples, 1);
    let test_order: Vec<usize> = (0..test_data.len()).collect();
    let test_batches = batches(&test_data, &test_order, man.batch);

    let val_frac = cfg.val_per_client as f64 / per_client as f64;
    let split = match cfg.dirichlet_alpha {
        Some(alpha) => {
            crate::data::dirichlet_split(&train_data, cfg.clients, alpha, val_frac, cfg.seed)
        }
        None => iid_split(&train_data, cfg.clients, val_frac, cfg.seed),
    };

    let mut init = mr.init_params()?;

    // Optional warmup (pretraining substitute): a few server-side steps
    // on held-out data so FL starts from a non-random model.
    if cfg.warmup_steps > 0 {
        let warm = Dataset::generate(&spec, cfg.warmup_steps * man.batch, 2);
        let order: Vec<usize> = (0..warm.len()).collect();
        let mut wopt = OptState::zeros(&man, Group::Weight);
        for b in batches(&warm, &order, man.batch) {
            mr.train_step(&mut init, &mut wopt, cfg.optimizer, cfg.lr, &b.x, &b.y)?;
        }
    }

    let pcfg = cfg.protocol_config();
    let batches_per_epoch = (cfg.train_per_client / man.batch).max(1);
    let total_scale_steps = cfg.rounds * cfg.scale_epochs * batches_per_epoch;
    let period = cfg.scale_epochs * batches_per_epoch;

    let clients: Vec<Client> = split
        .train
        .iter()
        .zip(&split.val)
        .enumerate()
        .filter(|(id, _)| keep(*id))
        .map(|(id, (tr, va))| {
            Client::new(
                id,
                init.clone(),
                tr.clone(),
                va.clone(),
                LrSchedule::new(cfg.schedule, cfg.scale_lr, total_scale_steps, period),
                pcfg.residuals,
                cfg.seed ^ (id as u64 + 1),
            )
        })
        .collect();

    Ok(ExperimentSetup {
        train_data,
        test_batches,
        init,
        clients,
    })
}

/// [`scheduler::ComputePlane`] over a (possibly sharded) client set:
/// slot-ordered training and scale sub-epochs on the thread that owns
/// the PJRT runtime. `clients` holds the locally-instantiated clients of
/// one shard — under round-robin ownership global client `ci` lives at
/// local index `ci / shards` (the fast path; the single-process
/// [`Experiment`] is the `shards == 1` identity case), but quorum
/// degradation can fold foreign clients into a survivor shard, so an
/// id search backs the arithmetic up.
pub(crate) struct ExperimentCompute<'a, 'rt> {
    pub mr: &'a ModelRuntime<'rt>,
    pub clients: &'a mut [Client],
    /// Total compute-shard count (1 = unsharded).
    pub shards: usize,
    pub train_data: &'a Dataset,
    pub cfg: &'a ExperimentConfig,
    pub pcfg: &'a ProtocolConfig,
}

impl ExperimentCompute<'_, '_> {
    /// Local index of global client `ci` (see the struct docs).
    fn local_of(&self, ci: usize) -> Result<usize> {
        let guess = ci / self.shards;
        if self.clients.get(guess).is_some_and(|c| c.id == ci) {
            return Ok(guess);
        }
        self.clients
            .iter()
            .position(|c| c.id == ci)
            .ok_or_else(|| anyhow::anyhow!("client {ci} is not owned by this shard"))
    }
}

impl ComputePlane for ExperimentCompute<'_, '_> {
    fn train(&mut self, lane: &mut RoundLane) -> Result<()> {
        let local = self.local_of(lane.client)?;
        self.clients[local].train_round(self.mr, self.train_data, self.cfg, lane)
    }

    fn scale(&mut self, lane: &mut RoundLane) -> Result<()> {
        let local = self.local_of(lane.client)?;
        self.clients[local].scale_round(self.mr, self.train_data, self.cfg, self.pcfg, lane)
    }
}

impl<'rt> Experiment<'rt> {
    /// Build everything: runtime artifacts, synthetic task, client splits,
    /// initial synchronization (server and clients share init.bin).
    pub fn build(rt: &'rt Runtime, cfg: ExperimentConfig) -> Result<Self> {
        let mr = ModelRuntime::open(rt, &cfg.artifacts_root, &cfg.variant)?;
        let setup = build_setup(&mr, &cfg, |_| true)?;
        let man = mr.manifest.clone();

        // Participant count is constant given the config; size the lane
        // set once so rounds recycle buffers instead of allocating.
        let n = setup.clients.len();
        let take = ((cfg.participation * n as f64).round() as usize).clamp(1, n);
        let lanes = (0..take).map(|_| RoundLane::new(man.clone())).collect();

        let server = Server::new(setup.init, cfg.downstream_codec());
        Ok(Self {
            pool: WorkerPool::new(cfg.codec_workers),
            lanes,
            broadcast: Delta::zeros(man.clone()),
            update_idx: man.update_indices(),
            scale_idx: man.group_indices(Group::Scale),
            order: Vec::with_capacity(n),
            cfg,
            mr,
            server,
            clients: setup.clients,
            train_data: setup.train_data,
            test_batches: setup.test_batches,
            obs: None,
        })
    }

    /// Attach a telemetry handle: rounds and codec stages record spans
    /// and live counters from here on. Telemetry never feeds back into
    /// the run — outputs stay byte-identical to an unobserved run.
    pub fn set_telemetry(&mut self, obs: std::sync::Arc<crate::obs::Telemetry>) {
        obs.metrics.set_model_params(self.server.params.numel());
        self.obs = Some(obs);
    }

    /// Codec-plane pool width actually in use.
    pub fn codec_workers(&self) -> usize {
        self.pool.workers()
    }

    /// Run the full FL process (Algorithm 1 outer loop), returning the
    /// per-round log all harnesses consume.
    pub fn run(&mut self) -> Result<RunLog> {
        self.run_with(|_| {})
    }

    /// Like [`Self::run`] but invoking `on_round` after every round (for
    /// live progress printing in the CLI/examples).
    pub fn run_with(&mut self, mut on_round: impl FnMut(&RoundMetrics)) -> Result<RunLog> {
        let pcfg = self.cfg.protocol_config();
        let mut log = RunLog::new(self.cfg.name.clone());
        for t in 0..self.cfg.rounds {
            let round_t0 = self.obs.as_ref().map(|ob| {
                ob.set_round(t as i64);
                ob.now_ns()
            });
            let m = self.run_round(t, &pcfg)?;
            on_round(&m);
            let acc = m.accuracy;
            if let (Some(ob), Some(t0)) = (&self.obs, round_t0) {
                ob.metrics.record_round(&m);
                ob.span(crate::obs::track::COORDINATOR, "round", t0, -1, -1);
            }
            log.push(m);
            if let Some(target) = self.cfg.target_accuracy {
                if acc >= target {
                    break;
                }
            }
        }
        if let Some(ob) = &self.obs {
            ob.set_round(-1);
        }
        Ok(log)
    }

    fn run_round(&mut self, t: usize, pcfg: &ProtocolConfig) -> Result<RoundMetrics> {
        let mut m = RoundMetrics {
            round: t,
            ..Default::default()
        };
        // Partial participation: a deterministic per-round subset.
        let n = self.clients.len();
        let take = self.lanes.len();
        scheduler::select_participants(self.cfg.seed, t, n, take, &mut self.order);

        // ---- stages 1–4 · the scheduler interleaves compute plane and
        //      codec plane per `cfg.pipelined` (byte-identical outputs
        //      either way) ----
        let mode = self.cfg.schedule_mode();
        {
            let mut compute = ExperimentCompute {
                mr: &self.mr,
                clients: &mut self.clients,
                shards: 1,
                train_data: &self.train_data,
                cfg: &self.cfg,
                pcfg,
            };
            scheduler::run_round_observed(
                mode,
                &self.pool,
                &mut compute,
                &mut self.lanes,
                &self.order,
                pcfg,
                &self.update_idx,
                &self.scale_idx,
                self.obs.as_deref(),
            )?;
        }
        for lane in &mut self.lanes {
            if let Some(e) = lane.error.take() {
                return Err(e);
            }
        }

        // ---- stage 5 · control plane: metrics, FedAvg, broadcast, eval ----
        scheduler::collect_lane_metrics(&mut m, self.lanes.iter(), &self.update_idx);

        let updates: Vec<&Delta> = self.lanes.iter().map(|l| &l.decoded).collect();
        let down_bytes_each = self.server.aggregate_into(&updates, &mut self.broadcast);
        m.down_bytes = down_bytes_each * self.clients.len();
        for client in &mut self.clients {
            client.apply_broadcast(&self.broadcast);
        }

        let report = self.server.evaluate(&self.mr, &self.test_batches)?;
        m.accuracy = report.accuracy;
        m.f1 = report.f1;
        m.test_loss = report.loss;

        // Fig. 3: per-layer scale statistics from client 0's replica
        if pcfg.scaled {
            m.scale_stats = self.clients[0]
                .scale_values()
                .into_iter()
                .map(|(layer, vals)| ScaleStats::from_values(&layer, &vals))
                .collect();
        }
        Ok(m)
    }

    /// Consistency invariant: every client replica must equal the server
    /// state after synchronization (checked by integration tests).
    pub fn replicas_in_sync(&self) -> bool {
        self.clients
            .iter()
            .all(|c| c.global == self.server.params)
    }
}
