//! Round scheduler: how one FL round's compute-plane and codec-plane
//! work is ordered across threads.
//!
//! The scheduler owns three decisions, all behind one entry point
//! ([`run_round`]):
//!
//! * **Participant selection** ([`select_participants`]) — the
//!   deterministic per-round subset under partial participation. One
//!   shared implementation so the single-process [`crate::fl::Experiment`]
//!   and the sharded coordinator can never diverge.
//! * **Stage interleaving** ([`ScheduleMode`]) — `Staged` runs the four
//!   round stages back to back (compute, codec, compute, codec; PR 1
//!   behavior), while `Pipelined` software-pipelines across clients:
//!   client *k*'s sparsify → quantize → encode (and later its
//!   encode-S + wire decode) executes on the [`WorkerPool`] while client
//!   *k+1* trains on the calling thread. The compute plane stays on the
//!   caller because PJRT executables are thread-affine.
//! * **Lane ownership** — each participant owns exactly one
//!   [`RoundLane`] for the whole round. In pipelined mode the lane
//!   *moves* into the codec job and back (no sharing, no locks), which
//!   is what makes the overlap race-free by construction.
//!
//! ```text
//! staged      compute:  T0 T1 T2 T3 ............ S0 S1 S2 S3
//!             codec:                E0 E1 E2 E3              F0 F1 F2 F3
//!
//! pipelined   compute:  T0 T1 T2 T3 S0 S1 S2 S3
//!             codec:       E0 E1 E2 E3 F0 F1 F2 F3
//!                          (T = train, E = encode W, S = scale epochs,
//!                           F = encode S + wire decode)
//! ```
//!
//! **Determinism invariant.** Every codec stage is a pure function of
//! its lane, and the compute stages run in slot order on one thread in
//! both modes, so bitstreams and `RunLog` metrics are byte-identical
//! for every [`ScheduleMode`], every pool width, and every shard count
//! (pinned by `tests/integration_parallel.rs`). Server aggregation
//! consumes lanes in slot order — an *ordered reduction* — which is why
//! sharded fan-in goes through [`fan_in`] instead of arrival order.
//!
//! The compute side is abstracted as [`ComputePlane`] so the scheduler
//! can be driven by the real PJRT-backed clients, by a per-shard client
//! subset (see `coordinator::run_experiment_sharded`), or by synthetic
//! compute in tests and benches.

use anyhow::Result;

use crate::data::XorShiftRng;
use crate::exec::WorkerPool;
use crate::fl::config::ProtocolConfig;
use crate::fl::lane::RoundLane;
use crate::metrics::RoundMetrics;
use crate::obs::{track, Telemetry};

/// How the round scheduler interleaves compute-plane and codec-plane
/// work. Both modes produce byte-identical outputs; they differ only in
/// wall-clock overlap.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum ScheduleMode {
    /// Four back-to-back stages: all trains, all W encodes, all scale
    /// sub-epochs, all S encodes + wire decodes (PR 1 behavior).
    #[default]
    Staged,
    /// Software pipelining across clients: codec work for client *k*
    /// overlaps compute for client *k+1* via [`WorkerPool::pipeline`].
    Pipelined,
}

/// The compute-plane half of one round, abstracted over who owns the
/// clients. Implementations must be deterministic per client: the
/// scheduler may reorder *codec* work freely, but it always invokes
/// `train`/`scale` in slot order on the calling thread.
pub trait ComputePlane {
    /// Stage 1 for one participant: local weight training + raw
    /// differential update (with residual injected) into `lane.raw`.
    /// `lane.client` identifies the participant.
    fn train(&mut self, lane: &mut RoundLane) -> Result<()>;

    /// Stage 3 for one participant: residual bookkeeping + scale
    /// sub-epochs; stages the S-only delta in `lane.sdelta` and sets
    /// `lane.scale_accepted` when a scale update is kept.
    fn scale(&mut self, lane: &mut RoundLane) -> Result<()>;
}

/// splitmix64 finalizer (Steele et al.): a full-avalanche u64 mixer, so
/// every input bit affects every output bit.
fn splitmix64(mut z: u64) -> u64 {
    z = z.wrapping_add(0x9E37_79B9_7F4A_7C15);
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

/// Per-round selection seed: the `(seed, round)` pair routed through
/// splitmix64 so nearby experiment seeds and rounds land on unrelated
/// shuffle streams. The previous `seed ^ (round + 0xF00D)` derivation
/// made distinct pairs collide outright — e.g. `(seed, round)` and
/// `(seed ^ (round + 0xF00D) ^ (round' + 0xF00D), round')` selected the
/// *same* participants — so sweeps over adjacent seeds produced
/// correlated (or identical) participation schedules across runs.
pub fn round_selection_seed(seed: u64, round: usize) -> u64 {
    splitmix64(seed ^ splitmix64(round as u64))
}

/// Deterministic per-round participant selection under partial
/// participation. Fills `order` with the participating client ids, one
/// per round slot (`order.len() == take` afterwards). With full
/// participation (`take == clients`) the order is the identity; with a
/// subset it is a shuffle of all clients truncated to `take`, seeded by
/// [`round_selection_seed`] — shared between the single-process
/// experiment and the sharded coordinator so they can never diverge.
pub fn select_participants(
    seed: u64,
    round: usize,
    clients: usize,
    take: usize,
    order: &mut Vec<usize>,
) {
    order.clear();
    order.extend(0..clients);
    if take < clients {
        let mut rng = XorShiftRng::new(round_selection_seed(seed, round));
        rng.shuffle(order);
    }
    order.truncate(take);
}

/// Static shard ownership: client `client` trains on shard
/// `client % shards`. Round-robin keeps shard loads balanced for every
/// contiguous client-id range and makes the local index computable as
/// `client / shards`.
pub fn shard_of(client: usize, shards: usize) -> usize {
    client % shards.max(1)
}

/// Ordered fan-in reduction for sharded rounds: merge per-shard lane
/// sets (each tagged with its global round slot) back into slot order,
/// so downstream aggregation and metrics see exactly the order a
/// single-shard round would produce. Slot tags are kept so the caller
/// can route each lane back to its owning shard afterwards.
///
/// This is also the ordering guarantee of the **wire** deployments
/// (`crate::net`): slot tags travel inside each `ROUND_DONE` frame, so
/// whether lanes arrive as moved structs from threads or as decoded
/// frames from TCP peers — in whatever interleaving the transport
/// produces — the reduction order is a pure function of the round's
/// participant selection, never of arrival order.
pub fn fan_in(mut parts: Vec<(usize, RoundLane)>) -> Vec<(usize, RoundLane)> {
    parts.sort_by_key(|(slot, _)| *slot);
    parts
}

/// Run the compute + codec stages of one round over `lanes` (one lane
/// per participant; `order[k]` is slot `k`'s client id). On return every
/// lane holds its encoded streams, the server-side decode and the round
/// bookkeeping; codec-stage failures are parked in `lane.error` for the
/// caller to surface. Compute errors abort (pipelined mode still drains
/// in-flight codec jobs first so no lane is lost).
pub fn run_round<C: ComputePlane>(
    mode: ScheduleMode,
    pool: &WorkerPool,
    compute: &mut C,
    lanes: &mut Vec<RoundLane>,
    order: &[usize],
    pcfg: &ProtocolConfig,
    update_idx: &[usize],
    scale_idx: &[usize],
) -> Result<()> {
    run_round_observed(
        mode, pool, compute, lanes, order, pcfg, update_idx, scale_idx, None,
    )
}

/// [`run_round`] with an optional telemetry handle: per-client
/// `compute.train` / `compute.scale` / `codec.encode_w` /
/// `codec.finish` spans land on the codec track. `obs = None` (the
/// [`run_round`] path) makes every instrumentation site a single
/// branch — the zero-allocation hot-path contract of
/// `benches/fl_round.rs` is measured against exactly that path.
#[allow(clippy::too_many_arguments)]
pub fn run_round_observed<C: ComputePlane>(
    mode: ScheduleMode,
    pool: &WorkerPool,
    compute: &mut C,
    lanes: &mut Vec<RoundLane>,
    order: &[usize],
    pcfg: &ProtocolConfig,
    update_idx: &[usize],
    scale_idx: &[usize],
    obs: Option<&Telemetry>,
) -> Result<()> {
    assert_eq!(
        lanes.len(),
        order.len(),
        "scheduler: one recycled lane per participant"
    );
    match mode {
        ScheduleMode::Staged => {
            run_staged(pool, compute, lanes, order, pcfg, update_idx, scale_idx, obs)
        }
        ScheduleMode::Pipelined => {
            run_pipelined(pool, compute, lanes, order, pcfg, update_idx, scale_idx, obs)
        }
    }
}

/// PR 1's staged schedule: barrier between every stage.
// fsfl-lint: hot
#[allow(clippy::too_many_arguments)]
fn run_staged<C: ComputePlane>(
    pool: &WorkerPool,
    compute: &mut C,
    lanes: &mut Vec<RoundLane>,
    order: &[usize],
    pcfg: &ProtocolConfig,
    update_idx: &[usize],
    scale_idx: &[usize],
    obs: Option<&Telemetry>,
) -> Result<()> {
    // stage 1 · compute: local weight training, serial in slot order
    for (k, lane) in lanes.iter_mut().enumerate() {
        lane.begin(order[k]);
        let t0 = obs.map(|o| o.now_ns());
        compute.train(lane)?;
        if let (Some(o), Some(t0)) = (obs, t0) {
            o.span(track::CODEC, "compute.train", t0, lane.client as i64, -1);
        }
    }
    // stage 2 · codec: encode W updates, fanned out
    pool.run_mut(&mut lanes[..], |_, lane| {
        let t0 = obs.map(|o| o.now_ns());
        lane.encode_upstream(pcfg, update_idx);
        if let (Some(o), Some(t0)) = (obs, t0) {
            o.span(
                track::CODEC,
                "codec.encode_w",
                t0,
                lane.client as i64,
                lane.up_bytes as i64,
            );
        }
    });
    // stage 3 · compute: residuals + scale sub-epochs, serial
    for lane in lanes.iter_mut() {
        let t0 = obs.map(|o| o.now_ns());
        compute.scale(lane)?;
        if let (Some(o), Some(t0)) = (obs, t0) {
            o.span(track::CODEC, "compute.scale", t0, lane.client as i64, -1);
        }
    }
    // stage 4 · codec: encode S streams + wire decode, fanned out
    pool.run_mut(&mut lanes[..], |_, lane| {
        let t0 = obs.map(|o| o.now_ns());
        lane.finish_round(pcfg, scale_idx);
        if let (Some(o), Some(t0)) = (obs, t0) {
            o.span(
                track::CODEC,
                "codec.finish",
                t0,
                lane.client as i64,
                lane.up_bytes as i64,
            );
        }
    });
    Ok(())
}
// fsfl-lint: end-hot

/// The software-pipelined schedule: lanes move into owned codec jobs on
/// the pool while the calling thread keeps training/scaling later slots.
#[allow(clippy::too_many_arguments)]
fn run_pipelined<C: ComputePlane>(
    pool: &WorkerPool,
    compute: &mut C,
    lanes: &mut Vec<RoundLane>,
    order: &[usize],
    pcfg: &ProtocolConfig,
    update_idx: &[usize],
    scale_idx: &[usize],
    obs: Option<&Telemetry>,
) -> Result<()> {
    /// One owned codec job: the lane travels with its stage tag.
    enum Job {
        Encode(RoundLane),
        Finish(RoundLane),
    }

    let take = order.len();
    let mut slots: Vec<Option<RoundLane>> = lanes.drain(..).map(Some).collect();
    let mut enc_tickets = vec![0usize; take];
    let mut fin_tickets = vec![0usize; take];
    // Compute errors are buffered (not early-returned) so every lane
    // still flows through both codec hops and lands back in its slot;
    // codec work on a stale lane is deterministic and harmless.
    let mut err: Option<anyhow::Error> = None;

    pool.pipeline(
        |job: Job| match job {
            Job::Encode(mut lane) => {
                let t0 = obs.map(|o| o.now_ns());
                lane.encode_upstream(pcfg, update_idx);
                if let (Some(o), Some(t0)) = (obs, t0) {
                    o.span(
                        track::CODEC,
                        "codec.encode_w",
                        t0,
                        lane.client as i64,
                        lane.up_bytes as i64,
                    );
                }
                Job::Encode(lane)
            }
            Job::Finish(mut lane) => {
                let t0 = obs.map(|o| o.now_ns());
                lane.finish_round(pcfg, scale_idx);
                if let (Some(o), Some(t0)) = (obs, t0) {
                    o.span(
                        track::CODEC,
                        "codec.finish",
                        t0,
                        lane.client as i64,
                        lane.up_bytes as i64,
                    );
                }
                Job::Finish(lane)
            }
        },
        |h| {
            // Stages 1+2 interleaved: encode slot k overlaps train k+1…
            for k in 0..take {
                let mut lane = slots[k].take().expect("lane taken twice");
                lane.begin(order[k]);
                if err.is_none() {
                    let t0 = obs.map(|o| o.now_ns());
                    if let Err(e) = compute.train(&mut lane) {
                        err = Some(e);
                    }
                    if let (Some(o), Some(t0)) = (obs, t0) {
                        o.span(track::CODEC, "compute.train", t0, lane.client as i64, -1);
                    }
                }
                enc_tickets[k] = h.submit(Job::Encode(lane));
            }
            // Stages 3+4 interleaved: finish slot k overlaps scale k+1…
            for k in 0..take {
                let mut lane = match h.take(enc_tickets[k]) {
                    Job::Encode(lane) => lane,
                    Job::Finish(_) => unreachable!("encode ticket yielded finish job"),
                };
                if err.is_none() {
                    let t0 = obs.map(|o| o.now_ns());
                    if let Err(e) = compute.scale(&mut lane) {
                        err = Some(e);
                    }
                    if let (Some(o), Some(t0)) = (obs, t0) {
                        o.span(track::CODEC, "compute.scale", t0, lane.client as i64, -1);
                    }
                }
                fin_tickets[k] = h.submit(Job::Finish(lane));
            }
            // Collect every lane back into its slot.
            for k in 0..take {
                let lane = match h.take(fin_tickets[k]) {
                    Job::Finish(lane) => lane,
                    Job::Encode(_) => unreachable!("finish ticket yielded encode job"),
                };
                slots[k] = Some(lane);
            }
        },
    );

    lanes.extend(
        slots
            .into_iter()
            .map(|s| s.expect("lane lost in pipeline")),
    );
    match err {
        Some(e) => Err(e),
        None => Ok(()),
    }
}

/// Stage-5 per-lane metric accumulation, shared between the
/// single-process round loop and the sharded coordinator so both
/// produce identical [`RoundMetrics`]. Lanes must be supplied in slot
/// order (float accumulation order is part of the determinism
/// invariant).
pub fn collect_lane_metrics<'a>(
    m: &mut RoundMetrics,
    lanes: impl IntoIterator<Item = &'a RoundLane>,
    update_idx: &[usize],
) {
    let mut take = 0usize;
    let mut sparsity_sum = 0.0;
    let mut rows_sum = 0.0;
    for lane in lanes {
        take += 1;
        m.up_bytes += lane.up_bytes;
        m.train_ms += lane.train_ms;
        m.scale_ms += lane.scale_ms;
        m.scale_accepted += lane.scale_accepted as usize;
        let sp = lane.update.sparsity_of(update_idx);
        m.client_sparsity.push(sp);
        sparsity_sum += sp;
        if lane.stats.rows_total > 0 {
            rows_sum += lane.stats.rows_skipped as f64 / lane.stats.rows_total as f64;
        }
    }
    if take > 0 {
        m.update_sparsity = sparsity_sum / take as f64;
        m.rows_skipped = rows_sum / take as f64;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn full_participation_is_identity_order() {
        let mut order = Vec::new();
        select_participants(7, 3, 5, 5, &mut order);
        assert_eq!(order, vec![0, 1, 2, 3, 4]);
    }

    #[test]
    fn partial_participation_is_seeded_and_truncated() {
        let mut a = Vec::new();
        let mut b = Vec::new();
        select_participants(7, 3, 10, 4, &mut a);
        select_participants(7, 3, 10, 4, &mut b);
        assert_eq!(a, b, "same seed+round must select the same subset");
        assert_eq!(a.len(), 4);
        // a valid subset: distinct client ids in range
        let mut sorted = a.clone();
        sorted.sort_unstable();
        sorted.dedup();
        assert_eq!(sorted.len(), 4);
        assert!(sorted.iter().all(|&ci| ci < 10));
        // recycled buffer: contents fully replaced
        select_participants(7, 3, 6, 6, &mut a);
        assert_eq!(a, vec![0, 1, 2, 3, 4, 5]);
    }

    #[test]
    fn distinct_seed_round_pairs_select_distinct_permutations() {
        // Regression for the old `seed ^ (round + 0xF00D)` derivation:
        // pairs that collided under it (same xor) must now produce
        // different permutations, and a grid of nearby seeds × rounds
        // must be pairwise distinct.
        let clients = 12;
        let take = 8;
        let mut perms: Vec<(u64, usize, Vec<usize>)> = Vec::new();
        for seed in 0..6u64 {
            for round in 0..6usize {
                let mut order = Vec::new();
                select_participants(seed, round, clients, take, &mut order);
                for (s, r, p) in &perms {
                    assert_ne!(
                        p, &order,
                        "(seed {seed}, round {round}) collides with (seed {s}, round {r})"
                    );
                }
                perms.push((seed, round, order));
            }
        }

        // An explicit old-scheme collision: pick (s1, r1), then derive
        // the seed that made (s2, r2) select identically before the fix.
        let (s1, r1, r2) = (7u64, 1usize, 2usize);
        let s2 = s1 ^ (r1 as u64 + 0xF00D) ^ (r2 as u64 + 0xF00D);
        assert_eq!(
            s1 ^ (r1 as u64 + 0xF00D),
            s2 ^ (r2 as u64 + 0xF00D),
            "constructed pair must collide under the old derivation"
        );
        let mut a = Vec::new();
        let mut b = Vec::new();
        select_participants(s1, r1, clients, take, &mut a);
        select_participants(s2, r2, clients, take, &mut b);
        assert_ne!(a, b, "old-scheme collision survived the splitmix mix");
    }

    #[test]
    fn shard_assignment_round_robin() {
        assert_eq!(shard_of(0, 3), 0);
        assert_eq!(shard_of(1, 3), 1);
        assert_eq!(shard_of(5, 3), 2);
        assert_eq!(shard_of(9, 3), 0);
        // degenerate shard counts never divide by zero
        assert_eq!(shard_of(4, 0), 0);
        assert_eq!(shard_of(4, 1), 0);
    }
}
