//! Learning-rate schedules for scale-factor optimization (paper Sec. 4.1,
//! Fig. 1). The scheduler steps **once per inferenced batch**; CAWR warm
//! restarts fire at the start of each main training epoch t, right before
//! the scale sub-epochs.

/// Which learning-rate curve drives the scale sub-epochs.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ScheduleKind {
    /// Constant base learning rate (the "no schedule" Fig. 2 configs).
    Const,
    /// Linearly decreasing from base to ~0 over the whole FL process.
    Linear,
    /// Cosine annealing with warm restarts at every main epoch.
    Cawr,
}

impl std::str::FromStr for ScheduleKind {
    type Err = anyhow::Error;
    fn from_str(s: &str) -> anyhow::Result<Self> {
        match s.to_ascii_lowercase().as_str() {
            "const" | "none" => Ok(ScheduleKind::Const),
            "linear" => Ok(ScheduleKind::Linear),
            "cawr" | "cosine" => Ok(ScheduleKind::Cawr),
            other => Err(anyhow::anyhow!("unknown schedule {other:?}")),
        }
    }
}

/// A stateful learning-rate schedule (one per client).
#[derive(Debug, Clone)]
pub struct LrSchedule {
    /// Curve shape.
    pub kind: ScheduleKind,
    /// Peak learning rate.
    pub base_lr: f32,
    /// Floor learning rate (0 by default).
    pub min_lr: f32,
    /// Total batch-steps across the whole FL process (Linear ramp length).
    pub total_steps: usize,
    /// Batch-steps per restart period (CAWR: one round's scale steps).
    pub period_steps: usize,
    global_step: usize,
    period_step: usize,
}

impl LrSchedule {
    /// Build a schedule; step counts are clamped to at least 1.
    pub fn new(kind: ScheduleKind, base_lr: f32, total_steps: usize, period_steps: usize) -> Self {
        Self {
            kind,
            base_lr,
            min_lr: 0.0,
            total_steps: total_steps.max(1),
            period_steps: period_steps.max(1),
            global_step: 0,
            period_step: 0,
        }
    }

    /// Learning rate for the *current* step, then advance.
    pub fn next_lr(&mut self) -> f32 {
        let lr = self.peek();
        self.global_step += 1;
        self.period_step += 1;
        lr
    }

    /// Learning rate for the current step without advancing.
    pub fn peek(&self) -> f32 {
        match self.kind {
            ScheduleKind::Const => self.base_lr,
            ScheduleKind::Linear => {
                let frac = (self.global_step as f32 / self.total_steps as f32).min(1.0);
                self.min_lr + (self.base_lr - self.min_lr) * (1.0 - frac)
            }
            ScheduleKind::Cawr => {
                let frac = (self.period_step as f32 / self.period_steps as f32).min(1.0);
                self.min_lr
                    + 0.5
                        * (self.base_lr - self.min_lr)
                        * (1.0 + (std::f32::consts::PI * frac).cos())
            }
        }
    }

    /// Warm restart (CAWR): reset the within-period counter.
    pub fn restart(&mut self) {
        self.period_step = 0;
    }

    /// Batch-steps taken since construction.
    pub fn global_step(&self) -> usize {
        self.global_step
    }

    /// Batch-steps taken since the last warm restart.
    pub fn period_step(&self) -> usize {
        self.period_step
    }

    /// Jump the schedule to an absolute position (session resume): the
    /// next [`LrSchedule::next_lr`] behaves exactly as it would have at
    /// that point of the original run.
    pub fn seek(&mut self, global_step: usize, period_step: usize) {
        self.global_step = global_step;
        self.period_step = period_step;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn const_is_flat() {
        let mut s = LrSchedule::new(ScheduleKind::Const, 0.1, 100, 10);
        for _ in 0..50 {
            assert_eq!(s.next_lr(), 0.1);
        }
    }

    #[test]
    fn linear_decays_to_zero() {
        let mut s = LrSchedule::new(ScheduleKind::Linear, 1.0, 100, 10);
        assert!((s.next_lr() - 1.0).abs() < 1e-6);
        for _ in 0..99 {
            s.next_lr();
        }
        assert!(s.peek() < 1e-6);
        // monotone decreasing
        let mut s = LrSchedule::new(ScheduleKind::Linear, 1.0, 50, 10);
        let mut prev = f32::INFINITY;
        for _ in 0..50 {
            let lr = s.next_lr();
            assert!(lr <= prev);
            prev = lr;
        }
    }

    #[test]
    fn cawr_restarts() {
        let mut s = LrSchedule::new(ScheduleKind::Cawr, 1.0, 1000, 10);
        assert!((s.next_lr() - 1.0).abs() < 1e-6);
        for _ in 0..9 {
            s.next_lr();
        }
        // end of period: near min
        assert!(s.peek() < 0.01);
        s.restart();
        assert!((s.peek() - 1.0).abs() < 1e-6);
    }

    #[test]
    fn cawr_is_cosine_shaped() {
        let mut s = LrSchedule::new(ScheduleKind::Cawr, 2.0, 1000, 100);
        for _ in 0..50 {
            s.next_lr();
        }
        // halfway through the period: half the base lr
        assert!((s.peek() - 1.0).abs() < 0.05);
    }
}
