//! Experiment configuration: protocol presets matching every row/curve in
//! the paper's evaluation, plus the knobs the harnesses sweep.

use crate::compression::{QuantConfig, SparsifyMode, UpdateCodec};
use crate::data::TaskKind;
use crate::fl::schedule::ScheduleKind;
use crate::fl::scheduler::ScheduleMode;
use crate::runtime::Optimizer;

/// How a client's update is compressed + whether scale training runs.
#[derive(Debug, Clone, Copy)]
pub struct ProtocolConfig {
    /// `None` → plain FedAvg: the raw f32 update is "transmitted".
    pub codec: Option<UpdateCodec>,
    /// Run Algorithm 1's scale-factor sub-epochs (the paper's S).
    pub scaled: bool,
    /// Error accumulation (Eq. 5).
    pub residuals: bool,
}

/// The named protocol rows of Table 2 / curves of Fig. 2.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Protocol {
    /// FedAvg [19]: uncompressed f32 updates.
    FedAvg,
    /// FedAvg†: uniform quantization + DeepCABAC, no sparsification.
    FedAvgQ,
    /// STC† [21]: top-k + ternary + error feedback + DeepCABAC.
    Stc,
    /// Eqs. (2)+(3): our sparsification without scaling.
    SparseOnly,
    /// STC‡: STC plus our filter scaling.
    StcScaled,
    /// FSFL: the paper's full method.
    Fsfl,
}

impl Protocol {
    /// Every protocol, in the paper's Table 2 row order.
    pub const ALL: [Protocol; 6] = [
        Protocol::FedAvg,
        Protocol::FedAvgQ,
        Protocol::Stc,
        Protocol::SparseOnly,
        Protocol::StcScaled,
        Protocol::Fsfl,
    ];

    /// Human-readable protocol name (Table 2 row label).
    pub fn name(self) -> &'static str {
        match self {
            Protocol::FedAvg => "FedAvg",
            Protocol::FedAvgQ => "FedAvg+DeepCABAC",
            Protocol::Stc => "STC",
            Protocol::SparseOnly => "Eqs.(2)+(3)",
            Protocol::StcScaled => "STC+scaling",
            Protocol::Fsfl => "FSFL",
        }
    }

    /// Build the protocol config. `sparsify` selects dynamic (Fig. 2) vs
    /// fixed-rate (Table 2) thresholds for the sparsifying protocols.
    pub fn config(self, sparsify: SparsifyMode, quant: QuantConfig) -> ProtocolConfig {
        let rate = match sparsify {
            SparsifyMode::TopK { rate } => rate,
            _ => 0.96,
        };
        match self {
            Protocol::FedAvg => ProtocolConfig {
                codec: None,
                scaled: false,
                residuals: false,
            },
            Protocol::FedAvgQ => ProtocolConfig {
                codec: Some(UpdateCodec {
                    sparsify: SparsifyMode::None,
                    quant,
                    ternary: false,
                }),
                scaled: false,
                residuals: false,
            },
            Protocol::Stc | Protocol::StcScaled => ProtocolConfig {
                codec: Some(UpdateCodec {
                    sparsify: SparsifyMode::TopK { rate },
                    quant,
                    ternary: true,
                }),
                scaled: self == Protocol::StcScaled,
                residuals: true,
            },
            Protocol::SparseOnly => ProtocolConfig {
                codec: Some(UpdateCodec {
                    sparsify,
                    quant,
                    ternary: false,
                }),
                scaled: false,
                residuals: false,
            },
            Protocol::Fsfl => ProtocolConfig {
                codec: Some(UpdateCodec {
                    sparsify,
                    quant,
                    ternary: false,
                }),
                scaled: true,
                residuals: false,
            },
        }
    }
}

impl std::str::FromStr for Protocol {
    type Err = anyhow::Error;
    fn from_str(s: &str) -> anyhow::Result<Self> {
        match s.to_ascii_lowercase().as_str() {
            "fedavg" => Ok(Protocol::FedAvg),
            "fedavg_q" | "fedavgq" => Ok(Protocol::FedAvgQ),
            "stc" => Ok(Protocol::Stc),
            "sparse" | "sparse_only" | "eqs23" => Ok(Protocol::SparseOnly),
            "stc_scaled" => Ok(Protocol::StcScaled),
            "fsfl" => Ok(Protocol::Fsfl),
            other => Err(anyhow::anyhow!("unknown protocol {other:?}")),
        }
    }
}

/// How shard workers and the coordinator exchange `ShardCmd`/`ShardMsg`
/// traffic in sharded deployments (see `coordinator` and `net`).
///
/// Every kind produces byte-identical bitstreams and `RunLog` round
/// metrics for a fixed config — pinned by the differential conformance
/// tests in `tests/integration_transport.rs`. They differ in what
/// actually moves: `Mpsc` passes owned structs between threads, the
/// wire kinds serialize every message through the `net` frame codec
/// (and therefore also *measure* transfer bytes instead of estimating
/// them — see [`crate::metrics::WireStats`]).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum TransportKind {
    /// In-process typed mpsc channels (zero serialization; the fastest
    /// shape for shards-as-threads).
    #[default]
    Mpsc,
    /// In-process byte pipes speaking the full wire protocol (frames,
    /// checksums, serialization) without a socket — the loopback
    /// reference every TCP byte is compared against.
    Loopback,
    /// `std::net` TCP on localhost; shards may live in other OS
    /// processes (`fsfl shard-worker`).
    Tcp,
}

impl TransportKind {
    /// Human-readable name (matches the `--transport` CLI values).
    pub fn name(self) -> &'static str {
        match self {
            TransportKind::Mpsc => "mpsc",
            TransportKind::Loopback => "loopback",
            TransportKind::Tcp => "tcp",
        }
    }

    /// Whether shard traffic crosses the serialized wire protocol (as
    /// opposed to moving as owned in-process structs).
    pub fn is_wire(self) -> bool {
        !matches!(self, TransportKind::Mpsc)
    }
}

impl std::str::FromStr for TransportKind {
    type Err = anyhow::Error;
    fn from_str(s: &str) -> anyhow::Result<Self> {
        match s.to_ascii_lowercase().as_str() {
            "mpsc" | "channel" => Ok(TransportKind::Mpsc),
            "loopback" | "loop" => Ok(TransportKind::Loopback),
            "tcp" => Ok(TransportKind::Tcp),
            other => Err(anyhow::anyhow!("unknown transport {other:?}")),
        }
    }
}

/// What the sharded coordinator does when a shard exhausts its retry
/// budget (or dies with a budget of zero). See the failure-domain
/// section of `ARCHITECTURE.md` for the full state machine.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum OnShardLoss {
    /// Abort the experiment with a descriptive error (historic
    /// behaviour, still the default: fail fast unless recovery was
    /// asked for).
    #[default]
    Abort,
    /// Respawn/re-admit a replacement worker with exponential backoff,
    /// rehydrate it from the coordinator's last collected state, and
    /// replay the in-flight round — outputs stay byte-identical to an
    /// undisturbed run.
    Respawn,
    /// Like `Respawn`, but once the retry budget is exhausted fold the
    /// dead shard's clients into the survivors (quorum mode) instead
    /// of aborting.
    Degrade,
}

impl OnShardLoss {
    /// Human-readable name (matches the `--on-shard-loss` CLI values).
    pub fn name(self) -> &'static str {
        match self {
            OnShardLoss::Abort => "abort",
            OnShardLoss::Respawn => "respawn",
            OnShardLoss::Degrade => "degrade",
        }
    }
}

impl std::str::FromStr for OnShardLoss {
    type Err = anyhow::Error;
    fn from_str(s: &str) -> anyhow::Result<Self> {
        match s.to_ascii_lowercase().as_str() {
            "abort" => Ok(OnShardLoss::Abort),
            "respawn" | "retry" => Ok(OnShardLoss::Respawn),
            "degrade" | "quorum" => Ok(OnShardLoss::Degrade),
            other => Err(anyhow::anyhow!("unknown shard-loss policy {other:?}")),
        }
    }
}

/// Supervision policy for sharded rounds: liveness leases, the
/// per-round deadline, and the retry/degrade budget the recovery state
/// machine spends before giving up on a shard. Purely operational —
/// it never changes what is computed, only how failures are handled —
/// so resume treats it like [`SessionConfig`]: overridable without
/// invalidating a snapshot.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct RoundPolicy {
    /// Liveness lease cadence for wire transports: the coordinator
    /// pings idle connections every `heartbeat` and declares a
    /// connection dead after ~3 missed beats. `0` disables leases.
    pub heartbeat: std::time::Duration,
    /// Upper bound on one round's compute+collect phase; a shard still
    /// silent past it is declared dead instead of blocking fan-in
    /// forever. `0` disables the deadline.
    pub round_deadline: std::time::Duration,
    /// How many respawn attempts the recovery machine makes per
    /// incident before applying [`RoundPolicy::on_loss`]'s terminal
    /// behaviour.
    pub retry_budget: usize,
    /// Base delay of the exponential (seeded-jitter) backoff between
    /// respawn attempts; also the worker connect-retry base.
    pub backoff: std::time::Duration,
    /// How long the coordinator waits for a worker to join/handshake
    /// (was a hardcoded 120 s).
    pub join_timeout: std::time::Duration,
    /// Terminal behaviour once the retry budget is exhausted.
    pub on_loss: OnShardLoss,
}

impl RoundPolicy {
    /// Whether this policy engages the round supervisor at all. The
    /// default policy (no heartbeat, no deadline, abort on loss) is
    /// fully unsupervised and preserves the legacy coordinator
    /// behaviour bit for bit; setting any liveness knob — or a
    /// non-abort loss reaction — turns supervision on.
    pub fn supervised(&self) -> bool {
        self.on_loss != OnShardLoss::Abort
            || !self.heartbeat.is_zero()
            || !self.round_deadline.is_zero()
    }
}

impl Default for RoundPolicy {
    fn default() -> Self {
        Self {
            heartbeat: std::time::Duration::ZERO,
            round_deadline: std::time::Duration::ZERO,
            retry_budget: 2,
            backoff: std::time::Duration::from_millis(100),
            join_timeout: std::time::Duration::from_secs(120),
            on_loss: OnShardLoss::Abort,
        }
    }
}

/// Durable-session settings: where checkpoints go and how often they
/// are written (see `crate::session`). Attached to an experiment via
/// [`ExperimentConfig::session`]; `None` disables checkpointing.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct SessionConfig {
    /// Directory the session store writes snapshots into (created on
    /// first write).
    pub dir: std::path::PathBuf,
    /// Snapshot cadence: write a checkpoint after every `every`-th
    /// completed round (`1` = every round; `0` disables cadence writes
    /// while keeping the directory configured for resume).
    pub every: usize,
    /// How many snapshots the store keeps after each write (GC knob;
    /// values below 1 are treated as 1). The default of
    /// [`SessionConfig::DEFAULT_RETAIN`] keeps the new snapshot plus
    /// one predecessor, so a crash mid-write always has a valid
    /// fallback.
    pub retain: usize,
    /// Fault injection for the session test plane: after completing
    /// round `k` (checkpoint included), abort the run with an error as
    /// an in-process stand-in for `kill -9`. Never set by the CLI.
    pub crash_after: Option<usize>,
}

impl SessionConfig {
    /// Default snapshot retention: the newest snapshot plus one
    /// predecessor.
    pub const DEFAULT_RETAIN: usize = 2;
}

/// Full experiment description (one Fig. 2 curve / Table 2 cell).
#[derive(Debug, Clone)]
pub struct ExperimentConfig {
    /// Experiment name (used for log/CSV file naming).
    pub name: String,
    /// Directory holding the AOT artifacts (`artifacts/<variant>/…`).
    pub artifacts_root: std::path::PathBuf,
    /// Model variant (an `artifacts/` subdirectory, e.g. `tiny_cnn`).
    pub variant: String,
    /// Synthetic task standing in for the paper's dataset.
    pub task: TaskKind,
    /// Which Table 2 protocol row to run.
    pub protocol: Protocol,
    /// Dynamic (Fig. 2) or fixed-rate (Table 2) sparsification.
    pub sparsify: SparsifyMode,
    /// Quantization step assignment (coarse/fine, Sec. 5.1).
    pub quant: QuantConfig,
    /// Total client count.
    pub clients: usize,
    /// Communication rounds T.
    pub rounds: usize,
    /// Local weight-training epochs per round.
    pub local_epochs: usize,
    /// Scale-factor sub-epochs E (Algorithm 1).
    pub scale_epochs: usize,
    /// Weight-training optimizer.
    pub optimizer: Optimizer,
    /// Weight-training learning rate.
    pub lr: f32,
    /// Scale-factor optimizer (paper Appendix B sweeps Adam vs SGD).
    pub scale_optimizer: Optimizer,
    /// Scale-factor base learning rate.
    pub scale_lr: f32,
    /// Scale-factor learning-rate schedule (Fig. 1).
    pub schedule: ScheduleKind,
    /// Compress the server→clients broadcast too (Fig. 2 VGG16 bidir).
    pub bidirectional: bool,
    /// Dirichlet alpha for non-IID splits; `None` → random IID split.
    pub dirichlet_alpha: Option<f64>,
    /// Training samples per client.
    pub train_per_client: usize,
    /// Validation samples per client (scale-factor selection).
    pub val_per_client: usize,
    /// Central test-set size.
    pub test_samples: usize,
    /// Master seed: datasets, splits, participation and client RNGs all
    /// derive from it, so a config is exactly repeatable.
    pub seed: u64,
    /// Early-exit once the central model reaches this accuracy.
    pub target_accuracy: Option<f64>,
    /// Fraction of clients participating per round (1.0 = all).
    pub participation: f64,
    /// Force error accumulation on/off regardless of protocol default
    /// (Fig. 5 runs every protocol with residuals).
    pub residuals_override: Option<bool>,
    /// Warmup steps on server data before FL starts (emulates the paper's
    /// ImageNet-pretrained starting point).
    pub warmup_steps: usize,
    /// Codec-plane worker pool width (encode/decode fan-out per round);
    /// `0` = auto (available parallelism), `1` = strictly serial. Any
    /// width produces byte-identical bitstreams and metrics. In sharded
    /// deployments an explicit width applies per shard, while auto
    /// divides the machine's parallelism across shards.
    pub codec_workers: usize,
    /// Software-pipeline each round (client *k*'s codec work overlaps
    /// client *k+1*'s compute; see `fl/scheduler.rs`). `false` = the
    /// staged schedule. Outputs are byte-identical either way.
    pub pipelined: bool,
    /// Compute shards for `coordinator::run_experiment_sharded`: clients
    /// are split round-robin over this many compute threads, each owning
    /// its own PJRT client. `0`/`1` = single compute thread. The
    /// in-process [`crate::fl::Experiment`] itself always runs one
    /// shard; outputs are byte-identical for every shard count.
    pub compute_shards: usize,
    /// How shard traffic moves between workers and the coordinator. A
    /// wire kind forces the sharded deployment path even for one shard
    /// (so the serialization seam is exercised); outputs are
    /// byte-identical for every kind.
    pub transport: TransportKind,
    /// Durable-session settings (checkpoint directory + cadence); `None`
    /// runs without checkpointing. A configured session forces the
    /// sharded coordinator path so all persistence lives in one place.
    pub session: Option<SessionConfig>,
    /// Round supervision policy: heartbeats, deadlines, retry budget
    /// and shard-loss behaviour. Operational only — never changes what
    /// is computed.
    pub policy: RoundPolicy,
    /// Hierarchical fan-in: on a wire transport each top-level slot
    /// becomes a mid-tier aggregator that owns this many leaf shards
    /// and reduces their lanes before streaming one merged ROUND_DONE
    /// upward (see the tree/aggregation-plane section of
    /// `ARCHITECTURE.md`). `0` = flat fan-in (today's shape); `1` = a
    /// depth-1 relay tree, byte-identical to flat by construction. The
    /// reduction in `scheduler::fan_in` is associative and slot-ordered,
    /// so every tree shape produces byte-identical `RunLog` rounds.
    /// Ignored on the mpsc transport (nothing is serialized there).
    pub tree_children: usize,
    /// Cold-state paging budget: at most this many `ClientState`s stay
    /// resident per shard between rounds; the rest page through the
    /// session snapshot codec on disk and are rehydrated when their
    /// client is selected. `0` = everything resident (today's shape).
    /// Purely a memory knob — paged and fully-resident runs are
    /// byte-identical.
    pub resident_clients: usize,
}

impl ExperimentConfig {
    /// Small, fast defaults (CI preset). Harnesses override fields.
    pub fn quick(variant: &str, task: TaskKind, protocol: Protocol) -> Self {
        Self {
            name: format!("{variant}-{}", protocol.name()),
            artifacts_root: "artifacts".into(),
            variant: variant.to_string(),
            task,
            protocol,
            sparsify: SparsifyMode::Dynamic {
                delta: 1.0,
                gamma: 1.0,
            },
            quant: QuantConfig::default(),
            clients: 2,
            rounds: 5,
            local_epochs: 1,
            scale_epochs: 2,
            optimizer: Optimizer::Adam,
            lr: 1e-3,
            scale_optimizer: Optimizer::Adam,
            scale_lr: 1e-2,
            schedule: ScheduleKind::Linear,
            bidirectional: false,
            dirichlet_alpha: None,
            train_per_client: 64,
            val_per_client: 32,
            test_samples: 64,
            seed: 0,
            target_accuracy: None,
            participation: 1.0,
            residuals_override: None,
            warmup_steps: 0,
            codec_workers: 0,
            pipelined: false,
            compute_shards: 1,
            transport: TransportKind::Mpsc,
            session: None,
            policy: RoundPolicy::default(),
            tree_children: 0,
            resident_clients: 0,
        }
    }

    /// The round schedule mode selected by [`Self::pipelined`].
    pub fn schedule_mode(&self) -> ScheduleMode {
        if self.pipelined {
            ScheduleMode::Pipelined
        } else {
            ScheduleMode::Staged
        }
    }

    /// Resolve the protocol preset, applying [`Self::residuals_override`].
    pub fn protocol_config(&self) -> ProtocolConfig {
        let mut p = self.protocol.config(self.sparsify, self.quant);
        if let Some(r) = self.residuals_override {
            p.residuals = r;
        }
        p
    }

    /// Downstream codec for bidirectional compression (paper: halved
    /// coarse step so two quantization legs stay within budget).
    pub fn downstream_codec(&self) -> Option<UpdateCodec> {
        if !self.bidirectional {
            return None;
        }
        Some(UpdateCodec {
            sparsify: self.sparsify,
            quant: QuantConfig::bidirectional(),
            ternary: false,
        })
    }
}
