//! Experiment configuration: protocol presets matching every row/curve in
//! the paper's evaluation, plus the knobs the harnesses sweep.

use crate::compression::{QuantConfig, SparsifyMode, UpdateCodec};
use crate::data::TaskKind;
use crate::fl::schedule::ScheduleKind;
use crate::runtime::Optimizer;

/// How a client's update is compressed + whether scale training runs.
#[derive(Debug, Clone, Copy)]
pub struct ProtocolConfig {
    /// `None` → plain FedAvg: the raw f32 update is "transmitted".
    pub codec: Option<UpdateCodec>,
    /// Run Algorithm 1's scale-factor sub-epochs (the paper's S).
    pub scaled: bool,
    /// Error accumulation (Eq. 5).
    pub residuals: bool,
}

/// The named protocol rows of Table 2 / curves of Fig. 2.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Protocol {
    /// FedAvg [19]: uncompressed f32 updates.
    FedAvg,
    /// FedAvg†: uniform quantization + DeepCABAC, no sparsification.
    FedAvgQ,
    /// STC† [21]: top-k + ternary + error feedback + DeepCABAC.
    Stc,
    /// Eqs. (2)+(3): our sparsification without scaling.
    SparseOnly,
    /// STC‡: STC plus our filter scaling.
    StcScaled,
    /// FSFL: the paper's full method.
    Fsfl,
}

impl Protocol {
    pub const ALL: [Protocol; 6] = [
        Protocol::FedAvg,
        Protocol::FedAvgQ,
        Protocol::Stc,
        Protocol::SparseOnly,
        Protocol::StcScaled,
        Protocol::Fsfl,
    ];

    pub fn name(self) -> &'static str {
        match self {
            Protocol::FedAvg => "FedAvg",
            Protocol::FedAvgQ => "FedAvg+DeepCABAC",
            Protocol::Stc => "STC",
            Protocol::SparseOnly => "Eqs.(2)+(3)",
            Protocol::StcScaled => "STC+scaling",
            Protocol::Fsfl => "FSFL",
        }
    }

    /// Build the protocol config. `sparsify` selects dynamic (Fig. 2) vs
    /// fixed-rate (Table 2) thresholds for the sparsifying protocols.
    pub fn config(self, sparsify: SparsifyMode, quant: QuantConfig) -> ProtocolConfig {
        let rate = match sparsify {
            SparsifyMode::TopK { rate } => rate,
            _ => 0.96,
        };
        match self {
            Protocol::FedAvg => ProtocolConfig {
                codec: None,
                scaled: false,
                residuals: false,
            },
            Protocol::FedAvgQ => ProtocolConfig {
                codec: Some(UpdateCodec {
                    sparsify: SparsifyMode::None,
                    quant,
                    ternary: false,
                }),
                scaled: false,
                residuals: false,
            },
            Protocol::Stc | Protocol::StcScaled => ProtocolConfig {
                codec: Some(UpdateCodec {
                    sparsify: SparsifyMode::TopK { rate },
                    quant,
                    ternary: true,
                }),
                scaled: self == Protocol::StcScaled,
                residuals: true,
            },
            Protocol::SparseOnly => ProtocolConfig {
                codec: Some(UpdateCodec {
                    sparsify,
                    quant,
                    ternary: false,
                }),
                scaled: false,
                residuals: false,
            },
            Protocol::Fsfl => ProtocolConfig {
                codec: Some(UpdateCodec {
                    sparsify,
                    quant,
                    ternary: false,
                }),
                scaled: true,
                residuals: false,
            },
        }
    }
}

impl std::str::FromStr for Protocol {
    type Err = anyhow::Error;
    fn from_str(s: &str) -> anyhow::Result<Self> {
        match s.to_ascii_lowercase().as_str() {
            "fedavg" => Ok(Protocol::FedAvg),
            "fedavg_q" | "fedavgq" => Ok(Protocol::FedAvgQ),
            "stc" => Ok(Protocol::Stc),
            "sparse" | "sparse_only" | "eqs23" => Ok(Protocol::SparseOnly),
            "stc_scaled" => Ok(Protocol::StcScaled),
            "fsfl" => Ok(Protocol::Fsfl),
            other => Err(anyhow::anyhow!("unknown protocol {other:?}")),
        }
    }
}

/// Full experiment description (one Fig. 2 curve / Table 2 cell).
#[derive(Debug, Clone)]
pub struct ExperimentConfig {
    pub name: String,
    pub artifacts_root: std::path::PathBuf,
    pub variant: String,
    pub task: TaskKind,
    pub protocol: Protocol,
    /// Dynamic (Fig. 2) or fixed-rate (Table 2) sparsification.
    pub sparsify: SparsifyMode,
    pub quant: QuantConfig,
    pub clients: usize,
    /// Communication rounds T.
    pub rounds: usize,
    /// Local weight-training epochs per round.
    pub local_epochs: usize,
    /// Scale-factor sub-epochs E (Algorithm 1).
    pub scale_epochs: usize,
    pub optimizer: Optimizer,
    pub lr: f32,
    pub scale_optimizer: Optimizer,
    pub scale_lr: f32,
    pub schedule: ScheduleKind,
    /// Compress the server→clients broadcast too (Fig. 2 VGG16 bidir).
    pub bidirectional: bool,
    /// Dirichlet alpha for non-IID splits; `None` → random IID split.
    pub dirichlet_alpha: Option<f64>,
    pub train_per_client: usize,
    pub val_per_client: usize,
    pub test_samples: usize,
    pub seed: u64,
    /// Early-exit once the central model reaches this accuracy.
    pub target_accuracy: Option<f64>,
    /// Fraction of clients participating per round (1.0 = all).
    pub participation: f64,
    /// Force error accumulation on/off regardless of protocol default
    /// (Fig. 5 runs every protocol with residuals).
    pub residuals_override: Option<bool>,
    /// Warmup steps on server data before FL starts (emulates the paper's
    /// ImageNet-pretrained starting point).
    pub warmup_steps: usize,
    /// Codec-plane worker pool width (encode/decode fan-out per round);
    /// `0` = auto (available parallelism), `1` = strictly serial. Any
    /// width produces byte-identical bitstreams and metrics.
    pub codec_workers: usize,
}

impl ExperimentConfig {
    /// Small, fast defaults (CI preset). Harnesses override fields.
    pub fn quick(variant: &str, task: TaskKind, protocol: Protocol) -> Self {
        Self {
            name: format!("{variant}-{}", protocol.name()),
            artifacts_root: "artifacts".into(),
            variant: variant.to_string(),
            task,
            protocol,
            sparsify: SparsifyMode::Dynamic {
                delta: 1.0,
                gamma: 1.0,
            },
            quant: QuantConfig::default(),
            clients: 2,
            rounds: 5,
            local_epochs: 1,
            scale_epochs: 2,
            optimizer: Optimizer::Adam,
            lr: 1e-3,
            scale_optimizer: Optimizer::Adam,
            scale_lr: 1e-2,
            schedule: ScheduleKind::Linear,
            bidirectional: false,
            dirichlet_alpha: None,
            train_per_client: 64,
            val_per_client: 32,
            test_samples: 64,
            seed: 0,
            target_accuracy: None,
            participation: 1.0,
            residuals_override: None,
            warmup_steps: 0,
            codec_workers: 0,
        }
    }

    pub fn protocol_config(&self) -> ProtocolConfig {
        let mut p = self.protocol.config(self.sparsify, self.quant);
        if let Some(r) = self.residuals_override {
            p.residuals = r;
        }
        p
    }

    /// Downstream codec for bidirectional compression (paper: halved
    /// coarse step so two quantization legs stay within budget).
    pub fn downstream_codec(&self) -> Option<UpdateCodec> {
        if !self.bidirectional {
            return None;
        }
        Some(UpdateCodec {
            sparsify: self.sparsify,
            quant: QuantConfig::bidirectional(),
            ternary: false,
        })
    }
}
