//! FL client: Algorithm 1 lines 6–21 — local weight training, dynamic
//! sparsification of the differential update, scale-factor sub-epochs
//! with best-of-E validation selection, and the discard rule.
//!
//! The round is split into **compute-plane** methods that must run on
//! the XLA thread ([`Client::train_round`], [`Client::scale_round`]) and
//! codec-plane work that lives on the [`crate::fl::RoundLane`] and runs
//! on the worker pool. All round-to-round state (the global replica, the
//! local training replica `work`, the Ŵ replica `hat`, optimizer state,
//! scale-selection buffers) is persistent: a steady-state round clones
//! no `ParamSet` and allocates nothing on this path.

use std::sync::Arc;

use anyhow::{anyhow, Result};

use crate::compression::Residual;
use crate::data::{batches, Batch, Dataset, XorShiftRng};
use crate::fl::config::{ExperimentConfig, ProtocolConfig};
use crate::fl::lane::RoundLane;
use crate::fl::schedule::LrSchedule;
use crate::model::params::Delta;
use crate::model::{Group, ParamSet};
use crate::runtime::{ModelRuntime, OptState};
use crate::supervise::{Clock, MonotonicClock};

/// Snapshot of one optimizer state (Adam moments + step counter) —
/// value-only, shapes validated against the live [`OptState`] on
/// install.
#[derive(Debug, Clone, PartialEq)]
pub struct OptSnapshot {
    /// First-moment estimates, one slab per group tensor.
    pub m: Vec<Vec<f32>>,
    /// Second-moment estimates, one slab per group tensor.
    pub v: Vec<Vec<f32>>,
    /// Adam step counter.
    pub t: f32,
}

impl OptSnapshot {
    /// Capture an optimizer state's values.
    pub fn of(opt: &OptState) -> Self {
        Self {
            m: opt.m.clone(),
            v: opt.v.clone(),
            t: opt.t,
        }
    }

    /// Validate shapes against `opt` without writing anything.
    fn check(&self, opt: &OptState, what: &str) -> Result<()> {
        if self.m.len() != opt.m.len() || self.v.len() != opt.v.len() {
            return Err(anyhow!(
                "{what}: snapshot has {}+{} moment slabs, state wants {}+{}",
                self.m.len(),
                self.v.len(),
                opt.m.len(),
                opt.v.len()
            ));
        }
        for (i, (s, t)) in self.m.iter().zip(&opt.m).enumerate() {
            if s.len() != t.len() {
                return Err(anyhow!("{what}: m[{i}] len {} != {}", s.len(), t.len()));
            }
        }
        for (i, (s, t)) in self.v.iter().zip(&opt.v).enumerate() {
            if s.len() != t.len() {
                return Err(anyhow!("{what}: v[{i}] len {} != {}", s.len(), t.len()));
            }
        }
        Ok(())
    }

    fn install(&self, opt: &mut OptState) {
        for (t, s) in opt.m.iter_mut().zip(&self.m) {
            t.copy_from_slice(s);
        }
        for (t, s) in opt.v.iter_mut().zip(&self.v) {
            t.copy_from_slice(s);
        }
        opt.t = self.t;
    }
}

/// Everything one client carries **between** rounds, in portable form:
/// the Eq. 5 error-accumulation residual, optimizer moments for both
/// training groups, the RNG stream position, the LR-schedule position
/// and the current training-sample permutation. The `global` replica is
/// deliberately absent — it always equals the server parameters at a
/// round boundary and is rehydrated from them (see the session plane in
/// `ARCHITECTURE.md`).
#[derive(Debug, Clone, PartialEq)]
pub struct ClientState {
    /// Global client id this state belongs to.
    pub id: usize,
    /// Raw [`XorShiftRng`] state.
    pub rng: u64,
    /// [`LrSchedule`] global step.
    pub sched_global: u64,
    /// [`LrSchedule`] within-period step.
    pub sched_period: u64,
    /// Current training-index permutation (shuffled in place each round,
    /// so the order is part of the resumable state).
    pub train_order: Vec<u64>,
    /// Error-accumulation residual values (protocols with Eq. 5 only).
    pub residual: Option<Vec<Vec<f32>>>,
    /// Weight-group optimizer snapshot.
    pub wopt: OptSnapshot,
    /// Scale-group optimizer snapshot.
    pub sopt: OptSnapshot,
}

/// One federated client: its replicas, optimizer state and round logic.
pub struct Client {
    /// Global client id (stable across rounds and shards).
    pub id: usize,
    /// This client's replica of the global model state; only ever mutated
    /// by applying broadcast deltas (so server/client divergence is a bug,
    /// asserted in integration tests).
    pub global: ParamSet,
    /// Local weight-training replica (overwritten from `global` each
    /// round; persistent so rounds don't clone the full model).
    work: ParamSet,
    /// Ŵ = W + Δ̂ replica for the scale sub-epochs (same reuse scheme).
    hat: ParamSet,
    wopt: OptState,
    sopt: OptState,
    /// Error-accumulation state (Eq. 5) when the protocol enables it.
    pub residual: Option<Residual>,
    /// Scale-factor learning-rate schedule (stepped once per batch).
    pub schedule: LrSchedule,
    train_idx: Vec<usize>,
    val_idx: Vec<usize>,
    /// Scale-tensor indices (cached from the manifest).
    scale_idx: Vec<usize>,
    /// Best-of-E selection buffers (one slice per scale tensor).
    baseline_scales: Vec<Vec<f32>>,
    best_scales: Vec<Vec<f32>>,
    rng: XorShiftRng,
    /// Time source for the per-stage `train_ms`/`scale_ms` timings
    /// (wall by default; swap via [`Client::set_clock`] to make the
    /// timing fields deterministic under a scripted clock).
    clock: Arc<dyn Clock>,
}

/// Snapshot `params`' scale tensors into reusable per-slot buffers.
fn copy_scales(params: &ParamSet, scale_idx: &[usize], out: &mut Vec<Vec<f32>>) {
    if out.len() != scale_idx.len() {
        out.clear();
        out.extend(scale_idx.iter().map(|&i| params.tensors[i].clone()));
        return;
    }
    for (slot, &i) in scale_idx.iter().enumerate() {
        out[slot].copy_from_slice(&params.tensors[i]);
    }
}

impl Client {
    /// Create a client with its synced initial replica and data split.
    pub fn new(
        id: usize,
        init: ParamSet,
        train_idx: Vec<usize>,
        val_idx: Vec<usize>,
        schedule: LrSchedule,
        residuals: bool,
        seed: u64,
    ) -> Self {
        let manifest = init.manifest.clone();
        Self {
            id,
            work: init.clone(),
            hat: init.clone(),
            wopt: OptState::zeros(&manifest, Group::Weight),
            sopt: OptState::zeros(&manifest, Group::Scale),
            residual: residuals.then(|| Residual::zeros(manifest.clone())),
            scale_idx: manifest.group_indices(Group::Scale),
            global: init,
            schedule,
            train_idx,
            val_idx,
            baseline_scales: Vec::new(),
            best_scales: Vec::new(),
            rng: XorShiftRng::new(seed ^ 0xC11E57),
            clock: Arc::new(MonotonicClock::new()),
        }
    }

    /// Replace the timing clock (scripted clocks make the cosmetic
    /// `train_ms`/`scale_ms` lane fields deterministic; training math
    /// never reads it).
    pub fn set_clock(&mut self, clock: Arc<dyn Clock>) {
        self.clock = clock;
    }

    /// Apply the server broadcast (Algorithm 1 lines 7–8).
    pub fn apply_broadcast(&mut self, delta: &Delta) {
        self.global.add_delta(delta);
    }

    fn train_batches(&mut self, ds: &Dataset, batch: usize) -> Vec<Batch> {
        self.rng.shuffle(&mut self.train_idx);
        batches(ds, &self.train_idx, batch)
    }

    fn val_batches(&self, ds: &Dataset, batch: usize) -> Vec<Batch> {
        batches(ds, &self.val_idx, batch)
    }

    fn eval_accuracy(&self, mr: &ModelRuntime, params: &ParamSet, val: &[Batch]) -> Result<f64> {
        let mut correct = 0.0f64;
        let mut total = 0usize;
        for b in val {
            let out = mr.eval_step(params, &b.x, &b.y)?;
            correct += out.correct as f64;
            total += b.size;
        }
        Ok(if total == 0 { 0.0 } else { correct / total as f64 })
    }

    /// Compute stage 1 (Algorithm 1 line 9; S frozen inside the HLO):
    /// local weight training, then the raw differential update (Eq. 1)
    /// with the carried residual injected (Eq. 5) into `lane.raw`.
    pub fn train_round(
        &mut self,
        mr: &ModelRuntime,
        ds: &Dataset,
        cfg: &ExperimentConfig,
        lane: &mut RoundLane,
    ) -> Result<()> {
        let t0 = self.clock.now();
        self.work.copy_from(&self.global);
        let mut loss_sum = 0.0f64;
        let mut loss_n = 0usize;
        for _ in 0..cfg.local_epochs {
            for b in self.train_batches(ds, mr.batch_size()) {
                let out = mr.train_step(
                    &mut self.work,
                    &mut self.wopt,
                    cfg.optimizer,
                    cfg.lr,
                    &b.x,
                    &b.y,
                )?;
                loss_sum += out.loss as f64;
                loss_n += 1;
            }
        }
        lane.train_ms = self.clock.now().saturating_sub(t0).as_millis();
        lane.train_loss = if loss_n == 0 {
            0.0
        } else {
            loss_sum / loss_n as f64
        };

        // ---- differential update (Eq. 1) + residual injection (Eq. 5) ----
        self.work.delta_from_into(&self.global, &mut lane.raw);
        if let Some(res) = &self.residual {
            res.inject(&mut lane.raw);
        }
        Ok(())
    }

    /// Compute stage 2 (Algorithm 1 lines 13–19), after the codec plane
    /// produced the dequantized Δ̂ in `lane.update`: residual bookkeeping,
    /// then the scale-factor sub-epochs on Ŵ = W + Δ̂ with best-of-E
    /// validation selection and the discard rule. On acceptance the raw
    /// S-only delta is staged in `lane.sdelta` for the codec plane.
    pub fn scale_round(
        &mut self,
        mr: &ModelRuntime,
        ds: &Dataset,
        cfg: &ExperimentConfig,
        pcfg: &ProtocolConfig,
        lane: &mut RoundLane,
    ) -> Result<()> {
        // Eq. (5): store what the codec dropped this round.
        if let Some(res) = &mut self.residual {
            res.update(&lane.raw, &lane.update);
        }
        lane.scale_accepted = false;
        lane.scale_ms = 0;
        if !(pcfg.scaled && cfg.scale_epochs > 0 && !self.scale_idx.is_empty()) {
            return Ok(());
        }

        let t1 = self.clock.now();
        // Ŵ = W^(t) + Δ̂ (line 11): the base for scale training.
        self.hat.copy_from(&self.global);
        self.hat.add_delta(&lane.update);
        let val = self.val_batches(ds, mr.batch_size());
        let mut best_acc = self.eval_accuracy(mr, &self.hat, &val)?;
        copy_scales(&self.hat, &self.scale_idx, &mut self.baseline_scales);
        copy_scales(&self.hat, &self.scale_idx, &mut self.best_scales);
        let mut accepted = false;
        self.schedule.restart(); // CAWR warm restart at each main epoch
        for _e in 0..cfg.scale_epochs {
            for b in self.train_batches(ds, mr.batch_size()) {
                let lr = self.schedule.next_lr();
                mr.scale_step(
                    &mut self.hat,
                    &mut self.sopt,
                    cfg.scale_optimizer,
                    lr,
                    &b.x,
                    &b.y,
                )?;
            }
            let acc = self.eval_accuracy(mr, &self.hat, &val)?;
            // paper: keep the sub-epoch with best validation perf (>=)
            if acc >= best_acc {
                best_acc = acc;
                copy_scales(&self.hat, &self.scale_idx, &mut self.best_scales);
                accepted = true;
            }
        }
        // restore the selected (or baseline, if nothing improved) S
        let chosen = if accepted {
            &self.best_scales
        } else {
            &self.baseline_scales
        };
        for (slot, &i) in self.scale_idx.iter().enumerate() {
            self.hat.tensors[i].copy_from_slice(&chosen[slot]);
        }
        if accepted {
            // Stage the S-only difference for the fine-step stream
            // (encoded + accumulated into Δ̂ on the codec plane). Only the
            // scale tensors are written here and only `scale_idx` is ever
            // encoded from `sdelta`, so no full clear() is needed — its
            // non-scale tensors stay zero from construction.
            for &i in &self.scale_idx {
                for ((d, &h), &g) in lane.sdelta.tensors[i]
                    .iter_mut()
                    .zip(&self.hat.tensors[i])
                    .zip(&self.global.tensors[i])
                {
                    *d = h - g;
                }
            }
        }
        lane.scale_accepted = accepted;
        lane.scale_ms = self.clock.now().saturating_sub(t1).as_millis();
        Ok(())
    }

    /// Capture this client's round-boundary state for the session plane
    /// (checkpoints and shard-to-shard migration).
    pub fn export_state(&self) -> ClientState {
        ClientState {
            id: self.id,
            rng: self.rng.state(),
            sched_global: self.schedule.global_step() as u64,
            sched_period: self.schedule.period_step() as u64,
            train_order: self.train_idx.iter().map(|&i| i as u64).collect(),
            residual: self.residual.as_ref().map(|r| r.snapshot()),
            wopt: OptSnapshot::of(&self.wopt),
            sopt: OptSnapshot::of(&self.sopt),
        }
    }

    /// Install a [`ClientState`] captured by [`Client::export_state`].
    /// Every shape/consistency check runs **before** any field is
    /// mutated, so a malformed state errors with this client untouched
    /// (no partial apply). The `global` replica is not part of the state
    /// — callers set it from the server parameters separately.
    pub fn import_state(&mut self, st: &ClientState) -> Result<()> {
        if st.id != self.id {
            return Err(anyhow!(
                "client state for id {} offered to client {}",
                st.id,
                self.id
            ));
        }
        if st.train_order.len() != self.train_idx.len() {
            return Err(anyhow!(
                "client {}: state carries {} training indices, split has {}",
                self.id,
                st.train_order.len(),
                self.train_idx.len()
            ));
        }
        // The order must be a permutation of this client's own split —
        // a stray sample index would otherwise pass the length check and
        // panic deep inside batching instead of erroring here.
        {
            let mut ours: Vec<usize> = self.train_idx.clone();
            let mut theirs: Vec<usize> = st.train_order.iter().map(|&i| i as usize).collect();
            ours.sort_unstable();
            theirs.sort_unstable();
            if ours != theirs {
                return Err(anyhow!(
                    "client {}: state's training order is not a permutation of this \
                     client's split",
                    self.id
                ));
            }
        }
        match (&st.residual, &self.residual) {
            (Some(_), None) => {
                return Err(anyhow!(
                    "client {}: state carries a residual but the protocol runs without one",
                    self.id
                ))
            }
            (None, Some(_)) => {
                return Err(anyhow!(
                    "client {}: protocol expects a residual but the state has none",
                    self.id
                ))
            }
            _ => {}
        }
        // Load-bearing pre-check: `restore` runs *after* the scalar
        // fields below are already written, so its internal validation
        // alone could not prevent a partial apply.
        if let (Some(slabs), Some(res)) = (&st.residual, &self.residual) {
            res.check(slabs)?;
        }
        st.wopt.check(&self.wopt, "weight optimizer")?;
        st.sopt.check(&self.sopt, "scale optimizer")?;

        // All checks passed — apply.
        self.rng = XorShiftRng::from_state(st.rng);
        self.schedule
            .seek(st.sched_global as usize, st.sched_period as usize);
        for (t, &i) in self.train_idx.iter_mut().zip(&st.train_order) {
            *t = i as usize;
        }
        if let (Some(slabs), Some(res)) = (&st.residual, &mut self.residual) {
            res.restore(slabs)?;
        }
        st.wopt.install(&mut self.wopt);
        st.sopt.install(&mut self.sopt);
        Ok(())
    }

    /// Current scale-factor values per layer (Fig. 3 statistics).
    pub fn scale_values(&self) -> Vec<(String, Vec<f32>)> {
        self.scale_idx
            .iter()
            .map(|&i| {
                (
                    self.global.manifest.tensors[i].layer.clone(),
                    self.global.tensors[i].clone(),
                )
            })
            .collect()
    }
}
