//! FL client: Algorithm 1 lines 6–21 — local weight training, dynamic
//! sparsification of the differential update, scale-factor sub-epochs
//! with best-of-E validation selection, and the discard rule.

use std::time::Instant;

use anyhow::Result;

use crate::compression::{EncodeStats, Residual, UpdateCodec};
use crate::data::{batches, Batch, Dataset, XorShiftRng};
use crate::fl::config::{ExperimentConfig, ProtocolConfig};
use crate::fl::schedule::LrSchedule;
use crate::model::params::Delta;
use crate::model::{Group, ParamSet};
use crate::runtime::{ModelRuntime, OptState};

/// What one client sends upstream after a round.
#[derive(Debug)]
pub struct ClientRoundOutput {
    /// Encoded bitstreams (W-update stream, optional S-update stream).
    /// Empty for uncompressed FedAvg.
    pub streams: Vec<Vec<u8>>,
    /// The dequantized update the server will reconstruct (== decode of
    /// `streams`, or the exact raw update for plain FedAvg).
    pub update: Delta,
    pub up_bytes: usize,
    pub stats: EncodeStats,
    pub scale_accepted: bool,
    pub train_loss: f64,
    pub train_ms: u128,
    pub scale_ms: u128,
}

pub struct Client {
    pub id: usize,
    /// This client's replica of the global model state; only ever mutated
    /// by applying broadcast deltas (so server/client divergence is a bug,
    /// asserted in integration tests).
    pub global: ParamSet,
    wopt: OptState,
    sopt: OptState,
    pub residual: Option<Residual>,
    pub schedule: LrSchedule,
    train_idx: Vec<usize>,
    val_idx: Vec<usize>,
    rng: XorShiftRng,
}

impl Client {
    pub fn new(
        id: usize,
        init: ParamSet,
        train_idx: Vec<usize>,
        val_idx: Vec<usize>,
        schedule: LrSchedule,
        residuals: bool,
        seed: u64,
    ) -> Self {
        let manifest = init.manifest.clone();
        Self {
            id,
            wopt: OptState::zeros(&manifest, Group::Weight),
            sopt: OptState::zeros(&manifest, Group::Scale),
            residual: residuals.then(|| Residual::zeros(manifest)),
            global: init,
            schedule,
            train_idx,
            val_idx,
            rng: XorShiftRng::new(seed ^ 0xC11E57),
        }
    }

    /// Apply the server broadcast (Algorithm 1 lines 7–8).
    pub fn apply_broadcast(&mut self, delta: &Delta) {
        self.global.add_delta(delta);
    }

    fn train_batches(&mut self, ds: &Dataset, batch: usize) -> Vec<Batch> {
        self.rng.shuffle(&mut self.train_idx);
        batches(ds, &self.train_idx, batch)
    }

    fn val_batches(&self, ds: &Dataset, batch: usize) -> Vec<Batch> {
        batches(ds, &self.val_idx, batch)
    }

    fn eval_accuracy(&self, mr: &ModelRuntime, params: &ParamSet, val: &[Batch]) -> Result<f64> {
        let mut correct = 0.0f64;
        let mut total = 0usize;
        for b in val {
            let out = mr.eval_step(params, &b.x, &b.y)?;
            correct += out.correct as f64;
            total += b.size;
        }
        Ok(if total == 0 { 0.0 } else { correct / total as f64 })
    }

    /// One communication round (Algorithm 1 lines 6–21).
    pub fn run_round(
        &mut self,
        mr: &ModelRuntime,
        ds: &Dataset,
        cfg: &ExperimentConfig,
        pcfg: &ProtocolConfig,
    ) -> Result<ClientRoundOutput> {
        let manifest = self.global.manifest.clone();
        let update_idx = manifest.update_indices();
        let scale_idx = manifest.group_indices(Group::Scale);

        // ---- local weight training (line 9; S frozen inside the HLO) ----
        let t0 = Instant::now();
        let mut work = self.global.clone();
        let mut loss_sum = 0.0f64;
        let mut loss_n = 0usize;
        for _ in 0..cfg.local_epochs {
            for b in self.train_batches(ds, mr.batch_size()) {
                let out =
                    mr.train_step(&mut work, &mut self.wopt, cfg.optimizer, cfg.lr, &b.x, &b.y)?;
                loss_sum += out.loss as f64;
                loss_n += 1;
            }
        }
        let train_ms = t0.elapsed().as_millis();

        // ---- differential update (Eq. 1) + residual injection (Eq. 5) ----
        let mut raw = work.delta_from(&self.global);
        if let Some(res) = &self.residual {
            res.inject(&mut raw);
        }

        // ---- sparsify + quantize + encode (lines 10–11) ----
        let (mut streams, w_update, stats, mut up_bytes) = match &pcfg.codec {
            None => {
                // plain FedAvg: "transmit" the exact raw update
                let bytes = crate::compression::cabac::codec::raw_bytes(&work, &update_idx);
                (Vec::new(), raw.clone(), EncodeStats::default(), bytes)
            }
            Some(codec) => {
                let (bytes, deq, stats) = codec.encode(raw.clone(), &update_idx);
                let n = bytes.len();
                (vec![bytes], deq, stats, n)
            }
        };
        if let Some(res) = &mut self.residual {
            res.update(&raw, &w_update);
        }
        // Ŵ = W^(t) + Δ̂ (line 11): the base for scale training.
        let mut hat = self.global.clone();
        hat.add_delta(&w_update);

        // ---- scale-factor sub-epochs (lines 13–19) ----
        let mut scale_accepted = false;
        let mut scale_ms = 0u128;
        let mut update = w_update;
        if pcfg.scaled && cfg.scale_epochs > 0 && !scale_idx.is_empty() {
            let t1 = Instant::now();
            let val = self.val_batches(ds, mr.batch_size());
            let mut best_acc = self.eval_accuracy(mr, &hat, &val)?;
            let baseline_scales: Vec<Vec<f32>> =
                scale_idx.iter().map(|&i| hat.tensors[i].clone()).collect();
            let mut best_scales = baseline_scales.clone();
            self.schedule.restart(); // CAWR warm restart at each main epoch
            for _e in 0..cfg.scale_epochs {
                for b in self.train_batches(ds, mr.batch_size()) {
                    let lr = self.schedule.next_lr();
                    mr.scale_step(
                        &mut hat,
                        &mut self.sopt,
                        cfg.scale_optimizer,
                        lr,
                        &b.x,
                        &b.y,
                    )?;
                }
                let acc = self.eval_accuracy(mr, &hat, &val)?;
                // paper: keep the sub-epoch with best validation perf (>=)
                if acc >= best_acc {
                    best_acc = acc;
                    best_scales = scale_idx.iter().map(|&i| hat.tensors[i].clone()).collect();
                    scale_accepted = true;
                }
            }
            // restore the selected (or baseline, if nothing improved) S
            let chosen = if scale_accepted {
                &best_scales
            } else {
                &baseline_scales
            };
            for (slot, &i) in scale_idx.iter().enumerate() {
                hat.tensors[i] = chosen[slot].clone();
            }
            if scale_accepted {
                // re-calculate differences considering S, quantize, encode
                // (fine step; transmitted as a second stream)
                let codec = pcfg.codec.unwrap_or(UpdateCodec::quant_only());
                let s_codec = UpdateCodec {
                    sparsify: crate::compression::SparsifyMode::None,
                    quant: codec.quant,
                    ternary: false,
                };
                let sdelta = hat.delta_from(&self.global);
                let mut only_s = Delta::zeros(manifest.clone());
                for &i in &scale_idx {
                    only_s.tensors[i] = sdelta.tensors[i].clone();
                }
                let (sbytes, sdeq, _) = s_codec.encode(only_s, &scale_idx);
                // keep Ŵ's S consistent with what the server reconstructs
                for &i in &scale_idx {
                    let mut t = self.global.tensors[i].clone();
                    for (x, d) in t.iter_mut().zip(&sdeq.tensors[i]) {
                        *x += d;
                    }
                    hat.tensors[i] = t;
                }
                update.accumulate(&sdeq);
                up_bytes += sbytes.len();
                streams.push(sbytes);
            }
            scale_ms = t1.elapsed().as_millis();
        }

        Ok(ClientRoundOutput {
            streams,
            update,
            up_bytes,
            stats,
            scale_accepted,
            train_loss: if loss_n == 0 {
                0.0
            } else {
                loss_sum / loss_n as f64
            },
            train_ms,
            scale_ms,
        })
    }

    /// Current scale-factor values per layer (Fig. 3 statistics).
    pub fn scale_values(&self) -> Vec<(String, Vec<f32>)> {
        self.global
            .manifest
            .group_indices(Group::Scale)
            .iter()
            .map(|&i| {
                (
                    self.global.manifest.tensors[i].layer.clone(),
                    self.global.tensors[i].clone(),
                )
            })
            .collect()
    }
}
