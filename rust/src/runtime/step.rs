//! Step executors: marshal [`ParamSet`]s to XLA literals, run the
//! compiled step, and write results back.
//!
//! Wire convention (mirrors python/compile/steps.py):
//!   train/scale: params… m[g]… v[g]… t lr x y  →  params… m… v… t loss correct
//!   eval:        params… x y                  →  loss correct

use std::cell::RefCell;
use std::rc::Rc;
use std::sync::Arc;

use anyhow::{anyhow, Result};

use crate::model::{Group, Manifest, ParamSet};

use super::{ArtifactSet, Optimizer, Runtime};

/// Adam/SGD state for one training group (m, v in group order + step t).
#[derive(Debug, Clone)]
pub struct OptState {
    /// The training group this state belongs to.
    pub group: Group,
    /// First-moment estimates, one slot per group tensor.
    pub m: Vec<Vec<f32>>,
    /// Second-moment estimates, one slot per group tensor.
    pub v: Vec<Vec<f32>>,
    /// Adam step counter.
    pub t: f32,
}

impl OptState {
    /// Fresh optimizer state for a manifest's training group.
    pub fn zeros(manifest: &Manifest, group: Group) -> Self {
        let sizes: Vec<usize> = manifest
            .group_indices(group)
            .iter()
            .map(|&i| manifest.tensors[i].numel())
            .collect();
        Self {
            group,
            m: sizes.iter().map(|&n| vec![0.0; n]).collect(),
            v: sizes.iter().map(|&n| vec![0.0; n]).collect(),
            t: 0.0,
        }
    }

    /// Reset (used for warm-restart style scale-optimizer re-inits).
    pub fn reset(&mut self) {
        for t in self.m.iter_mut().chain(self.v.iter_mut()) {
            t.iter_mut().for_each(|x| *x = 0.0);
        }
        self.t = 0.0;
    }
}

/// Scalar results of one step execution.
#[derive(Debug, Clone, Copy)]
pub struct StepOutput {
    /// Mean batch loss.
    pub loss: f32,
    /// Number of correct top-1 predictions in the batch.
    pub correct: f32,
}

/// All compiled executables of one model variant (lazily compiled).
pub struct ModelRuntime<'rt> {
    rt: &'rt Runtime,
    /// The variant's on-disk artifact set.
    pub artifacts: ArtifactSet,
    /// The variant's model contract.
    pub manifest: Arc<Manifest>,
    weight_idx: Vec<usize>,
    scale_idx: Vec<usize>,
    train_adam: RefCell<Option<Rc<xla::PjRtLoadedExecutable>>>,
    train_sgd: RefCell<Option<Rc<xla::PjRtLoadedExecutable>>>,
    scale_adam: RefCell<Option<Rc<xla::PjRtLoadedExecutable>>>,
    scale_sgd: RefCell<Option<Rc<xla::PjRtLoadedExecutable>>>,
    eval: RefCell<Option<Rc<xla::PjRtLoadedExecutable>>>,
    predict: RefCell<Option<Rc<xla::PjRtLoadedExecutable>>>,
    /// Cumulative host→device→host marshalling + execute time (perf pass).
    pub exec_calls: RefCell<u64>,
}

fn literal(data: &[f32], shape: &[usize]) -> Result<xla::Literal> {
    // Single-copy construction (perf pass): vec1+reshape would copy the
    // tensor twice; create_from_shape_and_untyped_data copies once.
    // SAFETY: reinterpreting `data`'s f32s as their raw bytes — same
    // allocation and lifetime (the slice borrows `data` and dies before
    // it), length from size_of_val so it spans exactly the f32s, and
    // u8's alignment (1) is always satisfied. Every f32 bit pattern is
    // a valid u8 sequence, so no uninitialized or invalid bytes.
    let bytes = unsafe {
        std::slice::from_raw_parts(data.as_ptr() as *const u8, std::mem::size_of_val(data))
    };
    xla::Literal::create_from_shape_and_untyped_data(xla::ElementType::F32, shape, bytes)
        .map_err(|e| anyhow!("literal create: {e}"))
}

impl<'rt> ModelRuntime<'rt> {
    /// Wrap an artifact set; step functions compile lazily on first use.
    pub fn load(rt: &'rt Runtime, artifacts: ArtifactSet) -> Result<Self> {
        let manifest = artifacts.manifest.clone();
        Ok(Self {
            rt,
            manifest: manifest.clone(),
            weight_idx: manifest.group_indices(Group::Weight),
            scale_idx: manifest.group_indices(Group::Scale),
            artifacts,
            train_adam: RefCell::new(None),
            train_sgd: RefCell::new(None),
            scale_adam: RefCell::new(None),
            scale_sgd: RefCell::new(None),
            eval: RefCell::new(None),
            predict: RefCell::new(None),
            exec_calls: RefCell::new(0),
        })
    }

    /// Open a variant by name under an artifacts root.
    pub fn open(rt: &'rt Runtime, root: impl AsRef<std::path::Path>, variant: &str) -> Result<Self> {
        Self::load(rt, ArtifactSet::open_variant(root, variant)?)
    }

    /// The fixed batch dimension baked into the step HLOs.
    pub fn batch_size(&self) -> usize {
        self.manifest.batch
    }

    /// The variant's initial parameters (`init.bin`).
    pub fn init_params(&self) -> Result<ParamSet> {
        self.artifacts.init_params()
    }

    /// Fresh optimizer state for one training group.
    pub fn opt_state(&self, group: Group) -> OptState {
        OptState::zeros(&self.manifest, group)
    }

    fn exe(
        &self,
        slot: &RefCell<Option<Rc<xla::PjRtLoadedExecutable>>>,
        file: &str,
    ) -> Result<()> {
        if slot.borrow().is_none() {
            // process-wide cache: sweeps over the same variant reuse the
            // compiled executable instead of re-running the XLA compiler
            let path = self.artifacts.hlo_path(file);
            let exe = self
                .rt
                .compile_cached(&path, || self.artifacts.compile(self.rt, file))?;
            *slot.borrow_mut() = Some(exe);
        }
        Ok(())
    }

    fn group_idx(&self, group: Group) -> Result<&[usize]> {
        match group {
            Group::Weight => Ok(&self.weight_idx),
            Group::Scale => Ok(&self.scale_idx),
            _ => Err(anyhow!("no optimizer group for {group:?}")),
        }
    }

    /// One weight-training step (Algorithm 1 line 9; S frozen inside HLO).
    pub fn train_step(
        &self,
        params: &mut ParamSet,
        opt: &mut OptState,
        optimizer: Optimizer,
        lr: f32,
        x: &[f32],
        y: &[f32],
    ) -> Result<StepOutput> {
        debug_assert_eq!(opt.group, Group::Weight);
        let (slot, file) = match optimizer {
            Optimizer::Adam => (&self.train_adam, "train_step.hlo.txt"),
            Optimizer::Sgd => (&self.train_sgd, "train_step_sgd.hlo.txt"),
        };
        self.exe(slot, file)?;
        let guard = slot.borrow();
        self.run_opt_step(guard.as_ref().unwrap(), Group::Weight, params, opt, lr, x, y)
    }

    /// One scale-factor sub-epoch step (Algorithm 1 line 14; W + BN state
    /// frozen inside the HLO — the model normalizes with running stats).
    pub fn scale_step(
        &self,
        params: &mut ParamSet,
        opt: &mut OptState,
        optimizer: Optimizer,
        lr: f32,
        x: &[f32],
        y: &[f32],
    ) -> Result<StepOutput> {
        debug_assert_eq!(opt.group, Group::Scale);
        let (slot, file) = match optimizer {
            Optimizer::Adam => (&self.scale_adam, "scale_step_adam.hlo.txt"),
            Optimizer::Sgd => (&self.scale_sgd, "scale_step_sgd.hlo.txt"),
        };
        self.exe(slot, file)?;
        let guard = slot.borrow();
        self.run_opt_step(guard.as_ref().unwrap(), Group::Scale, params, opt, lr, x, y)
    }

    /// Loss + correct-count on one batch with frozen params (BN running
    /// stats, no updates).
    pub fn eval_step(&self, params: &ParamSet, x: &[f32], y: &[f32]) -> Result<StepOutput> {
        self.exe(&self.eval, "eval_step.hlo.txt")?;
        let guard = self.eval.borrow();
        let exe = guard.as_ref().unwrap();
        let mut inputs = Vec::with_capacity(self.manifest.tensors.len() + 2);
        for (t, spec) in params.tensors.iter().zip(&self.manifest.tensors) {
            inputs.push(literal(t, &spec.shape)?);
        }
        inputs.push(self.batch_x_literal(x)?);
        inputs.push(self.batch_y_literal(y)?);
        let outs = self.execute(exe, &inputs)?;
        if outs.len() != 2 {
            return Err(anyhow!("eval: expected 2 outputs, got {}", outs.len()));
        }
        Ok(StepOutput {
            loss: outs[0].to_vec::<f32>()?[0],
            correct: outs[1].to_vec::<f32>()?[0],
        })
    }

    /// Top-1 predictions for one batch (f32 class indices, length B).
    pub fn predict_step(&self, params: &ParamSet, x: &[f32]) -> Result<Vec<f32>> {
        self.exe(&self.predict, "predict_step.hlo.txt")?;
        let guard = self.predict.borrow();
        let exe = guard.as_ref().unwrap();
        let mut inputs = Vec::with_capacity(self.manifest.tensors.len() + 1);
        for (t, spec) in params.tensors.iter().zip(&self.manifest.tensors) {
            inputs.push(literal(t, &spec.shape)?);
        }
        inputs.push(self.batch_x_literal(x)?);
        let outs = self.execute(exe, &inputs)?;
        if outs.len() != 1 {
            return Err(anyhow!("predict: expected 1 output, got {}", outs.len()));
        }
        Ok(outs[0].to_vec::<f32>()?)
    }

    fn batch_x_literal(&self, x: &[f32]) -> Result<xla::Literal> {
        let (h, w, c) = (
            self.manifest.input[0],
            self.manifest.input[1],
            self.manifest.input[2],
        );
        let b = self.manifest.batch;
        if x.len() != b * h * w * c {
            return Err(anyhow!("x len {} != {}x{}x{}x{}", x.len(), b, h, w, c));
        }
        literal(x, &[b, h, w, c])
    }

    fn batch_y_literal(&self, y: &[f32]) -> Result<xla::Literal> {
        let b = self.manifest.batch;
        if y.len() != b * self.manifest.classes {
            return Err(anyhow!("y len {} != {}x{}", y.len(), b, self.manifest.classes));
        }
        literal(y, &[b, self.manifest.classes])
    }

    fn run_opt_step(
        &self,
        exe: &xla::PjRtLoadedExecutable,
        group: Group,
        params: &mut ParamSet,
        opt: &mut OptState,
        lr: f32,
        x: &[f32],
        y: &[f32],
    ) -> Result<StepOutput> {
        let gidx = self.group_idx(group)?.to_vec();
        let n = self.manifest.tensors.len();
        let g = gidx.len();
        let mut inputs = Vec::with_capacity(n + 2 * g + 4);
        for (t, spec) in params.tensors.iter().zip(&self.manifest.tensors) {
            inputs.push(literal(t, &spec.shape)?);
        }
        for (slot, &i) in gidx.iter().enumerate() {
            inputs.push(literal(&opt.m[slot], &self.manifest.tensors[i].shape)?);
        }
        for (slot, &i) in gidx.iter().enumerate() {
            inputs.push(literal(&opt.v[slot], &self.manifest.tensors[i].shape)?);
        }
        inputs.push(xla::Literal::scalar(opt.t));
        inputs.push(xla::Literal::scalar(lr));
        inputs.push(self.batch_x_literal(x)?);
        inputs.push(self.batch_y_literal(y)?);

        let outs = self.execute(exe, &inputs)?;
        let want = n + 2 * g + 3;
        if outs.len() != want {
            return Err(anyhow!("step: expected {want} outputs, got {}", outs.len()));
        }
        for (i, out) in outs[..n].iter().enumerate() {
            params.tensors[i] = out.to_vec::<f32>()?;
        }
        for slot in 0..g {
            opt.m[slot] = outs[n + slot].to_vec::<f32>()?;
            opt.v[slot] = outs[n + g + slot].to_vec::<f32>()?;
        }
        opt.t = outs[n + 2 * g].to_vec::<f32>()?[0];
        Ok(StepOutput {
            loss: outs[n + 2 * g + 1].to_vec::<f32>()?[0],
            correct: outs[n + 2 * g + 2].to_vec::<f32>()?[0],
        })
    }

    fn execute(
        &self,
        exe: &xla::PjRtLoadedExecutable,
        inputs: &[xla::Literal],
    ) -> Result<Vec<xla::Literal>> {
        *self.exec_calls.borrow_mut() += 1;
        let result = exe
            .execute::<xla::Literal>(inputs)
            .map_err(|e| anyhow!("execute: {e}"))?;
        let lit = result[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("to_literal: {e}"))?;
        lit.to_tuple().map_err(|e| anyhow!("to_tuple: {e}"))
    }
}
