//! PJRT runtime: loads AOT artifacts (`artifacts/<variant>/*.hlo.txt`)
//! and executes train / scale-train / eval steps from the rust hot path.
//!
//! HLO **text** is the interchange format (see python/compile/aot.py);
//! `HloModuleProto::from_text_file` reassigns instruction ids so the
//! xla_extension 0.5.1 backend accepts modules lowered by jax >= 0.5.
//!
//! Python never runs here — after `make artifacts` the binary is
//! self-contained.

mod artifacts;
mod step;

pub use artifacts::{ArtifactSet, Optimizer};
pub use step::{ModelRuntime, OptState, StepOutput};

use std::cell::RefCell;
use std::collections::HashMap;
use std::path::PathBuf;
use std::rc::Rc;

use anyhow::Result;

/// Process-wide PJRT CPU client. Creating more than one CPU client per
/// process is wasteful (each spins up its own thread pool), so experiments
/// share a single [`Runtime`]. Compiled executables are cached by artifact
/// path: harness sweeps build many [`ModelRuntime`]s over the same variant
/// and recompiling each time costs seconds per step function (perf pass,
/// EXPERIMENTS.md §Perf).
pub struct Runtime {
    client: xla::PjRtClient,
    exe_cache: RefCell<HashMap<PathBuf, Rc<xla::PjRtLoadedExecutable>>>,
}

impl Runtime {
    /// Create the PJRT CPU client (errors cleanly on the vendored null
    /// backend — callers treat that as "no compute plane available").
    pub fn cpu() -> Result<Self> {
        let client = xla::PjRtClient::cpu().map_err(|e| anyhow::anyhow!("pjrt cpu: {e}"))?;
        Ok(Self {
            client,
            exe_cache: RefCell::new(HashMap::new()),
        })
    }

    /// The underlying PJRT client.
    pub fn client(&self) -> &xla::PjRtClient {
        &self.client
    }

    /// PJRT platform name (e.g. `cpu`).
    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    pub(crate) fn compile_cached(
        &self,
        path: &std::path::Path,
        compile: impl FnOnce() -> Result<xla::PjRtLoadedExecutable>,
    ) -> Result<Rc<xla::PjRtLoadedExecutable>> {
        if let Some(exe) = self.exe_cache.borrow().get(path) {
            return Ok(exe.clone());
        }
        let exe = Rc::new(compile()?);
        self.exe_cache
            .borrow_mut()
            .insert(path.to_path_buf(), exe.clone());
        Ok(exe)
    }

    /// Number of distinct executables compiled so far (diagnostics).
    pub fn compiled_count(&self) -> usize {
        self.exe_cache.borrow().len()
    }
}
