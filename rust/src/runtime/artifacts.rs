//! Artifact discovery/compilation: one compiled PJRT executable per step
//! function per model variant, cached after first compile.

use std::path::{Path, PathBuf};
use std::sync::Arc;

use anyhow::{anyhow, Context, Result};

use crate::model::Manifest;

use super::Runtime;

/// Which optimizer drives a step (baked into the HLO at AOT time; the
/// learning rate stays a runtime input so rust owns the schedule).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Optimizer {
    /// Adam with the jax defaults.
    Adam,
    /// Plain SGD.
    Sgd,
}

impl std::str::FromStr for Optimizer {
    type Err = anyhow::Error;
    fn from_str(s: &str) -> Result<Self> {
        match s.to_ascii_lowercase().as_str() {
            "adam" => Ok(Optimizer::Adam),
            "sgd" => Ok(Optimizer::Sgd),
            other => Err(anyhow!("unknown optimizer {other:?}")),
        }
    }
}

/// The on-disk artifact set of one model variant.
pub struct ArtifactSet {
    /// Variant directory (`artifacts/<variant>`).
    pub dir: PathBuf,
    /// The variant's parsed model contract.
    pub manifest: Arc<Manifest>,
}

impl ArtifactSet {
    /// Open a variant directory and load its manifest.
    pub fn open(dir: impl AsRef<Path>) -> Result<Self> {
        let dir = dir.as_ref().to_path_buf();
        let manifest = Arc::new(Manifest::load(dir.join("manifest.tsv"))?);
        Ok(Self { dir, manifest })
    }

    /// Root-relative helper: `ArtifactSet::open_variant("artifacts", "tiny_cnn")`.
    pub fn open_variant(root: impl AsRef<Path>, variant: &str) -> Result<Self> {
        Self::open(root.as_ref().join(variant))
    }

    /// Absolute path of one step function's HLO text file.
    pub fn hlo_path(&self, file: &str) -> PathBuf {
        self.dir.join(file)
    }

    /// Load the variant's initial parameters (`init.bin`).
    pub fn init_params(&self) -> Result<crate::model::ParamSet> {
        crate::model::ParamSet::from_bundle(self.manifest.clone(), self.dir.join("init.bin"))
    }

    pub(crate) fn compile(
        &self,
        rt: &Runtime,
        file: &str,
    ) -> Result<xla::PjRtLoadedExecutable> {
        let path = self.hlo_path(file);
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().ok_or_else(|| anyhow!("non-utf8 path"))?,
        )
        .map_err(|e| anyhow!("parsing {}: {e}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        rt.client()
            .compile(&comp)
            .map_err(|e| anyhow!("compiling {}: {e}", path.display()))
            .with_context(|| format!("artifact {}", path.display()))
    }
}
