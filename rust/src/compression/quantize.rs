//! Uniform quantization (paper Sec. 3): integer levels
//! `[-q, …, -1, 0, 1, …, p] · step_size`.
//!
//! The step sizes follow the paper's Sec. 5.1: a coarse step for weight
//! updates (4.88e-4 unidirectional, 2.44e-4 bidirectional — halved
//! because quantization noise is applied on both legs) and a fine step
//! (2.38e-6) for scale factors, biases and BatchNorm parameters.

use crate::model::TensorSpec;

/// Coarse weight-update step, unidirectional setups (paper Sec. 5.1).
pub const STEP_COARSE_UNI: f32 = 4.88e-4;
/// Coarse weight-update step, bidirectional setups (halved — two legs).
pub const STEP_COARSE_BI: f32 = 2.44e-4;
/// Fine step for scale factors, biases and BatchNorm parameters.
pub const STEP_FINE: f32 = 2.38e-6;

/// Nearest integer quantization level of `x` at `step`.
#[inline]
pub fn quantize(x: f32, step: f32) -> i32 {
    (x / step).round() as i32
}

/// Reconstruction of level `q` at `step`.
#[inline]
pub fn dequantize(q: i32, step: f32) -> f32 {
    q as f32 * step
}

/// Quantization step assignment per tensor (paper Sec. 5.1): row-structured
/// weight updates take the coarse step; scaling factors, biases and
/// BatchNorm parameters the fine step.
#[derive(Debug, Clone, Copy)]
pub struct QuantConfig {
    /// Step for row-structured weight updates.
    pub coarse_step: f32,
    /// Step for scale/bias/BatchNorm updates.
    pub fine_step: f32,
}

impl Default for QuantConfig {
    fn default() -> Self {
        Self {
            coarse_step: STEP_COARSE_UNI,
            fine_step: STEP_FINE,
        }
    }
}

impl QuantConfig {
    /// Bidirectional preset: halved coarse step (paper Sec. 5.1).
    pub fn bidirectional() -> Self {
        Self {
            coarse_step: STEP_COARSE_BI,
            fine_step: STEP_FINE,
        }
    }

    /// The step a tensor quantizes with (coarse vs fine by kind).
    #[inline]
    pub fn step_for(&self, spec: &TensorSpec) -> f32 {
        if spec.kind.is_fine_quantized() {
            self.fine_step
        } else {
            self.coarse_step
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn quantize_rounds_to_nearest() {
        assert_eq!(quantize(0.0, 0.5), 0);
        assert_eq!(quantize(0.24, 0.5), 0);
        assert_eq!(quantize(0.26, 0.5), 1);
        assert_eq!(quantize(-0.26, 0.5), -1);
        assert_eq!(quantize(1.6, 0.5), 3);
    }

    #[test]
    fn dequantize_error_bounded_by_half_step() {
        let step = 4.88e-4;
        for i in -1000..1000 {
            let x = i as f32 * 1.3e-4;
            let err = (dequantize(quantize(x, step), step) - x).abs();
            assert!(err <= step / 2.0 + 1e-9, "x={x} err={err}");
        }
    }
}
