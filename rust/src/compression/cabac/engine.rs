//! Adaptive binary arithmetic coding engine.
//!
//! DeepCABAC (the NNC standard's entropy stage) is a context-adaptive
//! binary arithmetic coder; we implement the same principle with the
//! well-known LZMA-style range coder: 32-bit range, 11-bit adaptive
//! probability states, byte-wise renormalization with carry propagation,
//! plus a bypass ("direct bits") mode for equiprobable suffix bits.
//!
//! The encoder/decoder pair is exactly inverse: `decode(encode(bits))`
//! reproduces the bit sequence for any interleaving of context-coded and
//! bypass bits (property-tested in `rust/tests/integration_compression.rs`).

/// Probability resolution in bits.
pub const PROB_BITS: u32 = 11;
/// Probability denominator (2048).
pub const PROB_ONE: u16 = 1 << PROB_BITS;
/// Initial (equiprobable) state of a fresh context model.
pub const PROB_INIT: u16 = PROB_ONE / 2;
/// Adaptation rate: higher = slower adaptation. 5 is the LZMA classic.
pub const MOVE_BITS: u32 = 5;
const TOP: u32 = 1 << 24;

/// One adaptive binary probability state ("context model").
#[derive(Debug, Clone, Copy)]
pub struct BitModel {
    /// Probability that the next bit is 0, in [0, 2048).
    pub p0: u16,
    /// When false the state never adapts (the "no context modeling"
    /// ablation: every bit codes at a fixed probability).
    pub adapt: bool,
}

impl Default for BitModel {
    fn default() -> Self {
        Self {
            p0: PROB_INIT,
            adapt: true,
        }
    }
}

impl BitModel {
    /// Frozen-probability model (ablation benches).
    pub fn frozen() -> Self {
        Self {
            p0: PROB_INIT,
            adapt: false,
        }
    }

    #[inline]
    fn update(&mut self, bit: u8) {
        if !self.adapt {
            return;
        }
        if bit == 0 {
            self.p0 += (PROB_ONE - self.p0) >> MOVE_BITS;
        } else {
            self.p0 -= self.p0 >> MOVE_BITS;
        }
    }
}

/// Range encoder over a growable byte buffer.
pub struct Encoder {
    low: u64,
    range: u32,
    cache: u8,
    cache_size: u64,
    out: Vec<u8>,
}

impl Default for Encoder {
    fn default() -> Self {
        Self::new()
    }
}

impl Encoder {
    /// Encoder over a fresh buffer.
    pub fn new() -> Self {
        Self::with_buffer(Vec::new())
    }

    /// Encode into a recycled buffer (cleared, capacity kept): the
    /// steady-state FL round re-uses one payload buffer per client, so
    /// encoding allocates nothing once buffers have grown to size.
    /// The produced bytes are identical to [`Encoder::new`]'s.
    ///
    /// ```
    /// use fsfl::compression::cabac::engine::{BitModel, Decoder, Encoder};
    ///
    /// let recycled = Vec::with_capacity(64); // e.g. last round's payload
    /// let mut enc = Encoder::with_buffer(recycled);
    /// let mut model = BitModel::default();
    /// for bit in [1u8, 0, 0, 1, 0, 1, 1, 0] {
    ///     enc.encode_bit(&mut model, bit);
    /// }
    /// let bytes = enc.finish();
    ///
    /// let mut dec = Decoder::new(&bytes);
    /// let mut model = BitModel::default();
    /// let decoded: Vec<u8> = (0..8).map(|_| dec.decode_bit(&mut model)).collect();
    /// assert_eq!(decoded, [1, 0, 0, 1, 0, 1, 1, 0]);
    /// ```
    pub fn with_buffer(mut out: Vec<u8>) -> Self {
        out.clear();
        Self {
            low: 0,
            range: u32::MAX,
            cache: 0,
            cache_size: 1,
            out,
        }
    }

    #[inline]
    fn shift_low(&mut self) {
        if self.low < 0xFF00_0000 || self.low > 0xFFFF_FFFF {
            let carry = (self.low >> 32) as u8;
            if self.cache_size != 0 {
                self.out.push(self.cache.wrapping_add(carry));
                for _ in 1..self.cache_size {
                    self.out.push(0xFFu8.wrapping_add(carry));
                }
            }
            self.cache = (self.low >> 24) as u8;
            self.cache_size = 0;
        }
        self.cache_size += 1;
        self.low = (self.low << 8) & 0xFFFF_FFFF;
    }

    /// Encode one bit with an adaptive context model.
    #[inline]
    pub fn encode_bit(&mut self, model: &mut BitModel, bit: u8) {
        let bound = (self.range >> PROB_BITS) * model.p0 as u32;
        if bit == 0 {
            self.range = bound;
        } else {
            self.low += bound as u64;
            self.range -= bound;
        }
        model.update(bit);
        while self.range < TOP {
            self.range <<= 8;
            self.shift_low();
        }
    }

    /// Encode `n` equiprobable bits, most-significant first (bypass mode —
    /// used for Exp-Golomb suffixes where adaptation buys nothing).
    #[inline]
    pub fn encode_direct(&mut self, value: u32, n: u32) {
        for i in (0..n).rev() {
            self.range >>= 1;
            let bit = (value >> i) & 1;
            if bit != 0 {
                self.low += self.range as u64;
            }
            while self.range < TOP {
                self.range <<= 8;
                self.shift_low();
            }
        }
    }

    /// Flush and return the bitstream.
    pub fn finish(mut self) -> Vec<u8> {
        for _ in 0..5 {
            self.shift_low();
        }
        self.out
    }

    /// Upper bound on the finished bitstream length.
    pub fn len_upper_bound(&self) -> usize {
        self.out.len() + 5
    }
}

/// Range decoder over a byte slice.
pub struct Decoder<'a> {
    code: u32,
    range: u32,
    input: &'a [u8],
    pos: usize,
}

impl<'a> Decoder<'a> {
    /// Decoder over an encoded bitstream (reads past-the-end as zeros).
    pub fn new(input: &'a [u8]) -> Self {
        let mut d = Self {
            code: 0,
            range: u32::MAX,
            input,
            pos: 0,
        };
        // First encoder byte is always 0 (cache priming); consume 5 bytes.
        for _ in 0..5 {
            d.code = (d.code << 8) | d.next_byte() as u32;
        }
        d
    }

    #[inline]
    fn next_byte(&mut self) -> u8 {
        let b = self.input.get(self.pos).copied().unwrap_or(0);
        self.pos += 1;
        b
    }

    /// Decode one context-coded bit (inverse of [`Encoder::encode_bit`]).
    #[inline]
    pub fn decode_bit(&mut self, model: &mut BitModel) -> u8 {
        let bound = (self.range >> PROB_BITS) * model.p0 as u32;
        let bit = if self.code < bound {
            self.range = bound;
            0
        } else {
            self.code -= bound;
            self.range -= bound;
            1
        };
        model.update(bit);
        while self.range < TOP {
            self.range <<= 8;
            self.code = (self.code << 8) | self.next_byte() as u32;
        }
        bit
    }

    /// Decode `n` bypass bits (inverse of [`Encoder::encode_direct`]).
    #[inline]
    pub fn decode_direct(&mut self, n: u32) -> u32 {
        let mut v = 0u32;
        for _ in 0..n {
            self.range >>= 1;
            let bit = if self.code >= self.range {
                self.code -= self.range;
                1
            } else {
                0
            };
            v = (v << 1) | bit;
            while self.range < TOP {
                self.range <<= 8;
                self.code = (self.code << 8) | self.next_byte() as u32;
            }
        }
        v
    }

    /// Bytes consumed so far (diagnostics).
    pub fn position(&self) -> usize {
        self.pos
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn context_bits_roundtrip() {
        let bits: Vec<u8> = (0..4000u32).map(|i| ((i * i + i / 7) % 5 == 0) as u8).collect();
        let mut enc = Encoder::new();
        let mut m = BitModel::default();
        for &b in &bits {
            enc.encode_bit(&mut m, b);
        }
        let bytes = enc.finish();
        let mut dec = Decoder::new(&bytes);
        let mut m = BitModel::default();
        for &b in &bits {
            assert_eq!(dec.decode_bit(&mut m), b);
        }
    }

    #[test]
    fn direct_bits_roundtrip() {
        let mut enc = Encoder::new();
        let vals: Vec<(u32, u32)> = (0..500u32)
            .map(|i| (i.wrapping_mul(2654435761) % (1 << (i % 24 + 1)), i % 24 + 1))
            .collect();
        for &(v, n) in &vals {
            enc.encode_direct(v, n);
        }
        let bytes = enc.finish();
        let mut dec = Decoder::new(&bytes);
        for &(v, n) in &vals {
            assert_eq!(dec.decode_direct(n), v, "n={n}");
        }
    }

    #[test]
    fn skewed_bits_compress() {
        // 99% zeros should code far below 1 bit/symbol.
        let n = 100_000;
        let bits: Vec<u8> = (0..n).map(|i| (i % 100 == 0) as u8).collect();
        let mut enc = Encoder::new();
        let mut m = BitModel::default();
        for &b in &bits {
            enc.encode_bit(&mut m, b);
        }
        let bytes = enc.finish();
        assert!(
            bytes.len() < n / 64,
            "expected < {} bytes, got {}",
            n / 64,
            bytes.len()
        );
    }

    #[test]
    fn interleaved_context_and_direct() {
        let mut enc = Encoder::new();
        let mut m0 = BitModel::default();
        let mut m1 = BitModel::default();
        for i in 0..2000u32 {
            enc.encode_bit(&mut m0, (i % 3 == 0) as u8);
            enc.encode_direct(i % 16, 4);
            enc.encode_bit(&mut m1, (i % 7 == 0) as u8);
        }
        let bytes = enc.finish();
        let mut dec = Decoder::new(&bytes);
        let mut m0 = BitModel::default();
        let mut m1 = BitModel::default();
        for i in 0..2000u32 {
            assert_eq!(dec.decode_bit(&mut m0), (i % 3 == 0) as u8);
            assert_eq!(dec.decode_direct(4), i % 16);
            assert_eq!(dec.decode_bit(&mut m1), (i % 7 == 0) as u8);
        }
    }
}
