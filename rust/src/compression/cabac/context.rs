//! Context model sets for the quantized-level syntax (NNC-flavored).
//!
//! Syntax elements per quantized integer level `q`:
//!   sig_flag   q != 0        — 3 contexts, selected by the significance
//!                              of the previous element (row start / prev
//!                              zero / prev nonzero), exploiting run
//!                              correlation in sparse updates
//!   sign_flag  q < 0         — 1 context
//!   gr1_flag   |q| > 1       — 1 context
//!   gr2_flag   |q| > 2       — 1 context
//!   remainder  |q| - 3       — Exp-Golomb(0), bypass bits
//!
//! Row-structured tensors additionally code one `row_skip` flag per filter
//! row (1 context): entire-row zero updates — the product of Eq. (3)
//! structured sparsification and scale-factor suppression — cost ~one bit
//! (well below after adaptation).

use super::engine::{BitModel, Decoder, Encoder};

/// The adaptive context-model set for one tensor's quantized levels.
#[derive(Debug, Clone, Default)]
pub struct LevelContexts {
    /// Per-row all-zero skip flag.
    pub row_skip: BitModel,
    /// Significance flags, indexed by [`SigCtx`].
    pub sig: [BitModel; 3],
    /// Sign flag.
    pub sign: BitModel,
    /// |q| > 1 flag.
    pub gr1: BitModel,
    /// |q| > 2 flag.
    pub gr2: BitModel,
}

impl LevelContexts {
    /// All-frozen contexts: the "DeepCABAC without context adaptation"
    /// ablation (every syntax bit coded at p=0.5-ish fixed probability).
    pub fn frozen() -> Self {
        Self {
            row_skip: BitModel::frozen(),
            sig: [BitModel::frozen(); 3],
            sign: BitModel::frozen(),
            gr1: BitModel::frozen(),
            gr2: BitModel::frozen(),
        }
    }
}

/// Significance context selection.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SigCtx {
    /// First element of a row.
    RowStart,
    /// Previous element quantized to zero.
    PrevZero,
    /// Previous element quantized nonzero.
    PrevNonZero,
}

impl SigCtx {
    /// Index into [`LevelContexts::sig`].
    #[inline]
    pub fn index(self) -> usize {
        match self {
            SigCtx::RowStart => 0,
            SigCtx::PrevZero => 1,
            SigCtx::PrevNonZero => 2,
        }
    }
}

/// Exp-Golomb order-0 value encoding in bypass mode.
#[inline]
pub fn encode_expgolomb(enc: &mut Encoder, value: u32) {
    let v = value + 1;
    let nbits = 32 - v.leading_zeros(); // floor(log2(v)) + 1
    // prefix: nbits-1 zeros then a 1; suffix: nbits-1 low bits of v
    enc.encode_direct(1, nbits);
    if nbits > 1 {
        enc.encode_direct(v & ((1 << (nbits - 1)) - 1), nbits - 1);
    }
}

/// Exp-Golomb order-0 value decoding (inverse of [`encode_expgolomb`]).
#[inline]
pub fn decode_expgolomb(dec: &mut Decoder) -> u32 {
    let mut zeros = 0u32;
    while dec.decode_direct(1) == 0 {
        zeros += 1;
        // Corrupt/truncated streams could otherwise drive the prefix
        // unbounded; clamp so decoding garbage yields garbage values but
        // never a shift overflow or runaway loop.
        if zeros >= 31 {
            break;
        }
    }
    let suffix = if zeros > 0 { dec.decode_direct(zeros) } else { 0 };
    ((1u32 << zeros) | suffix).saturating_sub(1)
}

/// Encode one quantized level with the full syntax.
#[inline]
pub fn encode_level(enc: &mut Encoder, cx: &mut LevelContexts, sig_ctx: SigCtx, q: i32) {
    let sig = (q != 0) as u8;
    enc.encode_bit(&mut cx.sig[sig_ctx.index()], sig);
    if sig == 0 {
        return;
    }
    enc.encode_bit(&mut cx.sign, (q < 0) as u8);
    let mag = q.unsigned_abs();
    let gr1 = (mag > 1) as u8;
    enc.encode_bit(&mut cx.gr1, gr1);
    if gr1 == 0 {
        return;
    }
    let gr2 = (mag > 2) as u8;
    enc.encode_bit(&mut cx.gr2, gr2);
    if gr2 == 0 {
        return;
    }
    encode_expgolomb(enc, mag - 3);
}

/// Decode one quantized level (inverse of [`encode_level`]).
#[inline]
pub fn decode_level(dec: &mut Decoder, cx: &mut LevelContexts, sig_ctx: SigCtx) -> i32 {
    if dec.decode_bit(&mut cx.sig[sig_ctx.index()]) == 0 {
        return 0;
    }
    let neg = dec.decode_bit(&mut cx.sign) == 1;
    let mut mag = 1u32;
    if dec.decode_bit(&mut cx.gr1) == 1 {
        mag = 2;
        if dec.decode_bit(&mut cx.gr2) == 1 {
            mag = 3 + decode_expgolomb(dec);
        }
    }
    if neg {
        -(mag as i32)
    } else {
        mag as i32
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn expgolomb_roundtrip() {
        let mut enc = Encoder::new();
        let vals: Vec<u32> = (0..2000).map(|i| (i * i) % 100_000).collect();
        for &v in &vals {
            encode_expgolomb(&mut enc, v);
        }
        let bytes = enc.finish();
        let mut dec = Decoder::new(&bytes);
        for &v in &vals {
            assert_eq!(decode_expgolomb(&mut dec), v);
        }
    }

    #[test]
    fn level_roundtrip_with_context_chain() {
        let levels: Vec<i32> = (0..5000i64)
            .map(|i| {
                if i % 17 == 0 {
                    ((i % 29) - 14) as i32
                } else {
                    0
                }
            })
            .collect();
        let mut enc = Encoder::new();
        let mut cx = LevelContexts::default();
        let mut prev = SigCtx::RowStart;
        for &q in &levels {
            encode_level(&mut enc, &mut cx, prev, q);
            prev = if q != 0 { SigCtx::PrevNonZero } else { SigCtx::PrevZero };
        }
        let bytes = enc.finish();
        let mut dec = Decoder::new(&bytes);
        let mut cx = LevelContexts::default();
        let mut prev = SigCtx::RowStart;
        for &q in &levels {
            let got = decode_level(&mut dec, &mut cx, prev);
            assert_eq!(got, q);
            prev = if q != 0 { SigCtx::PrevNonZero } else { SigCtx::PrevZero };
        }
    }
}
