//! DeepCABAC-style entropy codec, built from scratch:
//!
//! * [`engine`] — adaptive binary arithmetic (range) coder
//! * [`context`] — NNC-flavored syntax/context models for quantized levels
//! * [`codec`] — whole-update encode/decode with per-row skip flags
//!
//! This is the substrate behind every compressed transmission in the
//! reproduction (FedAvg†, STC†/‡, Eqs.(2)+(3) and FSFL all use it, as in
//! the paper's Table 2 where even STC is re-encoded with DeepCABAC).

pub mod codec;
pub mod context;
pub mod engine;

pub use codec::{
    decode_update, decode_update_into, decode_update_with, encode_update, encode_update_into,
    encode_update_opts, DecodeScratch, EncodeScratch, EncodeStats, StepFn,
};
pub use engine::{BitModel, Decoder, Encoder};
