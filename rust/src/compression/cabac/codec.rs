//! Whole-update encode/decode: a [`Delta`] → self-contained bitstream.
//!
//! Format (little-endian):
//! ```text
//! magic   b"FSDU"
//! u8      version (1)
//! u32     tensor entry count
//! entries u16 manifest index | f32 quantization step
//! u32     payload byte length
//! payload arithmetic-coded levels, tensors in entry order:
//!           row-structured: per row -> row_skip flag, then levels
//!           flat:           one "row" of levels
//! ```
//!
//! Encoding quantizes with each tensor's step; the function returns both
//! the bitstream and the **dequantized** update Δ̂ (what the decoder will
//! reconstruct) so the client can keep its local state consistent with
//! the server (Algorithm 1 line 11) and compute residuals (Eq. 5).

use std::sync::Arc;

use anyhow::{anyhow, Result};

use crate::model::{Manifest, TensorSpec};
use crate::model::ParamSet;

use super::context::{decode_level, encode_level, LevelContexts, SigCtx};
use super::engine::{Decoder, Encoder};
use crate::compression::quantize::{dequantize, quantize};
use crate::model::params::Delta;

const MAGIC: &[u8; 4] = b"FSDU";
const VERSION: u8 = 1;
const FLAG_ADAPTIVE: u8 = 1;

/// Maps a tensor spec to its quantization step size.
pub type StepFn<'a> = &'a dyn Fn(&TensorSpec) -> f32;

/// Size/occupancy statistics of one encoded update.
#[derive(Debug, Clone, Copy, Default)]
pub struct EncodeStats {
    pub bytes: usize,
    pub nonzero: usize,
    pub total: usize,
    pub rows_skipped: usize,
    pub rows_total: usize,
}

impl EncodeStats {
    pub fn sparsity(&self) -> f64 {
        if self.total == 0 {
            1.0
        } else {
            1.0 - self.nonzero as f64 / self.total as f64
        }
    }
}

fn sig_ctx(prev: Option<bool>) -> SigCtx {
    match prev {
        None => SigCtx::RowStart,
        Some(false) => SigCtx::PrevZero,
        Some(true) => SigCtx::PrevNonZero,
    }
}

/// Encode the selected tensors of `delta`. Returns `(bitstream, dequantized
/// update, stats)`; tensors not in `indices` are all-zero in the output
/// update.
pub fn encode_update(
    delta: &Delta,
    indices: &[usize],
    step_of: StepFn,
) -> (Vec<u8>, Delta, EncodeStats) {
    encode_update_opts(delta, indices, step_of, true)
}

/// [`encode_update`] with explicit context-adaptation control (the
/// "context modeling on/off" ablation; see benches/codec.rs).
pub fn encode_update_opts(
    delta: &Delta,
    indices: &[usize],
    step_of: StepFn,
    adaptive: bool,
) -> (Vec<u8>, Delta, EncodeStats) {
    let manifest = &delta.manifest;
    let mut header = Vec::with_capacity(16 + indices.len() * 6);
    header.extend_from_slice(MAGIC);
    header.push(VERSION);
    header.push(if adaptive { FLAG_ADAPTIVE } else { 0 });
    header.extend_from_slice(&(indices.len() as u32).to_le_bytes());

    let mut deq = Delta::zeros(manifest.clone());
    let mut enc = Encoder::new();
    let mut stats = EncodeStats::default();

    for &ti in indices {
        let spec = &manifest.tensors[ti];
        let step = step_of(spec);
        assert!(step > 0.0, "{}: non-positive step", spec.name);
        header.extend_from_slice(&(ti as u16).to_le_bytes());
        header.extend_from_slice(&step.to_le_bytes());

        let data = &delta.tensors[ti];
        let out = &mut deq.tensors[ti];
        let (rows, row_len) = spec.rows().unwrap_or((1, data.len()));
        let mut cx = if adaptive {
            LevelContexts::default()
        } else {
            LevelContexts::frozen()
        };
        for r in 0..rows {
            let row = &data[r * row_len..(r + 1) * row_len];
            let levels: Vec<i32> = row.iter().map(|&x| quantize(x, step)).collect();
            stats.total += row_len;
            if spec.rows().is_some() {
                stats.rows_total += 1;
                let skip = levels.iter().all(|&q| q == 0);
                enc.encode_bit(&mut cx.row_skip, skip as u8);
                if skip {
                    stats.rows_skipped += 1;
                    continue;
                }
            }
            let mut prev = None;
            for (c, &q) in levels.iter().enumerate() {
                encode_level(&mut enc, &mut cx, sig_ctx(prev), q);
                prev = Some(q != 0);
                if q != 0 {
                    stats.nonzero += 1;
                    out[r * row_len + c] = dequantize(q, step);
                }
            }
        }
    }

    let payload = enc.finish();
    header.extend_from_slice(&(payload.len() as u32).to_le_bytes());
    header.extend_from_slice(&payload);
    stats.bytes = header.len();
    (header, deq, stats)
}

/// Decode a bitstream produced by [`encode_update`].
pub fn decode_update(bytes: &[u8], manifest: &Arc<Manifest>) -> Result<Delta> {
    let mut pos = 0usize;
    let take = |pos: &mut usize, n: usize| -> Result<&[u8]> {
        if *pos + n > bytes.len() {
            return Err(anyhow!("truncated update stream at {pos}"));
        }
        let s = &bytes[*pos..*pos + n];
        *pos += n;
        Ok(s)
    };
    if take(&mut pos, 4)? != MAGIC {
        return Err(anyhow!("bad update magic"));
    }
    if take(&mut pos, 1)?[0] != VERSION {
        return Err(anyhow!("unsupported update version"));
    }
    let adaptive = take(&mut pos, 1)?[0] & FLAG_ADAPTIVE != 0;
    let count = u32::from_le_bytes(take(&mut pos, 4)?.try_into().unwrap()) as usize;
    let mut entries = Vec::with_capacity(count);
    for _ in 0..count {
        let ti = u16::from_le_bytes(take(&mut pos, 2)?.try_into().unwrap()) as usize;
        let step = f32::from_le_bytes(take(&mut pos, 4)?.try_into().unwrap());
        if ti >= manifest.tensors.len() {
            return Err(anyhow!("tensor index {ti} out of range"));
        }
        entries.push((ti, step));
    }
    let plen = u32::from_le_bytes(take(&mut pos, 4)?.try_into().unwrap()) as usize;
    let payload = take(&mut pos, plen)?;

    let mut dec = Decoder::new(payload);
    let mut delta = Delta::zeros(manifest.clone());
    for (ti, step) in entries {
        let spec = &manifest.tensors[ti];
        let numel = spec.numel();
        let (rows, row_len) = spec.rows().unwrap_or((1, numel));
        let out = &mut delta.tensors[ti];
        let mut cx = if adaptive {
            LevelContexts::default()
        } else {
            LevelContexts::frozen()
        };
        for r in 0..rows {
            if spec.rows().is_some() && dec.decode_bit(&mut cx.row_skip) == 1 {
                continue;
            }
            let mut prev = None;
            for c in 0..row_len {
                let q = decode_level(&mut dec, &mut cx, sig_ctx(prev));
                prev = Some(q != 0);
                if q != 0 {
                    out[r * row_len + c] = dequantize(q, step);
                }
            }
        }
    }
    Ok(delta)
}

/// Bytes an *uncompressed* f32 transmission of these tensors would take
/// (the paper's plain-FedAvg accounting in Table 2).
pub fn raw_bytes(params: &ParamSet, indices: &[usize]) -> usize {
    indices
        .iter()
        .map(|&i| params.manifest.tensors[i].numel() * 4)
        .sum()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::params::tests_support::manifest_conv_dense;

    #[test]
    fn roundtrip_mixed_tensors() {
        let m = manifest_conv_dense();
        let mut d = Delta::zeros(m.clone());
        // sparse conv rows, one fully zero row
        for c in 0..9 {
            d.tensors[0][c] = if c % 3 == 0 { 0.01 * c as f32 } else { 0.0 };
        }
        for c in 0..4 {
            d.tensors[1][c] = -1e-5 * c as f32;
        }
        let idx = vec![0usize, 1];
        let step = |spec: &TensorSpec| if spec.kind.is_fine_quantized() { 2.38e-6 } else { 4.88e-4 };
        let (bytes, deq, stats) = encode_update(&d, &idx, &step);
        assert!(stats.bytes > 0);
        let back = decode_update(&bytes, &m).unwrap();
        assert_eq!(back, deq);
        // dequantized values are within step/2 of originals
        for (t, spec) in deq.tensors.iter().zip(&m.tensors) {
            let s = step(spec);
            for (a, b) in t.iter().zip(&d.tensors[spec_index(&m, &spec.name)]) {
                assert!((a - b).abs() <= s / 2.0 + 1e-9);
            }
        }
    }

    fn spec_index(m: &Arc<Manifest>, name: &str) -> usize {
        m.index_of(name).unwrap()
    }

    #[test]
    fn zero_update_is_tiny() {
        let m = manifest_conv_dense();
        let d = Delta::zeros(m.clone());
        let idx: Vec<usize> = (0..m.tensors.len()).collect();
        let (bytes, _, stats) = encode_update(&d, &idx, &|_| 1e-3);
        assert_eq!(stats.nonzero, 0);
        // all-zero update: header dominates
        assert!(bytes.len() < 64 + idx.len() * 6, "got {}", bytes.len());
        let back = decode_update(&bytes, &m).unwrap();
        assert_eq!(back, d);
    }

    #[test]
    fn truncated_stream_is_error() {
        let m = manifest_conv_dense();
        let d = Delta::zeros(m.clone());
        let (bytes, _, _) = encode_update(&d, &[0], &|_| 1e-3);
        assert!(decode_update(&bytes[..3], &m).is_err());
        assert!(decode_update(&bytes[..10], &m).is_err());
    }
}
