//! Whole-update encode/decode: a [`Delta`] → self-contained bitstream.
//!
//! Format (little-endian):
//! ```text
//! magic   b"FSDU"
//! u8      version (1)
//! u32     tensor entry count
//! entries u16 manifest index | f32 quantization step
//! u32     payload byte length
//! payload arithmetic-coded levels, tensors in entry order:
//!           row-structured: per row -> row_skip flag, then levels
//!           flat:           one "row" of levels
//! ```
//!
//! Encoding quantizes with each tensor's step; the function returns both
//! the bitstream and the **dequantized** update Δ̂ (what the decoder will
//! reconstruct) so the client can keep its local state consistent with
//! the server (Algorithm 1 line 11) and compute residuals (Eq. 5).
//!
//! Two API layers: the `*_into` functions are the allocation-free core
//! (caller-owned output buffers + [`EncodeScratch`]/[`DecodeScratch`],
//! reused across rounds on the codec worker pool), and the original
//! allocating signatures remain as thin wrappers. Scratch reuse never
//! leaks state between calls: every output buffer is cleared up front
//! and the arithmetic-coder contexts are re-initialized per tensor, so
//! bitstreams are byte-identical whether buffers are fresh or recycled.

use std::sync::Arc;

use anyhow::{anyhow, Result};

use crate::model::ParamSet;
use crate::model::{Manifest, TensorSpec};

use super::context::{decode_level, encode_level, LevelContexts, SigCtx};
use super::engine::{Decoder, Encoder};
use crate::compression::quantize::{dequantize, quantize};
use crate::model::params::Delta;

const MAGIC: &[u8; 4] = b"FSDU";
const VERSION: u8 = 1;
const FLAG_ADAPTIVE: u8 = 1;

/// Maps a tensor spec to its quantization step size.
pub type StepFn<'a> = &'a dyn Fn(&TensorSpec) -> f32;

/// Size/occupancy statistics of one encoded update.
#[derive(Debug, Clone, Copy, Default)]
pub struct EncodeStats {
    /// Encoded bitstream length (header + payload).
    pub bytes: usize,
    /// Nonzero quantized levels encoded.
    pub nonzero: usize,
    /// Total elements covered by the encode.
    pub total: usize,
    /// Filter rows skipped entirely (1-bit row flags).
    pub rows_skipped: usize,
    /// Total filter rows seen.
    pub rows_total: usize,
}

impl EncodeStats {
    /// Fraction of zero levels in the encoded update.
    pub fn sparsity(&self) -> f64 {
        if self.total == 0 {
            1.0
        } else {
            1.0 - self.nonzero as f64 / self.total as f64
        }
    }
}

/// Reusable encode-side buffers: the per-row quantized-level staging
/// area and the arithmetic coder's payload buffer. Holding one of these
/// per codec lane makes steady-state encoding allocation-free.
#[derive(Debug, Default)]
pub struct EncodeScratch {
    levels: Vec<i32>,
    payload: Vec<u8>,
}

/// Reusable decode-side buffers (header entry table).
#[derive(Debug, Default)]
pub struct DecodeScratch {
    entries: Vec<(usize, f32)>,
}

fn sig_ctx(prev: Option<bool>) -> SigCtx {
    match prev {
        None => SigCtx::RowStart,
        Some(false) => SigCtx::PrevZero,
        Some(true) => SigCtx::PrevNonZero,
    }
}

/// Encode the selected tensors of `delta`. Returns `(bitstream, dequantized
/// update, stats)`; tensors not in `indices` are all-zero in the output
/// update.
pub fn encode_update(
    delta: &Delta,
    indices: &[usize],
    step_of: StepFn,
) -> (Vec<u8>, Delta, EncodeStats) {
    encode_update_opts(delta, indices, step_of, true)
}

/// [`encode_update`] with explicit context-adaptation control (the
/// "context modeling on/off" ablation; see benches/codec.rs).
pub fn encode_update_opts(
    delta: &Delta,
    indices: &[usize],
    step_of: StepFn,
    adaptive: bool,
) -> (Vec<u8>, Delta, EncodeStats) {
    let mut scratch = EncodeScratch::default();
    let mut deq = Delta::zeros(delta.manifest.clone());
    let mut dst = Vec::new();
    let stats = encode_update_into(delta, indices, step_of, adaptive, &mut scratch, &mut deq, &mut dst);
    (dst, deq, stats)
}

/// Allocation-free core: encode into `dst` and the dequantized view into
/// `deq` (both cleared first; `deq` must share `delta`'s manifest).
/// Produces bitstreams byte-identical to [`encode_update_opts`].
// fsfl-lint: hot
pub fn encode_update_into(
    delta: &Delta,
    indices: &[usize],
    step_of: StepFn,
    adaptive: bool,
    scratch: &mut EncodeScratch,
    deq: &mut Delta,
    dst: &mut Vec<u8>,
) -> EncodeStats {
    let manifest = &delta.manifest;
    debug_assert_eq!(deq.tensors.len(), manifest.tensors.len());
    deq.clear();
    dst.clear();
    dst.extend_from_slice(MAGIC);
    dst.push(VERSION);
    dst.push(if adaptive { FLAG_ADAPTIVE } else { 0 });
    dst.extend_from_slice(&(indices.len() as u32).to_le_bytes());

    let mut enc = Encoder::with_buffer(std::mem::take(&mut scratch.payload));
    let levels = &mut scratch.levels;
    let mut stats = EncodeStats::default();

    for &ti in indices {
        let spec = &manifest.tensors[ti];
        let step = step_of(spec);
        assert!(step > 0.0, "{}: non-positive step", spec.name);
        dst.extend_from_slice(&(ti as u16).to_le_bytes());
        dst.extend_from_slice(&step.to_le_bytes());

        let data = &delta.tensors[ti];
        let out = &mut deq.tensors[ti];
        let (rows, row_len) = spec.rows().unwrap_or((1, data.len()));
        let mut cx = if adaptive {
            LevelContexts::default()
        } else {
            LevelContexts::frozen()
        };
        for r in 0..rows {
            let row = &data[r * row_len..(r + 1) * row_len];
            levels.clear();
            levels.extend(row.iter().map(|&x| quantize(x, step)));
            stats.total += row_len;
            if spec.rows().is_some() {
                stats.rows_total += 1;
                let skip = levels.iter().all(|&q| q == 0);
                enc.encode_bit(&mut cx.row_skip, skip as u8);
                if skip {
                    stats.rows_skipped += 1;
                    continue;
                }
            }
            let mut prev = None;
            for (c, &q) in levels.iter().enumerate() {
                encode_level(&mut enc, &mut cx, sig_ctx(prev), q);
                prev = Some(q != 0);
                if q != 0 {
                    stats.nonzero += 1;
                    out[r * row_len + c] = dequantize(q, step);
                }
            }
        }
    }

    let payload = enc.finish();
    dst.extend_from_slice(&(payload.len() as u32).to_le_bytes());
    dst.extend_from_slice(&payload);
    scratch.payload = payload; // recycle the coder buffer for the next call
    stats.bytes = dst.len();
    stats
}
// fsfl-lint: end-hot

/// Decode a bitstream produced by [`encode_update`].
pub fn decode_update(bytes: &[u8], manifest: &Arc<Manifest>) -> Result<Delta> {
    let mut out = Delta::zeros(manifest.clone());
    decode_update_into(bytes, &mut out)?;
    Ok(out)
}

/// Decode into a caller-owned (recycled) `Delta`; cleared first.
pub fn decode_update_into(bytes: &[u8], out: &mut Delta) -> Result<()> {
    let mut scratch = DecodeScratch::default();
    decode_update_with(bytes, out, &mut scratch)
}

/// Allocation-free core of [`decode_update`].
// fsfl-lint: hot
pub fn decode_update_with(bytes: &[u8], out: &mut Delta, scratch: &mut DecodeScratch) -> Result<()> {
    let manifest = out.manifest.clone();
    out.clear();
    let mut pos = 0usize;
    let take = |pos: &mut usize, n: usize| -> Result<&[u8]> {
        if *pos + n > bytes.len() {
            return Err(anyhow!("truncated update stream at {pos}"));
        }
        let s = &bytes[*pos..*pos + n];
        *pos += n;
        Ok(s)
    };
    if take(&mut pos, 4)? != MAGIC {
        return Err(anyhow!("bad update magic"));
    }
    if take(&mut pos, 1)?[0] != VERSION {
        return Err(anyhow!("unsupported update version"));
    }
    let adaptive = take(&mut pos, 1)?[0] & FLAG_ADAPTIVE != 0;
    let count = u32::from_le_bytes(take(&mut pos, 4)?.try_into().unwrap()) as usize;
    let entries = &mut scratch.entries;
    entries.clear();
    for _ in 0..count {
        let ti = u16::from_le_bytes(take(&mut pos, 2)?.try_into().unwrap()) as usize;
        let step = f32::from_le_bytes(take(&mut pos, 4)?.try_into().unwrap());
        if ti >= manifest.tensors.len() {
            return Err(anyhow!("tensor index {ti} out of range"));
        }
        entries.push((ti, step));
    }
    let plen = u32::from_le_bytes(take(&mut pos, 4)?.try_into().unwrap()) as usize;
    let payload = take(&mut pos, plen)?;

    let mut dec = Decoder::new(payload);
    for &(ti, step) in entries.iter() {
        let spec = &manifest.tensors[ti];
        let numel = spec.numel();
        let (rows, row_len) = spec.rows().unwrap_or((1, numel));
        let tensor = &mut out.tensors[ti];
        let mut cx = if adaptive {
            LevelContexts::default()
        } else {
            LevelContexts::frozen()
        };
        for r in 0..rows {
            if spec.rows().is_some() && dec.decode_bit(&mut cx.row_skip) == 1 {
                continue;
            }
            let mut prev = None;
            for c in 0..row_len {
                let q = decode_level(&mut dec, &mut cx, sig_ctx(prev));
                prev = Some(q != 0);
                if q != 0 {
                    tensor[r * row_len + c] = dequantize(q, step);
                }
            }
        }
    }
    Ok(())
}
// fsfl-lint: end-hot

/// Bytes an *uncompressed* f32 transmission of these tensors would take
/// (the paper's plain-FedAvg accounting in Table 2).
pub fn raw_bytes(params: &ParamSet, indices: &[usize]) -> usize {
    raw_bytes_of(&params.manifest, indices)
}

/// [`raw_bytes`] from the manifest alone (no parameter values needed).
pub fn raw_bytes_of(manifest: &Manifest, indices: &[usize]) -> usize {
    indices
        .iter()
        .map(|&i| manifest.tensors[i].numel() * 4)
        .sum()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::params::tests_support::manifest_conv_dense;

    #[test]
    fn roundtrip_mixed_tensors() {
        let m = manifest_conv_dense();
        let mut d = Delta::zeros(m.clone());
        // sparse conv rows, one fully zero row
        for c in 0..9 {
            d.tensors[0][c] = if c % 3 == 0 { 0.01 * c as f32 } else { 0.0 };
        }
        for c in 0..4 {
            d.tensors[1][c] = -1e-5 * c as f32;
        }
        let idx = vec![0usize, 1];
        let step = |spec: &TensorSpec| if spec.kind.is_fine_quantized() { 2.38e-6 } else { 4.88e-4 };
        let (bytes, deq, stats) = encode_update(&d, &idx, &step);
        assert!(stats.bytes > 0);
        let back = decode_update(&bytes, &m).unwrap();
        assert_eq!(back, deq);
        // dequantized values are within step/2 of originals
        for (t, spec) in deq.tensors.iter().zip(&m.tensors) {
            let s = step(spec);
            for (a, b) in t.iter().zip(&d.tensors[spec_index(&m, &spec.name)]) {
                assert!((a - b).abs() <= s / 2.0 + 1e-9);
            }
        }
    }

    fn spec_index(m: &Arc<Manifest>, name: &str) -> usize {
        m.index_of(name).unwrap()
    }

    #[test]
    fn zero_update_is_tiny() {
        let m = manifest_conv_dense();
        let d = Delta::zeros(m.clone());
        let idx: Vec<usize> = (0..m.tensors.len()).collect();
        let (bytes, _, stats) = encode_update(&d, &idx, &|_| 1e-3);
        assert_eq!(stats.nonzero, 0);
        // all-zero update: header dominates
        assert!(bytes.len() < 64 + idx.len() * 6, "got {}", bytes.len());
        let back = decode_update(&bytes, &m).unwrap();
        assert_eq!(back, d);
    }

    #[test]
    fn truncated_stream_is_error() {
        let m = manifest_conv_dense();
        let d = Delta::zeros(m.clone());
        let (bytes, _, _) = encode_update(&d, &[0], &|_| 1e-3);
        assert!(decode_update(&bytes[..3], &m).is_err());
        assert!(decode_update(&bytes[..10], &m).is_err());
    }

    #[test]
    fn scratch_reuse_is_byte_identical_and_leak_free() {
        let m = manifest_conv_dense();
        let step = |_: &TensorSpec| 1e-3f32;
        let mut scratch = EncodeScratch::default();
        let mut deq = Delta::zeros(m.clone());
        let mut dst = Vec::new();

        // First encode: a dense update that dirties every buffer.
        let mut dense = Delta::zeros(m.clone());
        for t in &mut dense.tensors {
            for (i, x) in t.iter_mut().enumerate() {
                *x = 0.05 * (i as f32 + 1.0);
            }
        }
        let idx = vec![0usize, 1];
        encode_update_into(&dense, &idx, &step, true, &mut scratch, &mut deq, &mut dst);
        assert!(dst.len() > 16);

        // Second encode through the SAME scratch/deq/dst must match a
        // fresh allocating encode bit for bit — nothing from the dense
        // update may leak into the sparse one.
        let mut sparse = Delta::zeros(m.clone());
        sparse.tensors[0][4] = 2.5e-3;
        let stats2 = encode_update_into(&sparse, &idx, &step, true, &mut scratch, &mut deq, &mut dst);
        let (fresh_bytes, fresh_deq, fresh_stats) = encode_update(&sparse, &idx, &step);
        assert_eq!(dst, fresh_bytes);
        assert_eq!(deq, fresh_deq);
        assert_eq!(stats2.nonzero, fresh_stats.nonzero);

        // Decode through a recycled Delta + scratch matches too.
        let mut dscratch = DecodeScratch::default();
        let mut out = Delta::zeros(m.clone());
        decode_update_with(&fresh_bytes, &mut out, &mut dscratch).unwrap();
        // dirty it, decode again
        for t in &mut out.tensors {
            t.iter_mut().for_each(|x| *x = 7.0);
        }
        decode_update_with(&fresh_bytes, &mut out, &mut dscratch).unwrap();
        assert_eq!(out, fresh_deq);
    }
}
