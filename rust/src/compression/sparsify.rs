//! Sparsification of differential updates (paper Sec. 3).
//!
//! * **Unstructured** (Eq. 2): per-tensor dynamic threshold from a
//!   Gaussian approximation of the update distribution,
//!   `θ_u = max(|mean − δ·std|, |mean + δ·std|)`, floored at
//!   `step_size / 2` (anything below quantizes to zero anyway).
//!   Mean/std come from one fused sum/sum-of-squares pass.
//! * **Structured** (Eq. 3): per-filter-row threshold
//!   `θ_s = γ/M · Σ_m |mean(ΔF_m)|`; rows whose absolute update mean
//!   falls below θ_s are zeroed entirely (these become 1-bit row-skip
//!   flags in the codec). Row means are computed once and shared between
//!   the threshold and the zeroing pass via [`SparsifyScratch`].
//! * **Fixed-rate top-k**: the constant 96 % sparsity used for the
//!   Table 2 comparison against STC.
//!
//! The `*_with` entry points take a [`SparsifyScratch`] and are
//! allocation-free in steady state; the original signatures remain as
//! wrappers for tests/benches.

use crate::model::params::Delta;
use crate::model::TensorSpec;

use super::quantize::QuantConfig;

/// Which sparsification scheme a protocol applies to weight updates.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum SparsifyMode {
    /// No sparsification (plain FedAvg baselines).
    None,
    /// Eqs. (2) + (3): dynamic unstructured + structured thresholds.
    Dynamic {
        /// Std-dev multiplier of the Eq. (2) Gaussian threshold.
        delta: f32,
        /// Row-mean multiplier of the Eq. (3) structured threshold.
        gamma: f32,
    },
    /// Fixed-rate magnitude top-k (rate = fraction of zeros, e.g. 0.96).
    TopK {
        /// Fraction of elements zeroed.
        rate: f32,
    },
}

/// Reusable buffers for the sparsification kernels. The contents carry
/// no meaning across calls — every user clears before filling — so one
/// scratch can serve tensors of any shape back to back.
#[derive(Debug, Default)]
pub struct SparsifyScratch {
    /// Per-row means for Eq. (3) (shared threshold + apply pass).
    pub(crate) row_means: Vec<f64>,
    /// Magnitude staging for top-k selection.
    pub(crate) mags: Vec<f32>,
}

/// Eq. (2): Gaussian-approximation threshold for one tensor. Single
/// fused pass: `var = E[x²] − mean²` (clamped at 0 against f64 rounding).
pub fn unstructured_threshold(t: &[f32], delta: f32, step_size: f32) -> f32 {
    if t.is_empty() {
        return step_size / 2.0;
    }
    let n = t.len() as f64;
    let mut sum = 0.0f64;
    let mut sumsq = 0.0f64;
    for &x in t {
        let x = x as f64;
        sum += x;
        sumsq += x * x;
    }
    let mean = sum / n;
    let var = (sumsq / n - mean * mean).max(0.0);
    let std = var.sqrt();
    let d = delta as f64;
    let theta = (mean - d * std).abs().max((mean + d * std).abs()) as f32;
    theta.max(step_size / 2.0)
}

/// Zero all elements with |x| < θ. Returns number of zeroed elements.
pub fn apply_unstructured(t: &mut [f32], theta: f32) -> usize {
    let mut zeroed = 0;
    for x in t.iter_mut() {
        if x.abs() < theta && *x != 0.0 {
            *x = 0.0;
            zeroed += 1;
        }
    }
    zeroed
}

/// Fill `means` with the per-row means of a row-structured tensor.
pub fn row_means_into(t: &[f32], rows: usize, row_len: usize, means: &mut Vec<f64>) {
    means.clear();
    means.extend((0..rows).map(|r| {
        let row = &t[r * row_len..(r + 1) * row_len];
        row.iter().map(|&x| x as f64).sum::<f64>() / row_len as f64
    }));
}

/// Eq. (3) threshold from precomputed row means.
pub fn threshold_from_means(means: &[f64], gamma: f32) -> f32 {
    if means.is_empty() {
        return 0.0;
    }
    let sum_abs_means: f64 = means.iter().map(|m| m.abs()).sum();
    (gamma as f64 * sum_abs_means / means.len() as f64) as f32
}

/// Eq. (3): θ_s = γ/M · Σ_m |mean(row_m)| for a row-structured tensor.
pub fn structured_threshold(t: &[f32], rows: usize, row_len: usize, gamma: f32) -> f32 {
    if rows == 0 || row_len == 0 {
        return 0.0;
    }
    let mut means = Vec::new();
    row_means_into(t, rows, row_len, &mut means);
    threshold_from_means(&means, gamma)
}

/// Zero entire rows whose precomputed |mean| < θ_s (the means must come
/// from [`row_means_into`] on the same tensor). Returns rows zeroed.
pub fn apply_structured_with_means(
    t: &mut [f32],
    rows: usize,
    row_len: usize,
    theta: f32,
    means: &[f64],
) -> usize {
    debug_assert_eq!(means.len(), rows);
    let mut zeroed = 0;
    for (r, mean) in means.iter().enumerate().take(rows) {
        if (mean.abs() as f32) < theta {
            t[r * row_len..(r + 1) * row_len]
                .iter_mut()
                .for_each(|x| *x = 0.0);
            zeroed += 1;
        }
    }
    zeroed
}

/// Zero entire rows whose |mean| < θ_s. Returns number of rows zeroed.
pub fn apply_structured(t: &mut [f32], rows: usize, row_len: usize, theta: f32) -> usize {
    let mut means = Vec::new();
    row_means_into(t, rows, row_len, &mut means);
    apply_structured_with_means(t, rows, row_len, theta, &means)
}

/// Magnitude top-k through a recycled magnitude buffer.
pub fn apply_topk_with(t: &mut [f32], rate: f32, mags: &mut Vec<f32>) -> usize {
    let n = t.len();
    let keep = (((1.0 - rate as f64) * n as f64).round() as usize).min(n);
    if keep == n {
        return 0;
    }
    if keep == 0 {
        let zeroed = t.iter().filter(|&&x| x != 0.0).count();
        t.iter_mut().for_each(|x| *x = 0.0);
        return zeroed;
    }
    mags.clear();
    mags.extend(t.iter().map(|x| x.abs()));
    let cut = n - keep;
    mags.select_nth_unstable_by(cut, |a, b| a.partial_cmp(b).unwrap());
    let theta = mags[cut];
    // Keep strictly-above-theta always; break magnitude ties first-come so
    // exactly `keep` elements survive.
    let above = t.iter().filter(|x| x.abs() > theta).count();
    let mut ties_to_keep = keep.saturating_sub(above);
    let mut zeroed = 0;
    for x in t.iter_mut() {
        let a = x.abs();
        if a > theta {
            continue;
        }
        if a == theta && ties_to_keep > 0 && a > 0.0 {
            ties_to_keep -= 1;
            continue;
        }
        if *x != 0.0 {
            *x = 0.0;
            zeroed += 1;
        }
    }
    zeroed
}

/// Magnitude top-k: zero everything except the `(1-rate)` fraction with the
/// largest |x| (per tensor, as in STC / the Table 2 fixed-rate setting).
pub fn apply_topk(t: &mut [f32], rate: f32) -> usize {
    let mut mags = Vec::new();
    apply_topk_with(t, rate, &mut mags)
}

/// Apply a [`SparsifyMode`] to every update tensor in `indices` using
/// recycled scratch buffers. Returns total elements zeroed.
// fsfl-lint: hot
pub fn sparsify_with(
    delta: &mut Delta,
    indices: &[usize],
    mode: SparsifyMode,
    quant: &QuantConfig,
    scratch: &mut SparsifyScratch,
) -> usize {
    let manifest = delta.manifest.clone();
    let mut zeroed = 0;
    for &i in indices {
        let spec: &TensorSpec = &manifest.tensors[i];
        let t = &mut delta.tensors[i];
        match mode {
            SparsifyMode::None => {}
            SparsifyMode::Dynamic { delta: d, gamma } => {
                // Structured first (Eq. 3) on filter rows, then the
                // unstructured Gaussian threshold (Eq. 2) on survivors.
                if let Some((rows, row_len)) = spec.rows() {
                    row_means_into(t, rows, row_len, &mut scratch.row_means);
                    let theta_s = threshold_from_means(&scratch.row_means, gamma);
                    zeroed +=
                        apply_structured_with_means(t, rows, row_len, theta_s, &scratch.row_means);
                }
                let theta_u = unstructured_threshold(t, d, quant.step_for(spec));
                zeroed += apply_unstructured(t, theta_u);
            }
            SparsifyMode::TopK { rate } => {
                // Fixed-rate sparsity only targets the (large) weight
                // tensors; side parameters ride along as in the paper.
                if spec.rows().is_some() {
                    zeroed += apply_topk_with(t, rate, &mut scratch.mags);
                }
            }
        }
    }
    zeroed
}
// fsfl-lint: end-hot

/// Apply a [`SparsifyMode`] to every update tensor in `indices`.
/// Returns total elements zeroed.
pub fn sparsify(
    delta: &mut Delta,
    indices: &[usize],
    mode: SparsifyMode,
    quant: &QuantConfig,
) -> usize {
    let mut scratch = SparsifyScratch::default();
    sparsify_with(delta, indices, mode, quant, &mut scratch)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn eq2_threshold_zero_mean_gaussian() {
        // N(0, 1): theta ≈ delta * std (mean ≈ 0)
        let n = 10_000;
        let mut rng = crate::data::XorShiftRng::new(11);
        let t: Vec<f32> = (0..n).map(|_| rng.normal()).collect();
        let theta = unstructured_threshold(&t, 1.0, 1e-6);
        let std = {
            let m = t.iter().sum::<f32>() / n as f32;
            (t.iter().map(|x| (x - m).powi(2)).sum::<f32>() / n as f32).sqrt()
        };
        assert!((theta - std).abs() / std < 0.05, "theta={theta} std={std}");
    }

    #[test]
    fn eq2_respects_step_floor() {
        let t = vec![1e-9, -1e-9, 2e-9];
        let theta = unstructured_threshold(&t, 0.1, 1.0);
        assert_eq!(theta, 0.5);
    }

    #[test]
    fn eq2_constant_tensor_has_zero_variance() {
        // fused sum/sumsq must not go negative on constant input
        let t = vec![0.25f32; 4096];
        let theta = unstructured_threshold(&t, 3.0, 1e-9);
        assert!((theta - 0.25).abs() < 1e-5, "theta={theta}");
    }

    #[test]
    fn eq3_zeroes_low_mean_rows() {
        // rows: mean 1.0, mean 0.01, mean -1.0 → θ_s(γ=1) = 0.67
        let mut t = vec![1.0, 1.0, 1.0, 0.01, 0.01, 0.01, -1.0, -1.0, -1.0];
        let theta = structured_threshold(&t, 3, 3, 1.0);
        assert!((theta - 0.67).abs() < 1e-3);
        let zeroed = apply_structured(&mut t, 3, 3, theta);
        assert_eq!(zeroed, 1);
        assert_eq!(&t[3..6], &[0.0, 0.0, 0.0]);
        assert_eq!(t[0], 1.0);
        assert_eq!(t[8], -1.0);
    }

    #[test]
    fn eq3_shared_means_match_recompute() {
        let mut rng = crate::data::XorShiftRng::new(3);
        let t: Vec<f32> = (0..256).map(|_| rng.normal() * 0.01).collect();
        let mut means = Vec::new();
        row_means_into(&t, 16, 16, &mut means);
        let theta = threshold_from_means(&means, 1.0);
        assert_eq!(theta, structured_threshold(&t, 16, 16, 1.0));
        let mut a = t.clone();
        let mut b = t;
        let za = apply_structured_with_means(&mut a, 16, 16, theta, &means);
        let zb = apply_structured(&mut b, 16, 16, theta);
        assert_eq!(za, zb);
        assert_eq!(a, b);
    }

    #[test]
    fn topk_keeps_exact_fraction() {
        let n = 1000;
        let mut t: Vec<f32> = (0..n).map(|i| (i as f32 - 500.0) / 100.0).collect();
        apply_topk(&mut t, 0.96);
        let nonzero = t.iter().filter(|&&x| x != 0.0).count();
        assert_eq!(nonzero, 40);
        // survivors are the extremes
        assert!(t[0] != 0.0 && t[n - 1] != 0.0);
        assert_eq!(t[n / 2], 0.0);
    }

    #[test]
    fn topk_with_ties() {
        let mut t = vec![1.0f32; 10];
        apply_topk(&mut t, 0.5);
        assert_eq!(t.iter().filter(|&&x| x != 0.0).count(), 5);
    }

    #[test]
    fn topk_rate_one_zeroes_all() {
        let mut t = vec![1.0f32, -2.0, 3.0];
        apply_topk(&mut t, 1.0);
        assert!(t.iter().all(|&x| x == 0.0));
    }

    #[test]
    fn topk_scratch_reuse_across_shapes() {
        let mut mags = Vec::new();
        let mut big: Vec<f32> = (0..512).map(|i| i as f32).collect();
        apply_topk_with(&mut big, 0.9, &mut mags);
        // smaller tensor through the same (dirty, larger) scratch
        let mut small = vec![5.0f32, -1.0, 3.0, 0.5];
        let mut expect = small.clone();
        apply_topk(&mut expect, 0.5);
        apply_topk_with(&mut small, 0.5, &mut mags);
        assert_eq!(small, expect);
    }

    #[test]
    fn sparsify_with_matches_sparsify() {
        use crate::model::params::tests_support::manifest_conv_dense;
        let m = manifest_conv_dense();
        let mut rng = crate::data::XorShiftRng::new(9);
        let mut base = crate::model::params::Delta::zeros(m.clone());
        for t in &mut base.tensors {
            for x in t.iter_mut() {
                *x = rng.normal() * 1e-3;
            }
        }
        let q = QuantConfig::default();
        let idx = vec![0usize, 1];
        let mode = SparsifyMode::Dynamic { delta: 0.5, gamma: 1.0 };
        let mut a = base.clone();
        let z1 = sparsify(&mut a, &idx, mode, &q);
        let mut scratch = SparsifyScratch::default();
        let mut b = base;
        let z2 = sparsify_with(&mut b, &idx, mode, &q, &mut scratch);
        assert_eq!(z1, z2);
        assert_eq!(a, b);
    }
}
