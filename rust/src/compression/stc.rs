//! Sparse Ternary Compression baseline (Sattler et al. [21], as used in
//! the paper's Table 2 comparison).
//!
//! STC keeps the top-k update elements by magnitude per tensor and
//! ternarizes the survivors to `±μ`, where μ is the mean magnitude of
//! the survivors. Combined with error accumulation (Eq. 5, handled by
//! the protocol layer) this is the strongest prior-work baseline.
//!
//! Encoding: the paper re-encodes STC updates with DeepCABAC ("for
//! better comparability … we encoded weight updates with DeepCABAC in
//! our STC implementation"), which we mirror: the ternarized tensor is
//! passed to the cabac codec with step = μ, so levels are exactly
//! {-1, 0, +1}.

use crate::model::params::Delta;

/// Ternarize the row-structured weight tensors of `delta` in place:
/// top-(1-rate) magnitude survivors become ±μ. Returns per-tensor μ
/// (0.0 for tensors that were not ternarized or are all-zero).
pub fn ternarize(delta: &mut Delta, indices: &[usize], rate: f32) -> Vec<f32> {
    let mut mus = Vec::new();
    let mut mags = Vec::new();
    ternarize_into(delta, indices, rate, &mut mags, &mut mus);
    mus
}

/// Allocation-free core of [`ternarize`]: `mags` is the recycled top-k
/// magnitude buffer, `mus` the per-tensor μ output (resized + zeroed
/// here). μ is accumulated in a single pass over the survivors instead
/// of staging them in a temporary vector.
// fsfl-lint: hot
pub fn ternarize_into(
    delta: &mut Delta,
    indices: &[usize],
    rate: f32,
    mags: &mut Vec<f32>,
    mus: &mut Vec<f32>,
) {
    let manifest = delta.manifest.clone();
    mus.clear();
    mus.resize(manifest.tensors.len(), 0.0);
    for &i in indices {
        let spec = &manifest.tensors[i];
        if spec.rows().is_none() {
            // Side parameters (bias/BN/scales) are transmitted unternarized,
            // as in the paper's setup where STC applies to weight tensors.
            continue;
        }
        let t = &mut delta.tensors[i];
        super::sparsify::apply_topk_with(t, rate, mags);
        let mut sum = 0.0f32;
        let mut count = 0usize;
        for &x in t.iter() {
            if x != 0.0 {
                sum += x.abs();
                count += 1;
            }
        }
        if count == 0 {
            continue;
        }
        let mu = sum / count as f32;
        mus[i] = mu;
        for x in t.iter_mut() {
            if *x > 0.0 {
                *x = mu;
            } else if *x < 0.0 {
                *x = -mu;
            }
        }
    }
}
// fsfl-lint: end-hot

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::params::tests_support::manifest_conv_dense;

    #[test]
    fn ternary_values_are_plus_minus_mu() {
        let m = manifest_conv_dense();
        let mut d = Delta::zeros(m);
        d.tensors[0] = vec![0.5, -1.5, 0.1, 0.2, -0.3, 2.5, 0.05, -0.02, 1.0];
        let mus = ternarize(&mut d, &[0, 1], 0.5);
        let mu = mus[0];
        assert!(mu > 0.0);
        let vals: Vec<f32> = d.tensors[0].iter().copied().filter(|&x| x != 0.0).collect();
        // ~50% kept (9 * 0.5 rounds to 4..5 survivors)
        assert!(vals.len() == 4 || vals.len() == 5, "{vals:?}");
        for v in vals {
            assert!((v.abs() - mu).abs() < 1e-6);
        }
        // bias tensor untouched (not row-structured)
        assert_eq!(mus[1], 0.0);
    }

    #[test]
    fn mu_is_mean_of_survivor_magnitudes() {
        let m = manifest_conv_dense();
        let mut d = Delta::zeros(m);
        d.tensors[0] = vec![9.0, -3.0, 0.0, 0.0, 0.0, 0.0, 0.0, 0.0, 0.0];
        let mus = ternarize(&mut d, &[0], 7.0 / 9.0);
        assert!((mus[0] - 6.0).abs() < 1e-6);
        assert_eq!(d.tensors[0][0], 6.0);
        assert_eq!(d.tensors[0][1], -6.0);
    }

    #[test]
    fn all_zero_tensor_stays_zero() {
        let m = manifest_conv_dense();
        let mut d = Delta::zeros(m);
        let mus = ternarize(&mut d, &[0, 1], 0.9);
        assert!(mus.iter().all(|&x| x == 0.0));
        assert!(d.tensors.iter().all(|t| t.iter().all(|&x| x == 0.0)));
    }
}
