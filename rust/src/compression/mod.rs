//! The differential-update compression pipeline (paper Sec. 3):
//! sparsification → uniform quantization → DeepCABAC entropy coding,
//! plus the STC baseline and error accumulation.
//!
//! [`UpdateCodec`] is the facade the FL protocols use: it owns the
//! sparsify + quantize + encode configuration and produces
//! `(bitstream, dequantized Δ̂, stats)` triples.
//!
//! The hot path is [`UpdateCodec::encode_into`] / [`UpdateCodec::decode_into`]
//! with a per-lane [`CodecScratch`]: every intermediate buffer (row
//! quantization levels, range-coder payload, top-k magnitudes, Eq. 3 row
//! means, STC μ table, decoder entry table) is recycled across rounds,
//! so steady-state encoding/decoding performs no heap allocation.
//! **Scratch contract:** no call ever reads scratch contents left by a
//! previous call — every buffer is cleared (or fully overwritten) before
//! use, so one scratch may serve tensors and updates of any shape
//! back-to-back without leaking data across tensors or clients.

pub mod cabac;
pub mod quantize;
pub mod residual;
pub mod sparsify;
pub mod stc;

pub use cabac::{decode_update, encode_update, EncodeStats};
pub use quantize::QuantConfig;
pub use residual::Residual;
pub use sparsify::{SparsifyMode, SparsifyScratch};

use std::sync::Arc;

use anyhow::Result;

use crate::model::params::Delta;
use crate::model::Manifest;

/// All recycled buffers one codec lane (client slot or server) needs.
#[derive(Debug, Default)]
pub struct CodecScratch {
    /// Sparsification buffers (row means, top-k magnitudes).
    pub sparsify: SparsifyScratch,
    /// Encode-side buffers (level staging, coder payload).
    pub encode: cabac::EncodeScratch,
    /// Decode-side buffers (header entry table).
    pub decode: cabac::DecodeScratch,
    /// Per-tensor STC μ values (ternary protocols only).
    mus: Vec<f32>,
}

/// End-to-end codec: how a protocol turns a raw ΔW into wire bytes.
#[derive(Debug, Clone, Copy)]
pub struct UpdateCodec {
    /// Sparsification applied before quantization.
    pub sparsify: SparsifyMode,
    /// Quantization step assignment.
    pub quant: QuantConfig,
    /// Ternarize survivors to ±μ before encoding (the STC baseline).
    pub ternary: bool,
}

impl UpdateCodec {
    /// The paper's FSFL configuration (dynamic Eqs. 2+3 thresholds).
    pub fn fsfl(delta: f32, gamma: f32) -> Self {
        Self {
            sparsify: SparsifyMode::Dynamic { delta, gamma },
            quant: QuantConfig::default(),
            ternary: false,
        }
    }

    /// Fixed-rate variant used in Table 2 (96 % sparsity).
    pub fn fixed_rate(rate: f32) -> Self {
        Self {
            sparsify: SparsifyMode::TopK { rate },
            quant: QuantConfig::default(),
            ternary: false,
        }
    }

    /// STC baseline: top-k + ternarization (+ DeepCABAC encoding).
    pub fn stc(rate: f32) -> Self {
        Self {
            sparsify: SparsifyMode::TopK { rate },
            quant: QuantConfig::default(),
            ternary: true,
        }
    }

    /// FedAvg†: quantization + DeepCABAC but no sparsification.
    pub fn quant_only() -> Self {
        Self {
            sparsify: SparsifyMode::None,
            quant: QuantConfig::default(),
            ternary: false,
        }
    }

    /// Sparsify (consuming the raw update in place), quantize and encode.
    /// Returns `(wire bytes, dequantized Δ̂, stats)`. `indices` selects the
    /// transmitted tensors (partial updates transmit fewer).
    pub fn encode(&self, mut raw: Delta, indices: &[usize]) -> (Vec<u8>, Delta, EncodeStats) {
        let mut scratch = CodecScratch::default();
        let mut deq = Delta::zeros(raw.manifest.clone());
        let mut dst = Vec::new();
        let stats = self.encode_into(&mut raw, indices, &mut scratch, &mut deq, &mut dst);
        (dst, deq, stats)
    }

    /// Allocation-free encode: sparsifies `raw` **in place**, writes the
    /// bitstream to `dst` and the dequantized Δ̂ to `deq` (both cleared
    /// first; `deq` must share `raw`'s manifest). Byte-identical to
    /// [`UpdateCodec::encode`], and one `scratch` may serve updates of
    /// any shape back to back without leaking state between calls.
    ///
    /// ```
    /// use std::sync::Arc;
    /// use fsfl::compression::{CodecScratch, UpdateCodec};
    /// use fsfl::model::params::Delta;
    /// use fsfl::model::{Group, Kind, Manifest, TensorSpec};
    ///
    /// let manifest = Arc::new(Manifest {
    ///     model: "doc".into(), variant: "doc".into(), classes: 2,
    ///     input: vec![2, 2, 1], batch: 1, param_count: 8, scale_count: 0,
    ///     tensors: vec![TensorSpec {
    ///         name: "w".into(), shape: vec![2, 4], kind: Kind::ConvW,
    ///         group: Group::Weight, layer: "l".into(), out_ch: Some(2),
    ///         scale_for: None,
    ///     }],
    /// });
    /// let mut raw = Delta::zeros(manifest.clone());
    /// raw.tensors[0][1] = 6e-3;
    ///
    /// let codec = UpdateCodec::fsfl(1.0, 1.0);
    /// let mut scratch = CodecScratch::default();
    /// let mut deq = Delta::zeros(manifest.clone());
    /// let mut wire = Vec::new();
    /// let stats = codec.encode_into(&mut raw, &[0], &mut scratch, &mut deq, &mut wire);
    /// assert_eq!(stats.bytes, wire.len());
    /// assert!(stats.nonzero > 0);
    ///
    /// // The server decodes exactly those wire bytes back into Δ̂.
    /// let mut decoded = Delta::zeros(manifest);
    /// codec.decode_into(&wire, &mut decoded, &mut scratch).unwrap();
    /// assert_eq!(decoded, deq);
    /// ```
    // fsfl-lint: hot
    pub fn encode_into(
        &self,
        raw: &mut Delta,
        indices: &[usize],
        scratch: &mut CodecScratch,
        deq: &mut Delta,
        dst: &mut Vec<u8>,
    ) -> EncodeStats {
        let quant = self.quant;
        let CodecScratch {
            sparsify: sp,
            encode: enc,
            mus,
            ..
        } = scratch;
        if self.ternary {
            // STC: top-k happens inside ternarize; survivors become ±μ and
            // are coded with step = μ so levels are exactly ±1. Side
            // parameters keep their configured step.
            let rate = match self.sparsify {
                SparsifyMode::TopK { rate } => rate,
                _ => 0.99,
            };
            stc::ternarize_into(raw, indices, rate, &mut sp.mags, mus);
            let manifest = raw.manifest.clone();
            let mus: &Vec<f32> = mus;
            let step_fn = move |spec: &crate::model::TensorSpec| -> f32 {
                let idx = manifest.index_of(&spec.name).unwrap();
                if mus[idx] > 0.0 {
                    mus[idx]
                } else {
                    quant.step_for(spec)
                }
            };
            return cabac::encode_update_into(raw, indices, &step_fn, true, enc, deq, dst);
        }
        sparsify::sparsify_with(raw, indices, self.sparsify, &quant, sp);
        let step_fn = move |spec: &crate::model::TensorSpec| quant.step_for(spec);
        cabac::encode_update_into(raw, indices, &step_fn, true, enc, deq, dst)
    }
    // fsfl-lint: end-hot

    /// Decode a bitstream into a fresh [`Delta`].
    pub fn decode(&self, bytes: &[u8], manifest: &Arc<Manifest>) -> Result<Delta> {
        cabac::decode_update(bytes, manifest)
    }

    /// Allocation-free decode into a recycled `Delta` (cleared first).
    /// See [`UpdateCodec::encode_into`] for a round-trip example.
    // fsfl-lint: hot
    pub fn decode_into(
        &self,
        bytes: &[u8],
        out: &mut Delta,
        scratch: &mut CodecScratch,
    ) -> Result<()> {
        cabac::decode_update_with(bytes, out, &mut scratch.decode)
    }
    // fsfl-lint: end-hot
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::params::tests_support::manifest_conv_dense;

    /// One dirty scratch must serve every protocol family back to back
    /// and stay byte-identical to the allocating path.
    #[test]
    fn codec_scratch_reuse_matches_fresh_encode() {
        let m = manifest_conv_dense();
        let mut rng = crate::data::XorShiftRng::new(21);
        let mk = |rng: &mut crate::data::XorShiftRng| {
            let mut d = Delta::zeros(m.clone());
            for t in &mut d.tensors {
                for x in t.iter_mut() {
                    *x = rng.normal() * 2e-3;
                }
            }
            d
        };
        let idx = vec![0usize, 1];
        let mut scratch = CodecScratch::default();
        for codec in [
            UpdateCodec::fsfl(0.5, 1.0),
            UpdateCodec::stc(0.5),
            UpdateCodec::fixed_rate(0.5),
            UpdateCodec::quant_only(),
        ] {
            let raw = mk(&mut rng);
            let (fresh_bytes, fresh_deq, fresh_stats) = codec.encode(raw.clone(), &idx);
            let mut raw2 = raw;
            let mut deq = Delta::zeros(m.clone());
            let mut dst = Vec::new();
            let stats = codec.encode_into(&mut raw2, &idx, &mut scratch, &mut deq, &mut dst);
            assert_eq!(dst, fresh_bytes, "{codec:?}");
            assert_eq!(deq, fresh_deq, "{codec:?}");
            assert_eq!(stats.bytes, fresh_stats.bytes);
            let mut decoded = Delta::zeros(m.clone());
            codec.decode_into(&dst, &mut decoded, &mut scratch).unwrap();
            assert_eq!(decoded, fresh_deq, "{codec:?}");
        }
    }
}
