//! The differential-update compression pipeline (paper Sec. 3):
//! sparsification → uniform quantization → DeepCABAC entropy coding,
//! plus the STC baseline and error accumulation.
//!
//! [`UpdateCodec`] is the facade the FL protocols use: it owns the
//! sparsify + quantize + encode configuration and produces
//! `(bitstream, dequantized Δ̂, stats)` triples.

pub mod cabac;
pub mod quantize;
pub mod residual;
pub mod sparsify;
pub mod stc;

pub use cabac::{decode_update, encode_update, EncodeStats};
pub use quantize::QuantConfig;
pub use residual::Residual;
pub use sparsify::SparsifyMode;

use std::sync::Arc;

use anyhow::Result;

use crate::model::params::Delta;
use crate::model::Manifest;

/// End-to-end codec: how a protocol turns a raw ΔW into wire bytes.
#[derive(Debug, Clone, Copy)]
pub struct UpdateCodec {
    pub sparsify: SparsifyMode,
    pub quant: QuantConfig,
    /// Ternarize survivors to ±μ before encoding (the STC baseline).
    pub ternary: bool,
}

impl UpdateCodec {
    /// The paper's FSFL configuration (dynamic Eqs. 2+3 thresholds).
    pub fn fsfl(delta: f32, gamma: f32) -> Self {
        Self {
            sparsify: SparsifyMode::Dynamic { delta, gamma },
            quant: QuantConfig::default(),
            ternary: false,
        }
    }

    /// Fixed-rate variant used in Table 2 (96 % sparsity).
    pub fn fixed_rate(rate: f32) -> Self {
        Self {
            sparsify: SparsifyMode::TopK { rate },
            quant: QuantConfig::default(),
            ternary: false,
        }
    }

    /// STC baseline: top-k + ternarization (+ DeepCABAC encoding).
    pub fn stc(rate: f32) -> Self {
        Self {
            sparsify: SparsifyMode::TopK { rate },
            quant: QuantConfig::default(),
            ternary: true,
        }
    }

    /// FedAvg†: quantization + DeepCABAC but no sparsification.
    pub fn quant_only() -> Self {
        Self {
            sparsify: SparsifyMode::None,
            quant: QuantConfig::default(),
            ternary: false,
        }
    }

    /// Sparsify (consuming the raw update in place), quantize and encode.
    /// Returns `(wire bytes, dequantized Δ̂, stats)`. `indices` selects the
    /// transmitted tensors (partial updates transmit fewer).
    pub fn encode(&self, mut raw: Delta, indices: &[usize]) -> (Vec<u8>, Delta, EncodeStats) {
        let quant = self.quant;
        if self.ternary {
            // STC: top-k happens inside ternarize; survivors become ±μ and
            // are coded with step = μ so levels are exactly ±1. Side
            // parameters keep their configured step.
            let rate = match self.sparsify {
                SparsifyMode::TopK { rate } => rate,
                _ => 0.99,
            };
            let mus = stc::ternarize(&mut raw, indices, rate);
            let manifest = raw.manifest.clone();
            let step_fn = move |spec: &crate::model::TensorSpec| -> f32 {
                let idx = manifest.index_of(&spec.name).unwrap();
                if mus[idx] > 0.0 {
                    mus[idx]
                } else {
                    quant.step_for(spec)
                }
            };
            return cabac::encode_update(&raw, indices, &step_fn);
        }
        sparsify::sparsify(&mut raw, indices, self.sparsify, &quant);
        let step_fn = move |spec: &crate::model::TensorSpec| quant.step_for(spec);
        cabac::encode_update(&raw, indices, &step_fn)
    }

    pub fn decode(&self, bytes: &[u8], manifest: &Arc<Manifest>) -> Result<Delta> {
        cabac::decode_update(bytes, manifest)
    }
}
