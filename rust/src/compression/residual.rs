//! Error accumulation ("residuals", Eq. 5, after Sattler et al. [21]).
//!
//! Each client stores the difference between its full-precision update
//! and the actually-transmitted (sparsified + quantized) update:
//!
//! ```text
//! ΔW_i^(t+1) = R_i^(t) + W_i^(t+1) − W_i^(t)      (inserted at Alg.1 l.10)
//! R_i^(t+1)  = ΔW_i^(t+1) − Δ̂W_i^(t+1)
//! ```
//!
//! Small update elements accumulate across rounds until they clear the
//! sparsification/quantization thresholds, so no learning signal is ever
//! permanently discarded.

use crate::model::params::Delta;

/// One client's carried error-accumulation state.
#[derive(Debug, Clone)]
pub struct Residual {
    acc: Delta,
}

impl Residual {
    /// Zero-initialized residual for a manifest.
    pub fn zeros(manifest: std::sync::Arc<crate::model::Manifest>) -> Self {
        Self {
            acc: Delta::zeros(manifest),
        }
    }

    /// Inject the carried error into a fresh raw update (Eq. 5, first line).
    pub fn inject(&self, raw: &mut Delta) {
        raw.accumulate(&self.acc);
    }

    /// Store what was lost this round: `R = full − transmitted`.
    pub fn update(&mut self, full: &Delta, transmitted: &Delta) {
        for ((acc, f), t) in self
            .acc
            .tensors
            .iter_mut()
            .zip(&full.tensors)
            .zip(&transmitted.tensors)
        {
            for ((a, &x), &y) in acc.iter_mut().zip(f).zip(t) {
                *a = x - y;
            }
        }
    }

    /// Euclidean norm of the carried error.
    pub fn l2_norm(&self) -> f64 {
        self.acc.l2_norm()
    }

    /// Snapshot the carried error values, tensor-major in manifest order
    /// (session plane).
    pub fn snapshot(&self) -> Vec<Vec<f32>> {
        self.acc.tensors.clone()
    }

    /// Validate a [`Residual::snapshot`]'s shape against this residual
    /// without writing anything (callers that must guarantee no partial
    /// apply check every piece of state before mutating any of it).
    pub fn check(&self, slabs: &[Vec<f32>]) -> anyhow::Result<()> {
        if slabs.len() != self.acc.tensors.len() {
            return Err(anyhow::anyhow!(
                "residual snapshot has {} tensors, manifest wants {}",
                slabs.len(),
                self.acc.tensors.len()
            ));
        }
        for (i, (s, t)) in slabs.iter().zip(&self.acc.tensors).enumerate() {
            if s.len() != t.len() {
                return Err(anyhow::anyhow!(
                    "residual tensor {i}: snapshot len {} != manifest len {}",
                    s.len(),
                    t.len()
                ));
            }
        }
        Ok(())
    }

    /// Restore carried error values from a [`Residual::snapshot`]. Every
    /// slab length is validated before anything is written — a mismatch
    /// errors with the state untouched (no partial apply).
    pub fn restore(&mut self, slabs: &[Vec<f32>]) -> anyhow::Result<()> {
        self.check(slabs)?;
        for (t, s) in self.acc.tensors.iter_mut().zip(slabs) {
            t.copy_from_slice(s);
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::params::tests_support::manifest_conv_dense;

    #[test]
    fn residual_accumulates_until_transmitted() {
        let m = manifest_conv_dense();
        let mut res = Residual::zeros(m.clone());
        // Round 1: tiny update, everything "sparsified away".
        let mut raw = Delta::zeros(m.clone());
        raw.tensors[0][0] = 0.3;
        res.inject(&mut raw);
        assert_eq!(raw.tensors[0][0], 0.3);
        let transmitted = Delta::zeros(m.clone()); // all dropped
        res.update(&raw, &transmitted);
        assert!((res.l2_norm() - 0.3).abs() < 1e-6);

        // Round 2: same tiny update again; injected raw now carries 0.6.
        let mut raw2 = Delta::zeros(m.clone());
        raw2.tensors[0][0] = 0.3;
        res.inject(&mut raw2);
        assert!((raw2.tensors[0][0] - 0.6).abs() < 1e-6);

        // This time it is transmitted in full → residual drains to zero.
        let transmitted2 = raw2.clone();
        res.update(&raw2, &transmitted2);
        assert!(res.l2_norm() < 1e-9);
    }
}
