//! `fsfl` — launcher CLI for the FSFL reproduction.
//!
//! Subcommands map 1:1 to the paper's evaluation artifacts (DESIGN.md
//! experiment index): `run` for ad-hoc experiments, `fig1..fig5` and
//! `table1`/`table2` to regenerate each figure/table's series.

use anyhow::Result;

use fsfl::cli::Flags;
use fsfl::compression::SparsifyMode;
use fsfl::coordinator;
use fsfl::data::TaskKind;
use fsfl::fl::{ExperimentConfig, Protocol, ScheduleKind, SessionConfig, TransportKind};
use fsfl::harness;
use fsfl::runtime::Optimizer;
use fsfl::session::SessionStore;
use fsfl::supervise::{Clock, MonotonicClock};

const USAGE: &str = "\
fsfl — Filter-Scaled Sparse Federated Learning (paper reproduction)

USAGE: fsfl <COMMAND> [--flags]

COMMANDS:
  run      one FL experiment (--variant --task --protocol --clients
           --rounds --local-epochs --scale-epochs --optimizer --lr
           --scale-optimizer --scale-lr --schedule --rate --delta --gamma
           --bidirectional --dirichlet --train-per-client --val-per-client
           --test-samples --warmup-steps --participation --seed
           --target-accuracy --codec-workers --pipelined
           --compute-shards --transport mpsc|loopback|tcp --shard-procs
           --tree-children K (hierarchical fan-in: each wire shard slot
           becomes a mid-tier aggregator reducing K leaf shards;
           byte-identical to the flat fan-in)
           --resident-clients N (cold-state paging: keep at most N
           client states resident per shard, spill the rest through the
           session snapshot codec; 0 = everything resident)
           --synth (PJRT-free synthetic compute plane)
           --synth-model small|large (synthetic model contract)
           --emit-metrics (machine-readable `#fsfl-metric` stdout lines
           for the bench driver: live per-round latency/bytes, totals,
           measured wire traffic, incident history)
           --trace-out FILE (export a Chrome-trace JSON of the run's
           spans — open in Perfetto/chrome://tracing or inspect with
           `fsfl trace summarize`; deterministic: telemetry never
           changes the run's outputs)
           --metrics-addr HOST:PORT (serve live Prometheus text on
           GET /metrics for the run's duration)
           --checkpoint-dir DIR --checkpoint-every K
           --checkpoint-retain N (durable session; keep newest N snapshots)
           --resume DIR (continue a killed run from its last snapshot;
           byte-identical to the uninterrupted run)
           --elastic-resize R:M[,R:M…] (grow/shrink the shard set to M
           immediately before round R)
           --elastic-replace R:S[,R:S…] (replace shard S with a fresh
           worker immediately before round R)
           --heartbeat MS (liveness lease cadence; 0 = off)
           --round-deadline MS (straggler cutoff per round; 0 = off)
           --shard-retries N (respawn attempts per shard loss, default 2)
           --on-shard-loss abort|respawn|degrade (recovery policy once a
           shard is declared dead; default abort)
           --join-timeout SECS (worker join/handshake wait, default 120))
  shard-worker  join a coordinator as one shard process
           (--connect HOST:PORT; spawned automatically by
           `run --shard-procs`, or launch by hand against `serve`)
  aggregator  join a coordinator as one mid-tier aggregator that fans
           its slot out over K in-process leaf shards and streams one
           merged lane set upward (--connect HOST:PORT --children K;
           launch by hand against `serve`, one per shard slot)
  serve    bind a TCP listener and run one experiment over externally
           launched shard workers (--listen HOST:PORT, default
           127.0.0.1:0; accepts the same experiment flags as run;
           workers join via `fsfl shard-worker --connect`)
  bench    cross-scenario benchmark harness: drives this binary through
           the deterministic suite-A grid and/or the seeded stochastic
           suite-B legs, writes bench_runs.jsonl + BENCH_scenarios.json
           (--suite a|b|all|scale --smoke --seed N --out DIR, default
           bench-out, --bin PATH to benchmark another fsfl build;
           `scale` is the 100k-client paging cell and is not part of
           `all`)
  lint     invariant lint over the crate's sources (--root DIR, default
           `.`; accepts the repo root or the rust/ crate dir; --json for
           machine-readable findings). Enforces clock discipline,
           hot-path allocation fences, wire-protocol consistency, panic
           hygiene and unsafe SAFETY comments; exits 1 on any finding
  session  inspect DIR — dump snapshot metadata (version, round, shard
           assignment, client count, params checksum, size, valid/torn)
           without decoding parameters
  trace    summarize FILE — per-stage p50/p95/p99 latency and the
           top-3 widest spans per round of a --trace-out export
  fig1     LR schedule series (--epochs --steps-per-epoch --base-lr)
  fig2     accuracy vs transmitted data per config (--preset quick|paper
           --variant --task --sgd --bidirectional --clients --rounds)
  fig3     scale-factor statistics by depth (--preset --variant --rounds)
  fig4     update sparsity per epoch, scaled vs unscaled (--preset
           --variant --rounds)
  fig5     residuals + client-count scaling (--preset --variant
           --clients 2,4,8 --rounds)
  table1   #params_add and t_add per model (--preset --variants a,b,c)
  table2   protocol comparison (--preset --variant --clients 2,4,8,16
           --rounds --rate --target)
  appendix-c  per-client label histograms (--task --clients --dirichlet)

GLOBAL: --artifacts <dir> (default artifacts), --out <dir> (default results)
";

fn parse_task(s: &str) -> Result<TaskKind> {
    match s.to_ascii_lowercase().as_str() {
        "cifar" | "cifar10" => Ok(TaskKind::CifarLike),
        "voc" | "pascal" => Ok(TaskKind::VocLike),
        "xray" | "chest" => Ok(TaskKind::XrayLike),
        other => Err(anyhow::anyhow!("unknown task {other:?}")),
    }
}

/// Shared tail of every `run`/`serve` leg: CSV sink + summary line,
/// plus the machine-readable totals/wire/events lines under
/// `--emit-metrics` (and the `registry` cross-check line whenever a
/// telemetry handle was attached).
fn finish_run(
    log: &fsfl::metrics::RunLog,
    out: &std::path::Path,
    emit: bool,
    telemetry: Option<&fsfl::obs::Telemetry>,
) -> Result<()> {
    let csv = out.join(format!("{}.csv", log.name));
    log.write_csv(&csv)?;
    println!(
        "done: best acc {:.3}, total up {}, log → {}",
        log.best_accuracy(),
        fsfl::metrics::fmt_bytes(log.total_bytes(true)),
        csv.display()
    );
    if let Some(w) = log.wire {
        println!(
            "wire (measured at the frame layer): {} to shards, {} from shards",
            fsfl::metrics::fmt_bytes(w.sent() as usize),
            fsfl::metrics::fmt_bytes(w.received() as usize),
        );
    }
    if emit {
        for line in fsfl::bench::lines_finish(log) {
            println!("{line}");
        }
        if let Some(t) = telemetry {
            println!("{}", fsfl::bench::line_registry(&t.metrics));
        }
    }
    Ok(())
}

/// Telemetry wiring for one `run`/`serve` invocation: the optional
/// handle threaded into the coordinator, the live scrape endpoint, and
/// the trace destination written once the run completes. Telemetry is
/// strictly passive — a run with any of these armed produces
/// byte-identical CSV/metric output to one without.
struct ObsSetup {
    telemetry: fsfl::obs::Obs,
    trace_out: Option<std::path::PathBuf>,
    server: Option<fsfl::obs::MetricsServer>,
}

impl ObsSetup {
    /// Build the telemetry plane from the CLI flags. The handle exists
    /// whenever any consumer does: span tracing for `--trace-out`, the
    /// scrape endpoint for `--metrics-addr`, or the end-of-run
    /// `registry` cross-check line for `--emit-metrics`.
    fn build(
        trace_out: Option<String>,
        metrics_addr: Option<String>,
        emit: bool,
    ) -> Result<Self> {
        let tracing = trace_out.is_some();
        let telemetry = (tracing || metrics_addr.is_some() || emit).then(|| {
            fsfl::obs::Telemetry::new(
                std::sync::Arc::new(fsfl::supervise::MonotonicClock::new()),
                tracing,
            )
        });
        let server = match (metrics_addr, &telemetry) {
            (Some(addr), Some(t)) => {
                let srv = fsfl::obs::MetricsServer::bind(&addr, t.clone())?;
                println!("metrics endpoint: http://{}/metrics", srv.addr());
                // Scrapers race the run; make sure the address is on
                // the wire before round 0.
                std::io::Write::flush(&mut std::io::stdout()).ok();
                Some(srv)
            }
            _ => None,
        };
        Ok(Self {
            telemetry,
            trace_out: trace_out.map(Into::into),
            server,
        })
    }

    /// Shared run tail: metric lines, then the exported trace (if
    /// armed), then the scrape endpoint shuts down.
    fn finish(self, log: &fsfl::metrics::RunLog, out: &std::path::Path, emit: bool) -> Result<()> {
        finish_run(log, out, emit, self.telemetry.as_deref())?;
        if let (Some(path), Some(t)) = (&self.trace_out, &self.telemetry) {
            let doc = fsfl::obs::chrome::render(&t.drain_spans(), t.dropped_spans());
            std::fs::write(path, doc)
                .map_err(|e| anyhow::anyhow!("writing trace {}: {e}", path.display()))?;
            println!("trace → {}", path.display());
        }
        drop(self.server);
        Ok(())
    }
}

/// Round-event callback shared by every leg: the human progress line,
/// preceded under `--emit-metrics` by the live machine-readable round
/// line (stdout is line-buffered even into a pipe, so the bench driver
/// observes each round the moment it completes — that's what lets its
/// chaos leg SIGKILL this process provably mid-run).
fn round_printer(emit: bool) -> impl FnMut(&coordinator::Event) {
    // Time through the Clock trait, not Instant::now(): the inter-round
    // gap is presentation-only wall time, but every read still goes
    // through supervise so the clock-discipline lint holds crate-wide.
    let clock = MonotonicClock::new();
    let mut last = clock.now();
    move |ev: &coordinator::Event| {
        if let coordinator::Event::RoundDone(m) = ev {
            if emit {
                let now = clock.now();
                println!(
                    "{}",
                    fsfl::bench::line_round(m, now.saturating_sub(last).as_secs_f64() * 1e3)
                );
                last = now;
            }
            coordinator::print_round(m);
        }
    }
}

/// The supervision-policy flags shared by `run` and `run --resume`
/// (operational knobs, not experiment shape — a resume may re-arm them
/// freely without touching the snapshot's science config).
const POLICY_FLAGS: [&str; 5] = [
    "heartbeat",
    "round-deadline",
    "shard-retries",
    "on-shard-loss",
    "join-timeout",
];

/// Parse the supervision [`RoundPolicy`] flags (defaults preserved for
/// absent flags).
fn policy_from_flags(flags: &Flags) -> Result<fsfl::fl::RoundPolicy> {
    let mut p = fsfl::fl::RoundPolicy::default();
    if let Some(ms) = flags.get::<u64>("heartbeat")? {
        p.heartbeat = std::time::Duration::from_millis(ms);
    }
    if let Some(ms) = flags.get::<u64>("round-deadline")? {
        p.round_deadline = std::time::Duration::from_millis(ms);
    }
    p.retry_budget = flags.get_or("shard-retries", p.retry_budget)?;
    if let Some(s) = flags.str_opt("on-shard-loss") {
        p.on_loss = s.parse()?;
    }
    if let Some(secs) = flags.get::<u64>("join-timeout")? {
        p.join_timeout = std::time::Duration::from_secs(secs);
    }
    Ok(p)
}

/// `fsfl run --resume DIR`: continue a killed run from its newest valid
/// snapshot. The snapshot's config is re-run verbatim (including its
/// checkpoint settings, so the resumed run keeps checkpointing into the
/// same session directory). Supervision policy flags are operational
/// and may be re-armed on resume.
fn cmd_resume(
    dir: &str,
    shard_procs: bool,
    policy: Option<fsfl::fl::RoundPolicy>,
    out: &std::path::Path,
    emit: bool,
    obs: ObsSetup,
) -> Result<()> {
    // Read-only lookup: a mistyped path must error, not be created.
    if !std::path::Path::new(dir).is_dir() {
        return Err(anyhow::anyhow!("no session directory at {dir}"));
    }
    let store = SessionStore::open(dir)?;
    let state = store
        .latest()?
        .ok_or_else(|| anyhow::anyhow!("no usable snapshot in {dir}"))?;
    println!(
        "resuming {:?} at round {} ({} rounds total, {} shards, {} snapshot clients)",
        state.cfg.name,
        state.next_round,
        state.cfg.rounds,
        state.shards,
        state.clients.len()
    );
    let mut cfg = state.cfg.clone();
    // Keep checkpointing into the directory the snapshot was actually
    // loaded from — the embedded dir may be relative to the original
    // run's cwd and would silently point elsewhere here.
    if let Some(s) = cfg.session.as_mut() {
        s.dir = std::path::PathBuf::from(dir);
    }
    // Re-arm (or disarm) supervision per this invocation's flags; the
    // resume-equality check normalizes the policy, so this never trips
    // the "config mismatch" guard.
    if let Some(p) = policy {
        cfg.policy = p;
    }
    let manifest = if state.synthetic {
        let m = fsfl::model::Manifest::parse(&state.manifest_tsv)?;
        m.validate()?;
        Some(std::sync::Arc::new(m))
    } else {
        None
    };
    if emit {
        println!(
            "{}",
            fsfl::bench::line_run(
                &cfg.name,
                cfg.rounds,
                cfg.clients,
                manifest.as_ref().map(|m| m.param_count),
            )
        );
    }
    let mut on_event = round_printer(emit);
    let log = if state.synthetic {
        let manifest = manifest.expect("synthetic snapshot carries a manifest");
        if shard_procs {
            // Synthetic compute, real OS shard-worker processes.
            let exe = std::env::current_exe()?;
            coordinator::run_experiment_processes_session_observed(
                cfg,
                coordinator::ComputeSpec::Synthetic { manifest },
                &exe,
                coordinator::ElasticPlan::default(),
                Some(state),
                obs.telemetry.clone(),
                on_event,
            )?
        } else {
            coordinator::run_experiment_synthetic_session_observed(
                cfg,
                manifest,
                coordinator::ElasticPlan::default(),
                Some(state),
                None,
                obs.telemetry.clone(),
                on_event,
            )?
        }
    } else if shard_procs {
        // Workers speak TCP regardless of the snapshot's transport
        // field; the config itself is re-run verbatim.
        let exe = std::env::current_exe()?;
        coordinator::run_experiment_processes_session_observed(
            cfg,
            coordinator::ComputeSpec::Real,
            &exe,
            coordinator::ElasticPlan::default(),
            Some(state),
            obs.telemetry.clone(),
            on_event,
        )?
    } else {
        coordinator::run_experiment_resumed_observed(cfg, state, obs.telemetry.clone(), &mut on_event)?
    };
    obs.finish(&log, out, emit)
}

/// `fsfl session inspect DIR`: dump every snapshot's metadata without
/// decoding server parameters or client states into memory.
fn cmd_session_inspect(dir: &str) -> Result<()> {
    if !std::path::Path::new(dir).is_dir() {
        return Err(anyhow::anyhow!("no session directory at {dir}"));
    }
    let store = SessionStore::open(dir)?;
    let metas = store.inspect()?;
    if metas.is_empty() {
        println!("no snapshots in {dir}");
        return Ok(());
    }
    println!(
        "{:<24} {:>10} {:>4} {:>6} {:>7} {:>8} {:>7}  {:<18} status",
        "file", "bytes", "ver", "round", "shards", "clients", "rounds", "params-fnv"
    );
    for m in &metas {
        let name = m
            .path
            .file_name()
            .map(|n| n.to_string_lossy().into_owned())
            .unwrap_or_else(|| m.path.display().to_string());
        match &m.status {
            fsfl::session::SnapshotStatus::Valid(info) => println!(
                "{:<24} {:>10} {:>4} {:>6} {:>7} {:>8} {:>7}  {:<18} valid{}",
                name,
                m.file_size,
                info.version,
                info.next_round,
                info.shards,
                info.clients,
                info.rounds,
                format!("{:016x}", info.params_checksum),
                if info.synthetic { " (synth)" } else { "" },
            ),
            fsfl::session::SnapshotStatus::Torn(reason) => println!(
                "{:<24} {:>10} {:>4} {:>6} {:>7} {:>8} {:>7}  {:<18} TORN: {reason}",
                name, m.file_size, "-", "-", "-", "-", "-", "-",
            ),
        }
    }
    Ok(())
}

/// Everything `run` and `serve` share: the parsed experiment config
/// plus the deployment-shape knobs that ride alongside it.
struct RunArgs {
    cfg: ExperimentConfig,
    plan: coordinator::ElasticPlan,
    policy: fsfl::fl::RoundPolicy,
    policy_given: bool,
    shard_procs: bool,
    synth: bool,
    /// `Some` iff `--synth`: the selected synthetic model contract.
    manifest: Option<std::sync::Arc<fsfl::model::Manifest>>,
    emit: bool,
    resume_dir: Option<String>,
    /// `--trace-out FILE`: export a Chrome-trace JSON of the run.
    trace_out: Option<String>,
    /// `--metrics-addr HOST:PORT`: serve live Prometheus text.
    metrics_addr: Option<String>,
}

/// Parse the experiment-shape flags `run` and `serve` share (the
/// caller still runs `reject_unknown` after consuming its own extras).
fn parse_run_args(flags: &Flags, artifacts: &std::path::Path) -> Result<RunArgs> {
    let task = parse_task(&flags.str_or("task", "cifar"))?;
    let protocol: Protocol = flags.str_or("protocol", "fsfl").parse()?;
    let variant = flags.str_or("variant", "tiny_cnn");
    let mut cfg = ExperimentConfig::quick(&variant, task, protocol);
    cfg.artifacts_root = artifacts.to_path_buf();
    cfg.clients = flags.get_or("clients", 2)?;
    cfg.rounds = flags.get_or("rounds", 10)?;
    cfg.local_epochs = flags.get_or("local-epochs", 1)?;
    cfg.scale_epochs = flags.get_or("scale-epochs", 2)?;
    cfg.optimizer = flags.str_or("optimizer", "adam").parse::<Optimizer>()?;
    cfg.lr = flags.get_or("lr", 1e-3)?;
    cfg.scale_optimizer = flags
        .str_or("scale-optimizer", "adam")
        .parse::<Optimizer>()?;
    cfg.scale_lr = flags.get_or("scale-lr", 1e-2)?;
    cfg.schedule = flags.str_or("schedule", "linear").parse::<ScheduleKind>()?;
    cfg.sparsify = match flags.get::<f32>("rate")? {
        Some(r) => SparsifyMode::TopK { rate: r },
        None => SparsifyMode::Dynamic {
            delta: flags.get_or("delta", 1.0)?,
            gamma: flags.get_or("gamma", 1.0)?,
        },
    };
    cfg.bidirectional = flags.flag("bidirectional");
    cfg.dirichlet_alpha = flags.get("dirichlet")?;
    cfg.train_per_client = flags.get_or("train-per-client", 128)?;
    cfg.val_per_client = flags.get_or("val-per-client", 32)?;
    cfg.test_samples = flags.get_or("test-samples", 128)?;
    cfg.warmup_steps = flags.get_or("warmup-steps", 0)?;
    cfg.codec_workers = flags.get_or("codec-workers", 0)?;
    cfg.pipelined = flags.flag("pipelined");
    cfg.compute_shards = flags.get_or("compute-shards", 1)?;
    cfg.participation = flags.get_or("participation", 1.0)?;
    cfg.seed = flags.get_or("seed", 0)?;
    cfg.target_accuracy = flags.get("target-accuracy")?;
    cfg.transport = flags.str_or("transport", "mpsc").parse::<TransportKind>()?;
    cfg.tree_children = flags.get_or("tree-children", 0)?;
    cfg.resident_clients = flags.get_or("resident-clients", 0)?;
    let shard_procs = flags.flag("shard-procs");
    let synth = flags.flag("synth");
    let emit = flags.flag("emit-metrics");
    let model_name = flags.str_or("synth-model", "small");
    let manifest = if synth {
        Some(match model_name.as_str() {
            "small" => fsfl::fl::synth::demo_manifest(),
            "large" => fsfl::fl::synth::large_manifest(),
            other => {
                return Err(anyhow::anyhow!(
                    "unknown --synth-model {other:?} (small|large)"
                ))
            }
        })
    } else if flags.str_opt("synth-model").is_some() {
        return Err(anyhow::anyhow!("--synth-model requires --synth"));
    } else {
        None
    };
    if let Some(dir) = flags.str_opt("checkpoint-dir") {
        cfg.session = Some(SessionConfig {
            dir: dir.into(),
            every: flags.get_or("checkpoint-every", 1)?,
            retain: flags.get_or("checkpoint-retain", SessionConfig::DEFAULT_RETAIN)?,
            crash_after: None,
        });
    } else {
        let _ = flags.get_or::<usize>("checkpoint-every", 1); // mark known
        let _ = flags.get_or::<usize>("checkpoint-retain", SessionConfig::DEFAULT_RETAIN); // mark known
    }
    let mut plan = coordinator::ElasticPlan::default();
    if let Some(p) = flags.pairs("elastic-replace")? {
        plan.replace = p;
    }
    if let Some(p) = flags.pairs("elastic-resize")? {
        plan.resize = p;
    }
    let policy = policy_from_flags(flags)?;
    let policy_given = flags
        .keys()
        .iter()
        .any(|k| POLICY_FLAGS.contains(&k.as_str()));
    cfg.policy = policy.clone();
    let resume_dir = flags.str_opt("resume");
    let trace_out = flags.str_opt("trace-out");
    let metrics_addr = flags.str_opt("metrics-addr");
    Ok(RunArgs {
        cfg,
        plan,
        policy,
        policy_given,
        shard_procs,
        synth,
        manifest,
        emit,
        resume_dir,
        trace_out,
        metrics_addr,
    })
}

fn cmd_run(flags: &Flags, artifacts: &std::path::Path, out: &std::path::Path) -> Result<()> {
    let args = parse_run_args(flags, artifacts)?;
    flags.reject_unknown()?;
    let RunArgs {
        mut cfg,
        plan,
        policy,
        policy_given,
        shard_procs,
        synth,
        manifest,
        emit,
        resume_dir,
        trace_out,
        metrics_addr,
    } = args;
    let obs = ObsSetup::build(trace_out, metrics_addr, emit)?;

    if let Some(dir) = resume_dir {
        // Resume re-runs the snapshot's config verbatim — refuse
        // experiment-shape flags instead of silently ignoring them.
        // Supervision policy flags are operational, not shape, and may
        // be re-armed freely (as may metric emission and telemetry —
        // both strictly passive).
        const RESUME_FLAGS: [&str; 7] = [
            "resume",
            "out",
            "artifacts",
            "shard-procs",
            "emit-metrics",
            "trace-out",
            "metrics-addr",
        ];
        let stray: Vec<String> = flags
            .keys()
            .into_iter()
            .filter(|k| {
                !RESUME_FLAGS.contains(&k.as_str()) && !POLICY_FLAGS.contains(&k.as_str())
            })
            .map(|k| format!("--{k}"))
            .collect();
        if !stray.is_empty() {
            return Err(anyhow::anyhow!(
                "--resume re-runs the snapshot's experiment config verbatim; \
                 drop {} (or start a fresh checkpointed run)",
                stray.join(" ")
            ));
        }
        return cmd_resume(&dir, shard_procs, policy_given.then_some(policy), out, emit, obs);
    }

    if emit {
        println!(
            "{}",
            fsfl::bench::line_run(
                &cfg.name,
                cfg.rounds,
                cfg.clients,
                manifest.as_ref().map(|m| m.param_count),
            )
        );
    }
    let mut on_event = round_printer(emit);
    let log = if synth && shard_procs {
        // Synthetic compute, real OS shard-worker processes (needs a
        // socket: shard-procs implies TCP).
        cfg.transport = TransportKind::Tcp;
        let exe = std::env::current_exe()?;
        coordinator::run_experiment_processes_session_observed(
            cfg,
            coordinator::ComputeSpec::Synthetic {
                manifest: manifest.expect("--synth selected a manifest"),
            },
            &exe,
            plan,
            None,
            obs.telemetry.clone(),
            on_event,
        )?
    } else if synth {
        // PJRT-free synthetic compute plane over the selected model
        // contract — what the session/transport/bench CI jobs drive.
        coordinator::run_experiment_synthetic_session_observed(
            cfg,
            manifest.expect("--synth selected a manifest"),
            plan,
            None,
            None,
            obs.telemetry.clone(),
            on_event,
        )?
    } else if shard_procs {
        // Real OS processes need a socket: shard-procs implies TCP.
        cfg.transport = TransportKind::Tcp;
        let exe = std::env::current_exe()?;
        coordinator::run_experiment_processes_session_observed(
            cfg,
            coordinator::ComputeSpec::Real,
            &exe,
            plan,
            None,
            obs.telemetry.clone(),
            on_event,
        )?
    } else if !plan.is_empty() {
        coordinator::run_experiment_sharded_elastic_observed(
            cfg,
            plan,
            obs.telemetry.clone(),
            &mut on_event,
        )?
    } else {
        coordinator::run_experiment_threaded_observed(cfg, obs.telemetry.clone(), &mut on_event)?
    };
    obs.finish(&log, out, emit)
}

/// `fsfl serve`: bind a TCP listener, announce it (machine-readably
/// under `--emit-metrics`, so the bench driver can launch workers at
/// seeded Poisson offsets), and run one experiment over externally
/// launched `fsfl shard-worker` processes.
fn cmd_serve(flags: &Flags, artifacts: &std::path::Path, out: &std::path::Path) -> Result<()> {
    let args = parse_run_args(flags, artifacts)?;
    let listen = flags.str_or("listen", "127.0.0.1:0");
    flags.reject_unknown()?;
    if args.resume_dir.is_some() {
        return Err(anyhow::anyhow!(
            "serve does not resume sessions; use `fsfl run --resume DIR --shard-procs`"
        ));
    }
    if args.shard_procs {
        return Err(anyhow::anyhow!(
            "serve admits externally launched workers; drop --shard-procs and start \
             `fsfl shard-worker --connect` processes instead"
        ));
    }
    let RunArgs {
        mut cfg,
        plan,
        manifest,
        emit,
        trace_out,
        metrics_addr,
        ..
    } = args;
    let obs = ObsSetup::build(trace_out, metrics_addr, emit)?;
    // Externally-joined workers speak the TCP wire protocol regardless
    // of the --transport flag.
    cfg.transport = TransportKind::Tcp;
    let listener = std::net::TcpListener::bind(&listen)
        .map_err(|e| anyhow::anyhow!("binding {listen}: {e}"))?;
    let addr = listener.local_addr()?;
    if emit {
        println!("{}", fsfl::bench::line_listening(&addr.to_string()));
        println!(
            "{}",
            fsfl::bench::line_run(
                &cfg.name,
                cfg.rounds,
                cfg.clients,
                manifest.as_ref().map(|m| m.param_count),
            )
        );
    } else {
        println!(
            "listening on {addr}; waiting for {} shard worker(s)",
            cfg.compute_shards
        );
    }
    // Workers race the listen line; make sure it is on the wire first.
    std::io::Write::flush(&mut std::io::stdout()).ok();
    let compute = match &manifest {
        Some(m) => coordinator::ComputeSpec::Synthetic { manifest: m.clone() },
        None => coordinator::ComputeSpec::Real,
    };
    let log = coordinator::serve_session_observed(
        cfg,
        &listener,
        compute,
        plan,
        None,
        obs.telemetry.clone(),
        || Ok(()),
        round_printer(emit),
    )?;
    obs.finish(&log, out, emit)
}

/// `fsfl bench`: build the scenario list, drive the (release) binary
/// through it, and merge the per-run JSON lines into the committed
/// `BENCH_scenarios.json` trajectory file.
fn cmd_bench(flags: &Flags) -> Result<()> {
    use fsfl::bench::{driver, spec};
    let suite = flags.str_or("suite", "a").to_ascii_lowercase();
    let smoke = flags.flag("smoke");
    let seed: u64 = flags.get_or("seed", 7)?;
    let out = std::path::PathBuf::from(flags.str_or("out", "bench-out"));
    let exe = match flags.str_opt("bin") {
        Some(p) => std::path::PathBuf::from(p),
        None => std::env::current_exe()?,
    };
    flags.reject_unknown()?;
    let mut scenarios = Vec::new();
    if matches!(suite.as_str(), "a" | "all") {
        scenarios.extend(spec::suite_a(smoke));
    }
    if matches!(suite.as_str(), "b" | "all") {
        scenarios.extend(spec::suite_b(seed, smoke));
    }
    // The 100k-client scale cell is opt-in only: it is a memory/
    // throughput probe, not part of the `all` regression grids.
    if suite.as_str() == "scale" {
        scenarios.extend(spec::suite_scale(smoke));
    }
    if scenarios.is_empty() {
        return Err(anyhow::anyhow!("unknown --suite {suite:?} (a|b|all|scale)"));
    }
    let mode = if smoke { "smoke" } else { "full" };
    println!(
        "bench: {} scenario(s), suite {suite}, mode {mode}, driving {}",
        scenarios.len(),
        exe.display()
    );
    let records = driver::run_all(&exe, &scenarios, &out)?;
    let report = driver::summarize(&records, mode, seed);
    let path = out.join("BENCH_scenarios.json");
    report.write(&path)?;
    println!("summary → {}", path.display());
    let failed: Vec<&str> = records
        .iter()
        .filter(|r| !r.ok)
        .map(|r| r.scenario.id.as_str())
        .collect();
    if !failed.is_empty() {
        return Err(anyhow::anyhow!(
            "{} of {} scenario(s) failed: {}",
            failed.len(),
            records.len(),
            failed.join(", ")
        ));
    }
    Ok(())
}

/// `fsfl lint` — run the static-analysis plane over the crate sources
/// and exit 1 if any invariant is violated (see `fsfl::analysis`).
fn cmd_lint(flags: &Flags) -> Result<()> {
    let root = std::path::PathBuf::from(flags.str_or("root", "."));
    let json = flags.flag("json");
    flags.reject_unknown()?;
    let report = fsfl::analysis::run_lint(&root)?;
    if json {
        println!("{}", report.to_json());
    } else {
        for f in &report.findings {
            println!("{f}");
        }
        println!(
            "fsfl lint: {} file(s) scanned, {} finding(s)",
            report.files_scanned,
            report.findings.len()
        );
    }
    if !report.clean() {
        std::process::exit(1);
    }
    Ok(())
}

fn main() -> Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let Some(cmd) = args.first().cloned() else {
        eprint!("{USAGE}");
        std::process::exit(2);
    };
    if cmd == "trace" {
        // `fsfl trace summarize FILE` — positional sub-command, handled
        // before the flag parser (which rejects positionals).
        match (args.get(1).map(|s| s.as_str()), args.get(2)) {
            (Some("summarize"), Some(file)) => {
                Flags::parse(&args[3..])?.reject_unknown()?;
                print!(
                    "{}",
                    fsfl::obs::summarize::summarize_file(std::path::Path::new(file))?
                );
                return Ok(());
            }
            _ => {
                eprintln!("usage: fsfl trace summarize FILE\n\n{USAGE}");
                std::process::exit(2);
            }
        }
    }
    if cmd == "session" {
        // `fsfl session inspect DIR` — positional sub-command, handled
        // before the flag parser (which rejects positionals).
        match (args.get(1).map(|s| s.as_str()), args.get(2)) {
            (Some("inspect"), Some(dir)) => {
                Flags::parse(&args[3..])?.reject_unknown()?;
                return cmd_session_inspect(dir);
            }
            _ => {
                eprintln!("usage: fsfl session inspect DIR\n\n{USAGE}");
                std::process::exit(2);
            }
        }
    }
    let flags = Flags::parse(&args[1..])?;
    let artifacts = std::path::PathBuf::from(flags.str_or("artifacts", "artifacts"));
    let out = std::path::PathBuf::from(flags.str_or("out", "results"));
    // Worker processes produce no result files; don't litter their CWD.
    // `bench` manages its own output tree (default bench-out, not
    // results) inside cmd_bench.
    if !matches!(
        cmd.as_str(),
        "shard-worker" | "--shard-worker" | "aggregator" | "bench" | "lint"
    ) {
        std::fs::create_dir_all(&out).ok();
    }

    match cmd.as_str() {
        "run" => cmd_run(&flags, &artifacts, &out)?,
        "serve" => cmd_serve(&flags, &artifacts, &out)?,
        "bench" => cmd_bench(&flags)?,
        "lint" => cmd_lint(&flags)?,
        "shard-worker" | "--shard-worker" => {
            let addr = flags
                .str_opt("connect")
                .ok_or_else(|| anyhow::anyhow!("shard-worker needs --connect HOST:PORT"))?;
            flags.reject_unknown()?;
            coordinator::join_shard(&addr)?;
        }
        "aggregator" => {
            let addr = flags
                .str_opt("connect")
                .ok_or_else(|| anyhow::anyhow!("aggregator needs --connect HOST:PORT"))?;
            let children: usize = flags.get_or("children", 1)?;
            flags.reject_unknown()?;
            if children == 0 {
                return Err(anyhow::anyhow!("aggregator needs --children >= 1"));
            }
            coordinator::join_aggregator(&addr, children)?;
        }
        "fig1" => {
            let a = harness::Fig1Args::from_flags(&flags)?;
            flags.reject_unknown()?;
            harness::fig1(&out, a)?;
        }
        "fig2" => {
            let a = harness::Fig2Args::from_flags(&flags)?;
            flags.reject_unknown()?;
            harness::fig2(&artifacts, &out, a)?;
        }
        "fig3" => {
            let a = harness::Fig3Args::from_flags(&flags)?;
            flags.reject_unknown()?;
            harness::fig3(&artifacts, &out, a)?;
        }
        "fig4" => {
            let a = harness::Fig4Args::from_flags(&flags)?;
            flags.reject_unknown()?;
            harness::fig4(&artifacts, &out, a)?;
        }
        "fig5" => {
            let a = harness::Fig5Args::from_flags(&flags)?;
            flags.reject_unknown()?;
            harness::fig5(&artifacts, &out, a)?;
        }
        "table1" => {
            let a = harness::Table1Args::from_flags(&flags)?;
            flags.reject_unknown()?;
            harness::table1(&artifacts, &out, a)?;
        }
        "appendix-c" | "appc" => {
            let a = harness::AppCArgs::from_flags(&flags)?;
            flags.reject_unknown()?;
            harness::appendix_c(&out, a)?;
        }
        "table2" => {
            let a = harness::Table2Args::from_flags(&flags)?;
            flags.reject_unknown()?;
            harness::table2(&artifacts, &out, a)?;
        }
        "help" | "--help" | "-h" => print!("{USAGE}"),
        other => {
            eprintln!("unknown command {other:?}\n\n{USAGE}");
            std::process::exit(2);
        }
    }
    Ok(())
}
