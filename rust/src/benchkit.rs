//! Micro-benchmark harness (the offline registry has no criterion).
//!
//! Measures wall-clock over warmup + timed iterations, reports
//! min/median/mean and derived throughput. Used by `rust/benches/*` via
//! `cargo bench` (harness = false targets).
//!
//! Two extras for CI / perf-trajectory tracking:
//! * [`smoke_mode`] — benches run a seconds-long subset when invoked as
//!   `cargo bench --bench <name> -- --test` (the CI smoke gate).
//! * [`Report`] — a dependency-free JSON sink; `benches/fl_round.rs`
//!   emits `BENCH_fl_round.json` so future PRs can diff rounds/sec,
//!   encode µs/client and allocation counts against this one.

use std::time::Duration;

use crate::supervise::{Clock, MonotonicClock};

/// Timing summary of one benchmark.
pub struct BenchResult {
    /// Benchmark label.
    pub name: String,
    /// Timed iterations measured.
    pub iters: usize,
    /// Fastest iteration.
    pub min: Duration,
    /// Median iteration.
    pub median: Duration,
    /// Mean iteration.
    pub mean: Duration,
}

impl BenchResult {
    /// Print one aligned result line.
    pub fn print(&self) {
        println!(
            "{:<44} {:>6} iters  min {:>12?}  median {:>12?}  mean {:>12?}",
            self.name, self.iters, self.min, self.median, self.mean
        );
    }

    /// Print with a throughput figure (bytes or elements per iteration).
    pub fn print_throughput(&self, units_per_iter: f64, unit: &str) {
        let per_sec = units_per_iter / self.median.as_secs_f64();
        println!(
            "{:<44} {:>6} iters  median {:>12?}  {:>10.2} {unit}/s",
            self.name, self.iters, self.median, per_sec
        );
    }
}

/// Run `f` for `warmup` untimed + `iters` timed iterations (wall time
/// from a fresh [`MonotonicClock`]; see [`bench_with`] to inject one).
pub fn bench<R>(name: &str, warmup: usize, iters: usize, f: impl FnMut() -> R) -> BenchResult {
    bench_with(&MonotonicClock::new(), name, warmup, iters, f)
}

/// [`bench`] against an explicit [`Clock`] — the timing reads go
/// through the supervise plane like every other clock consumer, so a
/// scripted clock can exercise the harness without wall time.
pub fn bench_with<R>(
    clock: &dyn Clock,
    name: &str,
    warmup: usize,
    iters: usize,
    mut f: impl FnMut() -> R,
) -> BenchResult {
    for _ in 0..warmup {
        std::hint::black_box(f());
    }
    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t0 = clock.now();
        std::hint::black_box(f());
        samples.push(clock.now().saturating_sub(t0));
    }
    samples.sort();
    let mean = samples.iter().sum::<Duration>() / iters as u32;
    BenchResult {
        name: name.to_string(),
        iters,
        min: samples[0],
        median: samples[iters / 2],
        mean,
    }
}

/// Auto-calibrating variant: picks an iteration count so the whole
/// measurement takes roughly `budget`.
pub fn bench_auto<R>(name: &str, budget: Duration, mut f: impl FnMut() -> R) -> BenchResult {
    let clock = MonotonicClock::new();
    let t0 = clock.now();
    std::hint::black_box(f());
    let one = clock
        .now()
        .saturating_sub(t0)
        .max(Duration::from_nanos(100));
    let iters = (budget.as_secs_f64() / one.as_secs_f64()).clamp(3.0, 10_000.0) as usize;
    bench_with(&clock, name, iters.min(10) / 3 + 1, iters, f)
}

/// True when the bench binary was invoked in smoke mode
/// (`cargo bench --bench <name> -- --test`): run a fast subset that only
/// checks the bench still executes, not its timings.
pub fn smoke_mode() -> bool {
    std::env::args().any(|a| a == "--test" || a == "--smoke")
}

/// Minimal JSON object writer for benchmark artifacts (flat string /
/// number / nested-object values; no external deps by design).
#[derive(Debug, Default)]
pub struct Report {
    fields: Vec<(String, String)>,
}

fn json_escape(s: &str) -> String {
    let mut out = String::with_capacity(s.len() + 2);
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out
}

impl Report {
    /// Empty report.
    pub fn new() -> Self {
        Self::default()
    }

    /// Append a string field.
    pub fn str(&mut self, key: &str, value: &str) -> &mut Self {
        self.fields
            .push((key.to_string(), format!("\"{}\"", json_escape(value))));
        self
    }

    /// Append a number field (non-finite values render as `null`).
    pub fn num(&mut self, key: &str, value: f64) -> &mut Self {
        let v = if value.is_finite() {
            format!("{value}")
        } else {
            "null".to_string()
        };
        self.fields.push((key.to_string(), v));
        self
    }

    /// Append an integer field.
    pub fn int(&mut self, key: &str, value: u64) -> &mut Self {
        self.fields.push((key.to_string(), value.to_string()));
        self
    }

    /// Append a boolean field.
    pub fn bool(&mut self, key: &str, value: bool) -> &mut Self {
        self.fields.push((key.to_string(), value.to_string()));
        self
    }

    /// Append an explicit `null` field (schema-nullable slots must stay
    /// present rather than being omitted).
    pub fn null(&mut self, key: &str) -> &mut Self {
        self.fields.push((key.to_string(), "null".to_string()));
        self
    }

    /// Append an array-of-numbers field (non-finite entries render as
    /// `null`, like [`Report::num`]).
    pub fn nums(&mut self, key: &str, values: &[f64]) -> &mut Self {
        let inner: Vec<String> = values
            .iter()
            .map(|v| {
                if v.is_finite() {
                    format!("{v}")
                } else {
                    "null".to_string()
                }
            })
            .collect();
        self.fields
            .push((key.to_string(), format!("[{}]", inner.join(", "))));
        self
    }

    /// Nest another report as an object value.
    pub fn obj(&mut self, key: &str, value: Report) -> &mut Self {
        self.fields.push((key.to_string(), value.render()));
        self
    }

    /// Render the report as one JSON object.
    pub fn render(&self) -> String {
        let inner: Vec<String> = self
            .fields
            .iter()
            .map(|(k, v)| format!("\"{}\": {v}", json_escape(k)))
            .collect();
        format!("{{{}}}", inner.join(", "))
    }

    /// Write the rendered JSON (newline-terminated) to `path`.
    pub fn write(&self, path: impl AsRef<std::path::Path>) -> std::io::Result<()> {
        std::fs::write(path, self.render() + "\n")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measures_something() {
        let r = bench("spin", 1, 5, || {
            let mut s = 0u64;
            for i in 0..1000 {
                s = s.wrapping_add(i);
            }
            s
        });
        assert_eq!(r.iters, 5);
        assert!(r.min <= r.median && r.median <= r.mean * 2);
    }

    #[test]
    fn report_renders_valid_flat_json() {
        let mut inner = Report::new();
        inner.num("rounds_per_sec", 12.5).int("clients", 8);
        let mut r = Report::new();
        r.str("bench", "fl_round\"x\"").num("nan", f64::NAN).obj("pool4", inner);
        let s = r.render();
        assert_eq!(
            s,
            "{\"bench\": \"fl_round\\\"x\\\"\", \"nan\": null, \
             \"pool4\": {\"rounds_per_sec\": 12.5, \"clients\": 8}}"
        );
    }
}
