//! Micro-benchmark harness (the offline registry has no criterion).
//!
//! Measures wall-clock over warmup + timed iterations, reports
//! min/median/mean and derived throughput. Used by `rust/benches/*` via
//! `cargo bench` (harness = false targets).

use std::time::{Duration, Instant};

pub struct BenchResult {
    pub name: String,
    pub iters: usize,
    pub min: Duration,
    pub median: Duration,
    pub mean: Duration,
}

impl BenchResult {
    pub fn print(&self) {
        println!(
            "{:<44} {:>6} iters  min {:>12?}  median {:>12?}  mean {:>12?}",
            self.name, self.iters, self.min, self.median, self.mean
        );
    }

    /// Print with a throughput figure (bytes or elements per iteration).
    pub fn print_throughput(&self, units_per_iter: f64, unit: &str) {
        let per_sec = units_per_iter / self.median.as_secs_f64();
        println!(
            "{:<44} {:>6} iters  median {:>12?}  {:>10.2} {unit}/s",
            self.name, self.iters, self.median, per_sec
        );
    }
}

/// Run `f` for `warmup` untimed + `iters` timed iterations.
pub fn bench<R>(name: &str, warmup: usize, iters: usize, mut f: impl FnMut() -> R) -> BenchResult {
    for _ in 0..warmup {
        std::hint::black_box(f());
    }
    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t0 = Instant::now();
        std::hint::black_box(f());
        samples.push(t0.elapsed());
    }
    samples.sort();
    let mean = samples.iter().sum::<Duration>() / iters as u32;
    BenchResult {
        name: name.to_string(),
        iters,
        min: samples[0],
        median: samples[iters / 2],
        mean,
    }
}

/// Auto-calibrating variant: picks an iteration count so the whole
/// measurement takes roughly `budget`.
pub fn bench_auto<R>(name: &str, budget: Duration, mut f: impl FnMut() -> R) -> BenchResult {
    let t0 = Instant::now();
    std::hint::black_box(f());
    let one = t0.elapsed().max(Duration::from_nanos(100));
    let iters = (budget.as_secs_f64() / one.as_secs_f64()).clamp(3.0, 10_000.0) as usize;
    bench(name, iters.min(10) / 3 + 1, iters, f)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measures_something() {
        let r = bench("spin", 1, 5, || {
            let mut s = 0u64;
            for i in 0..1000 {
                s = s.wrapping_add(i);
            }
            s
        });
        assert_eq!(r.iters, 5);
        assert!(r.min <= r.median && r.median <= r.mean * 2);
    }
}
