//! Message serialization for the shard wire protocol.
//!
//! One frame (see [`super::frame`]) carries one message. The first
//! payload byte is a tag; commands (coordinator → shard) and messages
//! (shard → coordinator) use disjoint tag ranges so a misrouted frame
//! is caught immediately:
//!
//! ```text
//! coordinator → shard            shard → coordinator
//! 0x01 INIT   handshake: cfg +   0x11 READY      manifest.tsv + init
//!             compute spec                       params (the model
//! 0x02 ROUND  (slot, client)*                    contract crosses the
//! 0x03 APPLY  broadcast Δ + eval                 wire, so the
//!             (dense f32 or the                  coordinator needs no
//!             downstream stream)                 artifacts of its own)
//! 0x04 STOP
//! 0x05 STATE  session plane:     0x12 ROUND_DONE lane frames: bitstreams
//!             install replica/                   + per-lane metrics
//!             client state and/  0x13 EVAL       EvalReport + ScaleStats
//!             or collect it      0x14 FAILED     rendered error chain
//! 0x06 HEART- liveness ping      0x15 STATE      collected client states
//!       BEAT  (nonce; supervisor 0x16 HEARTBEAT  echo of the ping's
//!             lease renewal)                     nonce
//! ```
//!
//! Integers are u64 LE, floats are IEEE-754 LE bit patterns (exact
//! round-trip), strings and byte blobs are length-prefixed. Every
//! decoder is total: truncated, oversized, or inconsistent payloads
//! return errors — never panic, never a partially-restored lane — and
//! trailing bytes are rejected (a length desync can't hide). Pinned by
//! the randomized corpus tests in `tests/integration_transport.rs`.

use std::sync::Arc;

use anyhow::{anyhow, Result};

use crate::compression::{CodecScratch, EncodeStats, QuantConfig, SparsifyMode, UpdateCodec};
use crate::data::TaskKind;
use crate::fl::config::{OnShardLoss, RoundPolicy, SessionConfig, TransportKind};
use crate::fl::schedule::ScheduleKind;
use crate::fl::server::EvalReport;
use crate::fl::{ClientState, ExperimentConfig, OptSnapshot, Protocol, RoundLane};
use crate::metrics::{MsgKind, ScaleStats};
use crate::model::params::{Delta, ParamSet};
use crate::model::Manifest;
use crate::runtime::Optimizer;

/// Wire-protocol revision; bumped on any incompatible layout change.
/// Carried in INIT and READY so mismatched binaries fail the handshake
/// with a clear error instead of a checksum/desync mystery.
/// v2: session plane (STATE pair, config session block, APPLY format
/// byte for the encode-once downstream stream).
/// v3: the config session block carries the snapshot-retention knob,
/// and the STATE install's `(shard, shards)` assignment became
/// load-bearing — elastic resizing installs a changed shard count that
/// workers now accept (previously forward-compat only).
/// v4: HEARTBEAT command/message pair (supervisor liveness leases), and
/// the config grew a trailing round-supervision policy block (heartbeat
/// cadence, round deadline, retry budget, backoff base, join timeout,
/// shard-loss mode).
/// v5: the config grew a trailing hierarchy block — `tree_children`
/// (mid-tier aggregator fan-out; 0 = flat fan-in) and
/// `resident_clients` (cold-state paging budget; 0 = fully resident).
pub const PROTOCOL_VERSION: u8 = 5;

const TAG_INIT: u8 = 0x01;
const TAG_ROUND: u8 = 0x02;
const TAG_APPLY: u8 = 0x03;
const TAG_STOP: u8 = 0x04;
const TAG_STATE: u8 = 0x05;
const TAG_HEARTBEAT: u8 = 0x06;
const TAG_READY: u8 = 0x11;
const TAG_ROUND_DONE: u8 = 0x12;
const TAG_EVAL: u8 = 0x13;
const TAG_FAILED: u8 = 0x14;
const TAG_STATE_MSG: u8 = 0x15;
const TAG_HEARTBEAT_MSG: u8 = 0x16;

/// Classify a frame payload by its leading tag byte, for per-kind byte
/// accounting at the frame layer. Command/report pairs of the same
/// concept (`STATE`/`STATE_MSG`, `HEARTBEAT`/`HEARTBEAT_MSG`) collapse
/// into one kind — direction disambiguates. Empty payloads and unknown
/// tags land in [`MsgKind::Other`].
pub fn kind_of(payload: &[u8]) -> MsgKind {
    match payload.first() {
        Some(&TAG_INIT) => MsgKind::Init,
        Some(&TAG_ROUND) => MsgKind::Round,
        Some(&TAG_APPLY) => MsgKind::Apply,
        Some(&TAG_STOP) => MsgKind::Stop,
        Some(&TAG_STATE) | Some(&TAG_STATE_MSG) => MsgKind::State,
        Some(&TAG_HEARTBEAT) | Some(&TAG_HEARTBEAT_MSG) => MsgKind::Heartbeat,
        Some(&TAG_READY) => MsgKind::Ready,
        Some(&TAG_ROUND_DONE) => MsgKind::RoundDone,
        Some(&TAG_EVAL) => MsgKind::Eval,
        Some(&TAG_FAILED) => MsgKind::Failed,
        _ => MsgKind::Other,
    }
}

/// APPLY payload carries the dense f32 broadcast delta.
const APPLY_FMT_DENSE: u8 = 0;
/// APPLY payload carries the downstream codec's bitstream (encoded once
/// per round by the server, fanned out as bytes to every shard).
const APPLY_FMT_STREAM: u8 = 1;

// ---------------------------------------------------------------------------
// primitives
// ---------------------------------------------------------------------------

pub(crate) fn put_u64(buf: &mut Vec<u8>, v: u64) {
    buf.extend_from_slice(&v.to_le_bytes());
}

pub(crate) fn put_usize(buf: &mut Vec<u8>, v: usize) {
    put_u64(buf, v as u64);
}

pub(crate) fn put_f32(buf: &mut Vec<u8>, v: f32) {
    buf.extend_from_slice(&v.to_le_bytes());
}

pub(crate) fn put_f64(buf: &mut Vec<u8>, v: f64) {
    buf.extend_from_slice(&v.to_le_bytes());
}

pub(crate) fn put_bool(buf: &mut Vec<u8>, v: bool) {
    buf.push(v as u8);
}

pub(crate) fn put_bytes(buf: &mut Vec<u8>, b: &[u8]) {
    put_usize(buf, b.len());
    buf.extend_from_slice(b);
}

pub(crate) fn put_str(buf: &mut Vec<u8>, s: &str) {
    put_bytes(buf, s.as_bytes());
}

/// Bounds-checked cursor over one message payload. Shared with the
/// session snapshot codec (`crate::session`), which speaks the same
/// primitive layout.
pub(crate) struct Rd<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Rd<'a> {
    pub(crate) fn new(buf: &'a [u8]) -> Self {
        Self { buf, pos: 0 }
    }

    pub(crate) fn remaining(&self) -> usize {
        self.buf.len() - self.pos
    }

    pub(crate) fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        if self.remaining() < n {
            return Err(anyhow!(
                "truncated message: wanted {n} bytes at offset {}, {} left",
                self.pos,
                self.remaining()
            ));
        }
        let s = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    pub(crate) fn u8(&mut self) -> Result<u8> {
        Ok(self.take(1)?[0])
    }

    pub(crate) fn u64(&mut self) -> Result<u64> {
        let b = self.take(8)?;
        Ok(u64::from_le_bytes([
            b[0], b[1], b[2], b[3], b[4], b[5], b[6], b[7],
        ]))
    }

    pub(crate) fn usize_(&mut self) -> Result<usize> {
        let v = self.u64()?;
        usize::try_from(v).map_err(|_| anyhow!("value {v} overflows usize"))
    }

    pub(crate) fn f32(&mut self) -> Result<f32> {
        let b = self.take(4)?;
        Ok(f32::from_le_bytes([b[0], b[1], b[2], b[3]]))
    }

    pub(crate) fn f64(&mut self) -> Result<f64> {
        let b = self.take(8)?;
        Ok(f64::from_le_bytes([
            b[0], b[1], b[2], b[3], b[4], b[5], b[6], b[7],
        ]))
    }

    pub(crate) fn bool_(&mut self) -> Result<bool> {
        match self.u8()? {
            0 => Ok(false),
            1 => Ok(true),
            other => Err(anyhow!("invalid bool byte {other:#04x}")),
        }
    }

    pub(crate) fn bytes(&mut self) -> Result<&'a [u8]> {
        let n = self.usize_()?;
        self.take(n)
    }

    pub(crate) fn str_(&mut self) -> Result<String> {
        let b = self.bytes()?;
        std::str::from_utf8(b)
            .map(|s| s.to_string())
            .map_err(|e| anyhow!("invalid utf-8 string on the wire: {e}"))
    }

    pub(crate) fn done(&self) -> Result<()> {
        if self.remaining() != 0 {
            return Err(anyhow!(
                "{} trailing bytes after message end (length desync)",
                self.remaining()
            ));
        }
        Ok(())
    }
}

fn expect_tag(rd: &mut Rd, want: u8, what: &str) -> Result<()> {
    let got = rd.u8()?;
    if got != want {
        return Err(anyhow!("expected {what} (tag {want:#04x}), got {got:#04x}"));
    }
    Ok(())
}

// ---------------------------------------------------------------------------
// f32 slabs (Delta / ParamSet payloads)
// ---------------------------------------------------------------------------

/// Append a delta's flat f32 values (element count + LE bit patterns,
/// manifest order). Both sides share the manifest, so tensor boundaries
/// are implied.
fn put_delta(buf: &mut Vec<u8>, d: &Delta) {
    put_usize(buf, d.numel());
    for t in &d.tensors {
        for &x in t {
            put_f32(buf, x);
        }
    }
}

/// Read a slab written by [`put_delta`] into `out` (shape from its
/// manifest; a size mismatch is an error before anything is written).
fn read_delta_into(rd: &mut Rd, out: &mut Delta) -> Result<()> {
    let n = rd.usize_()?;
    if n != out.numel() {
        return Err(anyhow!(
            "delta size mismatch: wire carries {n} values, manifest wants {}",
            out.numel()
        ));
    }
    let need = n
        .checked_mul(4)
        .ok_or_else(|| anyhow!("delta byte size overflows"))?;
    let bytes = rd.take(need)?;
    let mut off = 0usize;
    for t in out.tensors.iter_mut() {
        for x in t.iter_mut() {
            *x = f32::from_le_bytes([bytes[off], bytes[off + 1], bytes[off + 2], bytes[off + 3]]);
            off += 4;
        }
    }
    Ok(())
}

// ---------------------------------------------------------------------------
// ExperimentConfig
// ---------------------------------------------------------------------------

fn put_sparsify(buf: &mut Vec<u8>, m: SparsifyMode) {
    match m {
        SparsifyMode::None => buf.push(0),
        SparsifyMode::Dynamic { delta, gamma } => {
            buf.push(1);
            put_f32(buf, delta);
            put_f32(buf, gamma);
        }
        SparsifyMode::TopK { rate } => {
            buf.push(2);
            put_f32(buf, rate);
        }
    }
}

fn read_sparsify(rd: &mut Rd) -> Result<SparsifyMode> {
    Ok(match rd.u8()? {
        0 => SparsifyMode::None,
        1 => SparsifyMode::Dynamic {
            delta: rd.f32()?,
            gamma: rd.f32()?,
        },
        2 => SparsifyMode::TopK { rate: rd.f32()? },
        other => return Err(anyhow!("unknown sparsify tag {other}")),
    })
}

fn put_opt_f64(buf: &mut Vec<u8>, v: Option<f64>) {
    match v {
        None => buf.push(0),
        Some(x) => {
            buf.push(1);
            put_f64(buf, x);
        }
    }
}

fn read_opt_f64(rd: &mut Rd) -> Result<Option<f64>> {
    Ok(match rd.u8()? {
        0 => None,
        1 => Some(rd.f64()?),
        other => return Err(anyhow!("invalid option byte {other}")),
    })
}

/// Serialize an [`ExperimentConfig`] (appended to `buf`; field order is
/// fixed by this function and [`read_config`] alone).
fn put_config(buf: &mut Vec<u8>, cfg: &ExperimentConfig) {
    put_str(buf, &cfg.name);
    put_str(buf, &cfg.artifacts_root.to_string_lossy());
    put_str(buf, &cfg.variant);
    buf.push(match cfg.task {
        TaskKind::CifarLike => 0,
        TaskKind::VocLike => 1,
        TaskKind::XrayLike => 2,
    });
    buf.push(match cfg.protocol {
        Protocol::FedAvg => 0,
        Protocol::FedAvgQ => 1,
        Protocol::Stc => 2,
        Protocol::SparseOnly => 3,
        Protocol::StcScaled => 4,
        Protocol::Fsfl => 5,
    });
    put_sparsify(buf, cfg.sparsify);
    put_f32(buf, cfg.quant.coarse_step);
    put_f32(buf, cfg.quant.fine_step);
    put_usize(buf, cfg.clients);
    put_usize(buf, cfg.rounds);
    put_usize(buf, cfg.local_epochs);
    put_usize(buf, cfg.scale_epochs);
    put_bool(buf, matches!(cfg.optimizer, Optimizer::Sgd));
    put_f32(buf, cfg.lr);
    put_bool(buf, matches!(cfg.scale_optimizer, Optimizer::Sgd));
    put_f32(buf, cfg.scale_lr);
    buf.push(match cfg.schedule {
        ScheduleKind::Const => 0,
        ScheduleKind::Linear => 1,
        ScheduleKind::Cawr => 2,
    });
    put_bool(buf, cfg.bidirectional);
    put_opt_f64(buf, cfg.dirichlet_alpha);
    put_usize(buf, cfg.train_per_client);
    put_usize(buf, cfg.val_per_client);
    put_usize(buf, cfg.test_samples);
    put_u64(buf, cfg.seed);
    put_opt_f64(buf, cfg.target_accuracy);
    put_f64(buf, cfg.participation);
    match cfg.residuals_override {
        None => buf.push(0),
        Some(false) => buf.push(1),
        Some(true) => buf.push(2),
    }
    put_usize(buf, cfg.warmup_steps);
    put_usize(buf, cfg.codec_workers);
    put_bool(buf, cfg.pipelined);
    put_usize(buf, cfg.compute_shards);
    buf.push(match cfg.transport {
        TransportKind::Mpsc => 0,
        TransportKind::Loopback => 1,
        TransportKind::Tcp => 2,
    });
    match &cfg.session {
        None => put_bool(buf, false),
        Some(s) => {
            put_bool(buf, true);
            put_str(buf, &s.dir.to_string_lossy());
            put_usize(buf, s.every);
            put_usize(buf, s.retain);
            match s.crash_after {
                None => put_bool(buf, false),
                Some(k) => {
                    put_bool(buf, true);
                    put_usize(buf, k);
                }
            }
        }
    }
    // v4 round-supervision policy block. Durations travel as u64
    // nanoseconds (exact for anything a policy plausibly holds).
    put_u64(buf, cfg.policy.heartbeat.as_nanos() as u64);
    put_u64(buf, cfg.policy.round_deadline.as_nanos() as u64);
    put_usize(buf, cfg.policy.retry_budget);
    put_u64(buf, cfg.policy.backoff.as_nanos() as u64);
    put_u64(buf, cfg.policy.join_timeout.as_nanos() as u64);
    buf.push(match cfg.policy.on_loss {
        OnShardLoss::Abort => 0,
        OnShardLoss::Respawn => 1,
        OnShardLoss::Degrade => 2,
    });
    // v5 hierarchy block: aggregator fan-out + paging budget.
    put_usize(buf, cfg.tree_children);
    put_usize(buf, cfg.resident_clients);
}

fn read_config(rd: &mut Rd) -> Result<ExperimentConfig> {
    let name = rd.str_()?;
    let artifacts_root = std::path::PathBuf::from(rd.str_()?);
    let variant = rd.str_()?;
    let task = match rd.u8()? {
        0 => TaskKind::CifarLike,
        1 => TaskKind::VocLike,
        2 => TaskKind::XrayLike,
        other => return Err(anyhow!("unknown task tag {other}")),
    };
    let protocol = match rd.u8()? {
        0 => Protocol::FedAvg,
        1 => Protocol::FedAvgQ,
        2 => Protocol::Stc,
        3 => Protocol::SparseOnly,
        4 => Protocol::StcScaled,
        5 => Protocol::Fsfl,
        other => return Err(anyhow!("unknown protocol tag {other}")),
    };
    let sparsify = read_sparsify(rd)?;
    let quant = QuantConfig {
        coarse_step: rd.f32()?,
        fine_step: rd.f32()?,
    };
    let clients = rd.usize_()?;
    let rounds = rd.usize_()?;
    let local_epochs = rd.usize_()?;
    let scale_epochs = rd.usize_()?;
    let optimizer = if rd.bool_()? {
        Optimizer::Sgd
    } else {
        Optimizer::Adam
    };
    let lr = rd.f32()?;
    let scale_optimizer = if rd.bool_()? {
        Optimizer::Sgd
    } else {
        Optimizer::Adam
    };
    let scale_lr = rd.f32()?;
    let schedule = match rd.u8()? {
        0 => ScheduleKind::Const,
        1 => ScheduleKind::Linear,
        2 => ScheduleKind::Cawr,
        other => return Err(anyhow!("unknown schedule tag {other}")),
    };
    let bidirectional = rd.bool_()?;
    let dirichlet_alpha = read_opt_f64(rd)?;
    let train_per_client = rd.usize_()?;
    let val_per_client = rd.usize_()?;
    let test_samples = rd.usize_()?;
    let seed = rd.u64()?;
    let target_accuracy = read_opt_f64(rd)?;
    let participation = rd.f64()?;
    let residuals_override = match rd.u8()? {
        0 => None,
        1 => Some(false),
        2 => Some(true),
        other => return Err(anyhow!("invalid residuals-override byte {other}")),
    };
    let warmup_steps = rd.usize_()?;
    let codec_workers = rd.usize_()?;
    let pipelined = rd.bool_()?;
    let compute_shards = rd.usize_()?;
    let transport = match rd.u8()? {
        0 => TransportKind::Mpsc,
        1 => TransportKind::Loopback,
        2 => TransportKind::Tcp,
        other => return Err(anyhow!("unknown transport tag {other}")),
    };
    let session = if rd.bool_()? {
        let dir = std::path::PathBuf::from(rd.str_()?);
        let every = rd.usize_()?;
        let retain = rd.usize_()?;
        let crash_after = if rd.bool_()? {
            Some(rd.usize_()?)
        } else {
            None
        };
        Some(SessionConfig {
            dir,
            every,
            retain,
            crash_after,
        })
    } else {
        None
    };
    let policy = RoundPolicy {
        heartbeat: std::time::Duration::from_nanos(rd.u64()?),
        round_deadline: std::time::Duration::from_nanos(rd.u64()?),
        retry_budget: rd.usize_()?,
        backoff: std::time::Duration::from_nanos(rd.u64()?),
        join_timeout: std::time::Duration::from_nanos(rd.u64()?),
        on_loss: match rd.u8()? {
            0 => OnShardLoss::Abort,
            1 => OnShardLoss::Respawn,
            2 => OnShardLoss::Degrade,
            other => return Err(anyhow!("unknown shard-loss tag {other}")),
        },
    };
    let tree_children = rd.usize_()?;
    let resident_clients = rd.usize_()?;
    Ok(ExperimentConfig {
        name,
        artifacts_root,
        variant,
        task,
        protocol,
        sparsify,
        quant,
        clients,
        rounds,
        local_epochs,
        scale_epochs,
        optimizer,
        lr,
        scale_optimizer,
        scale_lr,
        schedule,
        bidirectional,
        dirichlet_alpha,
        train_per_client,
        val_per_client,
        test_samples,
        seed,
        target_accuracy,
        participation,
        residuals_override,
        warmup_steps,
        codec_workers,
        pipelined,
        compute_shards,
        transport,
        session,
        policy,
        tree_children,
        resident_clients,
    })
}

/// Serialize an [`ExperimentConfig`] into `buf` (cleared first). Exact
/// round-trip through [`decode_config`] — floats travel as bit
/// patterns, so a config crosses the process boundary without any
/// value drift.
pub fn encode_config(buf: &mut Vec<u8>, cfg: &ExperimentConfig) {
    buf.clear();
    put_config(buf, cfg);
}

/// Inverse of [`encode_config`].
pub fn decode_config(payload: &[u8]) -> Result<ExperimentConfig> {
    let mut rd = Rd::new(payload);
    let cfg = read_config(&mut rd)?;
    rd.done()?;
    Ok(cfg)
}

// ---------------------------------------------------------------------------
// commands (coordinator → shard)
// ---------------------------------------------------------------------------

/// What a joining shard should run its compute plane on.
#[derive(Clone)]
pub enum ComputeSpec {
    /// Real PJRT-backed clients built from the config's artifacts.
    Real,
    /// The deterministic [`crate::fl::SyntheticPlane`] over this model
    /// contract — no PJRT, no artifacts; what the transport conformance
    /// and multi-process CI tests run on.
    Synthetic {
        /// Model contract the synthetic deltas conform to.
        manifest: Arc<Manifest>,
    },
}

/// Decoded INIT handshake: everything a joining shard needs to build
/// its half of the experiment.
pub struct Init {
    /// This shard's index.
    pub shard: usize,
    /// Total shard count.
    pub shards: usize,
    /// The experiment to run (exact copy of the coordinator's config).
    pub cfg: ExperimentConfig,
    /// Which compute plane to build.
    pub compute: ComputeSpec,
}

/// Encode the INIT handshake into `buf` (cleared first).
pub fn encode_init(
    buf: &mut Vec<u8>,
    shard: usize,
    shards: usize,
    cfg: &ExperimentConfig,
    compute: &ComputeSpec,
) {
    buf.clear();
    buf.push(TAG_INIT);
    buf.push(PROTOCOL_VERSION);
    put_usize(buf, shard);
    put_usize(buf, shards);
    put_config(buf, cfg);
    match compute {
        ComputeSpec::Real => buf.push(0),
        ComputeSpec::Synthetic { manifest } => {
            buf.push(1);
            put_str(buf, &manifest.to_tsv());
        }
    }
}

/// Decode an INIT payload (version-checked).
pub fn decode_init(payload: &[u8]) -> Result<Init> {
    let mut rd = Rd::new(payload);
    expect_tag(&mut rd, TAG_INIT, "INIT")?;
    let version = rd.u8()?;
    if version != PROTOCOL_VERSION {
        return Err(anyhow!(
            "wire protocol version mismatch: coordinator speaks v{version}, this binary v{PROTOCOL_VERSION}"
        ));
    }
    let shard = rd.usize_()?;
    let shards = rd.usize_()?;
    if shards == 0 || shard >= shards {
        return Err(anyhow!("invalid shard assignment {shard}/{shards}"));
    }
    let cfg = read_config(&mut rd)?;
    let compute = match rd.u8()? {
        0 => ComputeSpec::Real,
        1 => {
            let tsv = rd.str_()?;
            let manifest = Manifest::parse(&tsv)?;
            manifest.validate()?;
            ComputeSpec::Synthetic {
                manifest: Arc::new(manifest),
            }
        }
        other => return Err(anyhow!("unknown compute-spec tag {other}")),
    };
    rd.done()?;
    Ok(Init {
        shard,
        shards,
        cfg,
        compute,
    })
}

/// Encode a ROUND command (this round's `(global slot, client id)`
/// assignments for one shard; possibly empty) into `buf`.
pub fn encode_round(buf: &mut Vec<u8>, slots: &[(usize, usize)]) {
    buf.clear();
    buf.push(TAG_ROUND);
    put_usize(buf, slots.len());
    for &(slot, client) in slots {
        put_usize(buf, slot);
        put_usize(buf, client);
    }
}

/// Decode a ROUND payload.
pub fn decode_round(payload: &[u8]) -> Result<Vec<(usize, usize)>> {
    let mut rd = Rd::new(payload);
    expect_tag(&mut rd, TAG_ROUND, "ROUND")?;
    let count = rd.usize_()?;
    if count > rd.remaining() / 16 {
        return Err(anyhow!(
            "implausible slot count {count} for {} remaining bytes",
            rd.remaining()
        ));
    }
    let mut slots = Vec::with_capacity(count);
    for _ in 0..count {
        let slot = rd.usize_()?;
        let client = rd.usize_()?;
        slots.push((slot, client));
    }
    rd.done()?;
    Ok(slots)
}

/// Encode an APPLY command (the aggregated broadcast delta + whether
/// this shard evaluates the central model afterwards) into `buf`. The
/// payload carries the dense f32 delta; bidirectional setups use
/// [`encode_apply_stream`] instead.
pub fn encode_apply(buf: &mut Vec<u8>, broadcast: &Delta, eval: bool) {
    buf.clear();
    buf.push(TAG_APPLY);
    put_bool(buf, eval);
    buf.push(APPLY_FMT_DENSE);
    put_delta(buf, broadcast);
}

/// Encode an APPLY command whose payload is the server's downstream
/// bitstream (bidirectional setups): the broadcast is encoded **once**
/// per round by `Server::aggregate_into` and these exact bytes fan out
/// to every shard, which decodes them back into the identical
/// dequantized delta.
pub fn encode_apply_stream(buf: &mut Vec<u8>, stream: &[u8], eval: bool) {
    buf.clear();
    buf.push(TAG_APPLY);
    put_bool(buf, eval);
    buf.push(APPLY_FMT_STREAM);
    put_bytes(buf, stream);
}

/// Decode an APPLY payload into a recycled broadcast buffer; returns
/// the eval flag. A stream-format payload is decoded with `downstream`
/// (the shard's copy of the server's broadcast codec) — receiving one
/// without a configured downstream codec is a protocol error.
pub fn decode_apply_into(
    payload: &[u8],
    broadcast: &mut Delta,
    downstream: Option<&UpdateCodec>,
    scratch: &mut CodecScratch,
) -> Result<bool> {
    let mut rd = Rd::new(payload);
    expect_tag(&mut rd, TAG_APPLY, "APPLY")?;
    let eval = rd.bool_()?;
    match rd.u8()? {
        APPLY_FMT_DENSE => read_delta_into(&mut rd, broadcast)?,
        APPLY_FMT_STREAM => {
            let codec = downstream.ok_or_else(|| {
                anyhow!("APPLY carries a downstream stream but no downstream codec is configured")
            })?;
            let stream = rd.bytes()?;
            codec.decode_into(stream, broadcast, scratch)?;
        }
        other => return Err(anyhow!("unknown APPLY format byte {other:#04x}")),
    }
    rd.done()?;
    Ok(eval)
}

/// Encode a STOP command into `buf`.
pub fn encode_stop(buf: &mut Vec<u8>) {
    buf.clear();
    buf.push(TAG_STOP);
}

// ---------------------------------------------------------------------------
// session plane: STATE command / message pair
// ---------------------------------------------------------------------------

/// Rehydration payload of a STATE command: re-assignment plus the
/// absolute replica parameters and the client states a shard must
/// install. Sent on resume (every shard) and on elastic membership
/// changes (the shards whose assignment or client set changed).
pub struct StateInstall {
    /// The receiving shard's index. A worker keeps its index across an
    /// elastic resize (cross-index reassignment stays rejected); only
    /// replacements re-join under the departed index.
    pub shard: usize,
    /// Total shard count under the (possibly resized) membership.
    /// Workers accept a changed count by rebuilding their client sets
    /// under the new round-robin assignment before importing the
    /// migrated states.
    pub shards: usize,
    /// Rounds already completed; local round counters fast-forward here.
    pub rounds_done: u64,
    /// Absolute server parameters — every local replica is set to an
    /// exact copy (bit-for-bit, which is what keeps resumed and
    /// uninterrupted runs byte-identical).
    pub params: ParamSet,
    /// Round-boundary states for the clients this shard now owns (empty
    /// on the synthetic plane, which carries no per-client state).
    pub clients: Vec<ClientState>,
}

/// One STATE command: install state and/or collect it. `collect`
/// requests a [`MsgTag::State`] response carrying every local client's
/// exported state (how checkpoints and migrations read a shard).
pub struct StateCmd {
    /// Respond with the shard's current client states.
    pub collect: bool,
    /// State to install before responding (if any).
    pub install: Option<StateInstall>,
}

fn put_slabs(buf: &mut Vec<u8>, slabs: &[Vec<f32>]) {
    put_usize(buf, slabs.len());
    for s in slabs {
        put_usize(buf, s.len());
        for &x in s {
            put_f32(buf, x);
        }
    }
}

fn read_slabs(rd: &mut Rd) -> Result<Vec<Vec<f32>>> {
    let count = rd.usize_()?;
    if count > rd.remaining() / 8 {
        return Err(anyhow!(
            "implausible slab count {count} for {} remaining bytes",
            rd.remaining()
        ));
    }
    // Capacity is capped: `count` is plausibility-checked above, but a
    // crafted frame could still claim millions of entries — grow on
    // demand instead of pre-allocating attacker-controlled capacity.
    let mut out = Vec::with_capacity(count.min(1 << 12));
    for _ in 0..count {
        let len = rd.usize_()?;
        let need = len
            .checked_mul(4)
            .ok_or_else(|| anyhow!("slab byte size overflows"))?;
        let bytes = rd.take(need)?;
        let mut slab = Vec::with_capacity(len);
        for c in bytes.chunks_exact(4) {
            slab.push(f32::from_le_bytes([c[0], c[1], c[2], c[3]]));
        }
        out.push(slab);
    }
    Ok(out)
}

fn put_opt_snapshot(buf: &mut Vec<u8>, o: &OptSnapshot) {
    put_slabs(buf, &o.m);
    put_slabs(buf, &o.v);
    put_f32(buf, o.t);
}

fn read_opt_snapshot(rd: &mut Rd) -> Result<OptSnapshot> {
    Ok(OptSnapshot {
        m: read_slabs(rd)?,
        v: read_slabs(rd)?,
        t: rd.f32()?,
    })
}

pub(crate) fn put_client_state(buf: &mut Vec<u8>, st: &ClientState) {
    put_usize(buf, st.id);
    put_u64(buf, st.rng);
    put_u64(buf, st.sched_global);
    put_u64(buf, st.sched_period);
    put_usize(buf, st.train_order.len());
    for &i in &st.train_order {
        put_u64(buf, i);
    }
    match &st.residual {
        None => put_bool(buf, false),
        Some(slabs) => {
            put_bool(buf, true);
            put_slabs(buf, slabs);
        }
    }
    put_opt_snapshot(buf, &st.wopt);
    put_opt_snapshot(buf, &st.sopt);
}

pub(crate) fn read_client_state(rd: &mut Rd) -> Result<ClientState> {
    let id = rd.usize_()?;
    let rng = rd.u64()?;
    let sched_global = rd.u64()?;
    let sched_period = rd.u64()?;
    let n = rd.usize_()?;
    if n > rd.remaining() / 8 {
        return Err(anyhow!(
            "implausible training-order length {n} for {} remaining bytes",
            rd.remaining()
        ));
    }
    let mut train_order = Vec::with_capacity(n);
    for _ in 0..n {
        train_order.push(rd.u64()?);
    }
    let residual = if rd.bool_()? {
        Some(read_slabs(rd)?)
    } else {
        None
    };
    let wopt = read_opt_snapshot(rd)?;
    let sopt = read_opt_snapshot(rd)?;
    Ok(ClientState {
        id,
        rng,
        sched_global,
        sched_period,
        train_order,
        residual,
        wopt,
        sopt,
    })
}

pub(crate) fn read_client_states(rd: &mut Rd) -> Result<Vec<ClientState>> {
    let count = rd.usize_()?;
    // Every client state needs at least its five fixed u64 fields.
    if count > rd.remaining() / 40 {
        return Err(anyhow!(
            "implausible client-state count {count} for {} remaining bytes",
            rd.remaining()
        ));
    }
    let mut out = Vec::with_capacity(count.min(1 << 12));
    for _ in 0..count {
        out.push(read_client_state(rd)?);
    }
    Ok(out)
}

/// Skip one slab block written by [`put_slabs`] without materializing
/// the f32 vectors (used by the metadata-only snapshot inspector).
fn skip_slabs(rd: &mut Rd) -> Result<()> {
    let count = rd.usize_()?;
    if count > rd.remaining() / 8 {
        return Err(anyhow!(
            "implausible slab count {count} for {} remaining bytes",
            rd.remaining()
        ));
    }
    for _ in 0..count {
        let len = rd.usize_()?;
        let need = len
            .checked_mul(4)
            .ok_or_else(|| anyhow!("slab byte size overflows"))?;
        rd.take(need)?;
    }
    Ok(())
}

/// Walk past a serialized client-state block, validating structure but
/// allocating nothing — the metadata half of [`read_client_states`].
/// Returns the client count.
pub(crate) fn skip_client_states(rd: &mut Rd) -> Result<usize> {
    let count = rd.usize_()?;
    if count > rd.remaining() / 40 {
        return Err(anyhow!(
            "implausible client-state count {count} for {} remaining bytes",
            rd.remaining()
        ));
    }
    for _ in 0..count {
        let _id = rd.usize_()?;
        let _rng = rd.u64()?;
        let _sched_global = rd.u64()?;
        let _sched_period = rd.u64()?;
        let n = rd.usize_()?;
        if n > rd.remaining() / 8 {
            return Err(anyhow!(
                "implausible training-order length {n} for {} remaining bytes",
                rd.remaining()
            ));
        }
        rd.take(n * 8)?;
        if rd.bool_()? {
            skip_slabs(rd)?;
        }
        for _ in 0..2 {
            // wopt then sopt: two slab blocks + the step counter each
            skip_slabs(rd)?;
            skip_slabs(rd)?;
            rd.f32()?;
        }
    }
    Ok(count)
}

/// Encode a STATE command into `buf`.
pub fn encode_state_cmd(buf: &mut Vec<u8>, cmd: &StateCmd) {
    buf.clear();
    buf.push(TAG_STATE);
    put_bool(buf, cmd.collect);
    match &cmd.install {
        None => put_bool(buf, false),
        Some(inst) => {
            put_bool(buf, true);
            put_usize(buf, inst.shard);
            put_usize(buf, inst.shards);
            put_u64(buf, inst.rounds_done);
            put_usize(buf, inst.params.numel());
            for t in &inst.params.tensors {
                for &x in t {
                    put_f32(buf, x);
                }
            }
            put_usize(buf, inst.clients.len());
            for c in &inst.clients {
                put_client_state(buf, c);
            }
        }
    }
}

/// Decode a STATE command; the install's parameter slab is shaped (and
/// size-checked) against `manifest` before anything is returned.
pub fn decode_state_cmd(payload: &[u8], manifest: &Arc<Manifest>) -> Result<StateCmd> {
    let mut rd = Rd::new(payload);
    expect_tag(&mut rd, TAG_STATE, "STATE")?;
    let collect = rd.bool_()?;
    let install = if rd.bool_()? {
        let shard = rd.usize_()?;
        let shards = rd.usize_()?;
        if shards == 0 || shard >= shards {
            return Err(anyhow!("invalid shard re-assignment {shard}/{shards}"));
        }
        let rounds_done = rd.u64()?;
        let numel = rd.usize_()?;
        let want: usize = manifest.tensors.iter().map(|t| t.numel()).sum();
        if numel != want {
            return Err(anyhow!(
                "state params size mismatch: wire carries {numel} values, manifest wants {want}"
            ));
        }
        let need = numel
            .checked_mul(4)
            .ok_or_else(|| anyhow!("param byte size overflows"))?;
        let bytes = rd.take(need)?;
        let mut chunks = bytes.chunks_exact(4);
        let mut tensors = Vec::with_capacity(manifest.tensors.len());
        for spec in &manifest.tensors {
            let mut t = Vec::with_capacity(spec.numel());
            for c in chunks.by_ref().take(spec.numel()) {
                t.push(f32::from_le_bytes([c[0], c[1], c[2], c[3]]));
            }
            tensors.push(t);
        }
        let params = ParamSet::new(manifest.clone(), tensors)?;
        let clients = read_client_states(&mut rd)?;
        Some(StateInstall {
            shard,
            shards,
            rounds_done,
            params,
            clients,
        })
    } else {
        None
    };
    rd.done()?;
    Ok(StateCmd { collect, install })
}

/// Encode a STATE message (a shard's collected client states) into
/// `buf`.
pub fn encode_state_msg(buf: &mut Vec<u8>, shard: usize, clients: &[ClientState]) {
    buf.clear();
    buf.push(TAG_STATE_MSG);
    put_usize(buf, shard);
    put_usize(buf, clients.len());
    for c in clients {
        put_client_state(buf, c);
    }
}

/// Decode a STATE message payload.
pub fn decode_state_msg(payload: &[u8]) -> Result<(usize, Vec<ClientState>)> {
    let mut rd = Rd::new(payload);
    expect_tag(&mut rd, TAG_STATE_MSG, "STATE message")?;
    let shard = rd.usize_()?;
    let clients = read_client_states(&mut rd)?;
    rd.done()?;
    Ok((shard, clients))
}

/// Encode a HEARTBEAT command (liveness ping) into `buf`. The nonce
/// identifies the ping; the shard echoes it back in its HEARTBEAT
/// message so the coordinator can renew the connection's lease.
pub fn encode_heartbeat_cmd(buf: &mut Vec<u8>, nonce: u64) {
    buf.clear();
    buf.push(TAG_HEARTBEAT);
    put_u64(buf, nonce);
}

/// Decode a HEARTBEAT command payload, returning the nonce to echo.
pub fn decode_heartbeat_cmd(payload: &[u8]) -> Result<u64> {
    let mut rd = Rd::new(payload);
    expect_tag(&mut rd, TAG_HEARTBEAT, "HEARTBEAT command")?;
    let nonce = rd.u64()?;
    rd.done()?;
    Ok(nonce)
}

/// Encode a HEARTBEAT message (the shard's echo of a ping) into `buf`.
pub fn encode_heartbeat_msg(buf: &mut Vec<u8>, shard: usize, nonce: u64) {
    buf.clear();
    buf.push(TAG_HEARTBEAT_MSG);
    put_usize(buf, shard);
    put_u64(buf, nonce);
}

/// Decode a HEARTBEAT message payload: `(shard, echoed nonce)`.
pub fn decode_heartbeat_msg(payload: &[u8]) -> Result<(usize, u64)> {
    let mut rd = Rd::new(payload);
    expect_tag(&mut rd, TAG_HEARTBEAT_MSG, "HEARTBEAT message")?;
    let shard = rd.usize_()?;
    let nonce = rd.u64()?;
    rd.done()?;
    Ok((shard, nonce))
}

/// Command-frame kinds (first payload byte), for dispatch before the
/// per-kind decoder runs.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum CmdTag {
    /// INIT handshake.
    Init,
    /// ROUND fan-out.
    Round,
    /// APPLY broadcast.
    Apply,
    /// Clean shutdown.
    Stop,
    /// Session-plane state install/collect.
    State,
    /// Liveness ping (supervisor lease renewal).
    Heartbeat,
}

/// Classify a command payload by tag.
pub fn cmd_tag(payload: &[u8]) -> Result<CmdTag> {
    match payload.first() {
        Some(&TAG_INIT) => Ok(CmdTag::Init),
        Some(&TAG_ROUND) => Ok(CmdTag::Round),
        Some(&TAG_APPLY) => Ok(CmdTag::Apply),
        Some(&TAG_STOP) => Ok(CmdTag::Stop),
        Some(&TAG_STATE) => Ok(CmdTag::State),
        Some(&TAG_HEARTBEAT) => Ok(CmdTag::Heartbeat),
        Some(&other) => Err(anyhow!("unknown command tag {other:#04x}")),
        None => Err(anyhow!("empty command frame")),
    }
}

// ---------------------------------------------------------------------------
// messages (shard → coordinator)
// ---------------------------------------------------------------------------

/// Encode a READY handshake into `buf`: shard index, the model contract
/// as `manifest.tsv` text, and the initial parameters — everything the
/// coordinator needs to build the server without artifacts or a runtime
/// of its own.
pub fn encode_ready(buf: &mut Vec<u8>, shard: usize, init: &ParamSet) {
    buf.clear();
    buf.push(TAG_READY);
    buf.push(PROTOCOL_VERSION);
    put_usize(buf, shard);
    put_str(buf, &init.manifest.to_tsv());
    put_usize(buf, init.numel());
    for t in &init.tensors {
        for &x in t {
            put_f32(buf, x);
        }
    }
}

/// Decode a READY payload; parses and validates the manifest, then
/// shapes the parameter slab against it.
pub fn decode_ready(payload: &[u8]) -> Result<(usize, ParamSet)> {
    let mut rd = Rd::new(payload);
    expect_tag(&mut rd, TAG_READY, "READY")?;
    let version = rd.u8()?;
    if version != PROTOCOL_VERSION {
        return Err(anyhow!(
            "wire protocol version mismatch: shard speaks v{version}, this binary v{PROTOCOL_VERSION}"
        ));
    }
    let shard = rd.usize_()?;
    let tsv = rd.str_()?;
    let manifest = Manifest::parse(&tsv)?;
    manifest.validate()?;
    let manifest = Arc::new(manifest);
    let numel = rd.usize_()?;
    let want: usize = manifest.tensors.iter().map(|t| t.numel()).sum();
    if numel != want {
        return Err(anyhow!(
            "init params size mismatch: wire carries {numel} values, manifest wants {want}"
        ));
    }
    let need = numel
        .checked_mul(4)
        .ok_or_else(|| anyhow!("param byte size overflows"))?;
    let bytes = rd.take(need)?;
    let mut off = 0usize;
    let mut tensors = Vec::with_capacity(manifest.tensors.len());
    for spec in &manifest.tensors {
        let mut t = vec![0.0f32; spec.numel()];
        for x in t.iter_mut() {
            *x = f32::from_le_bytes([bytes[off], bytes[off + 1], bytes[off + 2], bytes[off + 3]]);
            off += 4;
        }
        tensors.push(t);
    }
    rd.done()?;
    let init = ParamSet::new(manifest, tensors)?;
    Ok((shard, init))
}

/// Encode a ROUND_DONE message into `buf`: every finished lane's wire
/// image ([`RoundLane::wire_parts`]), tagged with its global round slot.
/// Errors only if a wall-clock value overflows the wire (u64 ms).
pub fn encode_round_done(
    buf: &mut Vec<u8>,
    shard: usize,
    lanes: &[(usize, RoundLane)],
) -> Result<()> {
    buf.clear();
    buf.push(TAG_ROUND_DONE);
    put_usize(buf, shard);
    put_usize(buf, lanes.len());
    for (slot, lane) in lanes {
        let p = lane.wire_parts();
        put_usize(buf, *slot);
        put_usize(buf, p.client);
        let mut flags = 0u8;
        if p.stream_w.is_some() {
            flags |= 1;
        }
        if p.stream_s.is_some() {
            flags |= 2;
        }
        if p.raw.is_some() {
            flags |= 4;
        }
        buf.push(flags);
        put_usize(buf, p.up_bytes);
        put_u64(
            buf,
            u64::try_from(p.train_ms).map_err(|_| anyhow!("train_ms overflows the wire"))?,
        );
        put_u64(
            buf,
            u64::try_from(p.scale_ms).map_err(|_| anyhow!("scale_ms overflows the wire"))?,
        );
        put_f64(buf, p.train_loss);
        put_bool(buf, p.scale_accepted);
        put_usize(buf, p.stats.bytes);
        put_usize(buf, p.stats.nonzero);
        put_usize(buf, p.stats.total);
        put_usize(buf, p.stats.rows_skipped);
        put_usize(buf, p.stats.rows_total);
        if let Some(w) = p.stream_w {
            put_bytes(buf, w);
        }
        if let Some(s) = p.stream_s {
            put_bytes(buf, s);
        }
        if let Some(raw) = p.raw {
            put_delta(buf, raw);
        }
    }
    Ok(())
}

/// Decode a ROUND_DONE payload into coordinator-side lanes (popped from
/// `free` when available, freshly allocated otherwise). For encoded
/// protocols this *decodes the transmitted bitstreams* — the server's
/// aggregation input is reconstructed from exactly the bytes that
/// crossed the transport. Any inconsistency (flag combinations, sizes,
/// malformed bitstreams) is an error; no partially-restored lane is
/// ever returned.
pub fn decode_round_done_into(
    payload: &[u8],
    manifest: &Arc<Manifest>,
    free: &mut Vec<RoundLane>,
) -> Result<(usize, Vec<(usize, RoundLane)>)> {
    let mut rd = Rd::new(payload);
    expect_tag(&mut rd, TAG_ROUND_DONE, "ROUND_DONE")?;
    let shard = rd.usize_()?;
    let count = rd.usize_()?;
    if count > rd.remaining() {
        return Err(anyhow!(
            "implausible lane count {count} for {} remaining bytes",
            rd.remaining()
        ));
    }
    let mut out: Vec<(usize, RoundLane)> = Vec::with_capacity(count);
    for _ in 0..count {
        let slot = rd.usize_()?;
        let client = rd.usize_()?;
        let flags = rd.u8()?;
        if flags & !0b111 != 0 {
            return Err(anyhow!("unknown lane flags {flags:#04x}"));
        }
        let (has_w, has_s, has_raw) = (flags & 1 != 0, flags & 2 != 0, flags & 4 != 0);
        if has_w == has_raw {
            return Err(anyhow!(
                "lane must carry exactly one of stream-W / raw update (flags {flags:#04x})"
            ));
        }
        if has_s && !has_w {
            return Err(anyhow!("S stream without a W stream (flags {flags:#04x})"));
        }
        let up_bytes = rd.usize_()?;
        let train_ms = rd.u64()? as u128;
        let scale_ms = rd.u64()? as u128;
        let train_loss = rd.f64()?;
        let scale_accepted = rd.bool_()?;
        let stats = EncodeStats {
            bytes: rd.usize_()?,
            nonzero: rd.usize_()?,
            total: rd.usize_()?,
            rows_skipped: rd.usize_()?,
            rows_total: rd.usize_()?,
        };
        let mut lane = free
            .pop()
            .unwrap_or_else(|| RoundLane::new(manifest.clone()));
        lane.stream_w.clear();
        lane.stream_s.clear();
        if has_w {
            let b = rd.bytes()?;
            lane.stream_w.extend_from_slice(b);
        }
        if has_s {
            let b = rd.bytes()?;
            lane.stream_s.extend_from_slice(b);
        }
        if has_raw {
            read_delta_into(&mut rd, &mut lane.decoded)?;
        }
        lane.restore_wire(
            client,
            has_w,
            has_s,
            up_bytes,
            train_ms,
            scale_ms,
            train_loss,
            scale_accepted,
            stats,
        )?;
        out.push((slot, lane));
    }
    rd.done()?;
    Ok((shard, out))
}

/// Encode an EVAL message (central-model report + per-layer scale
/// statistics) into `buf`.
pub fn encode_eval(buf: &mut Vec<u8>, report: &EvalReport, stats: &[ScaleStats]) {
    buf.clear();
    buf.push(TAG_EVAL);
    put_f64(buf, report.loss);
    put_f64(buf, report.accuracy);
    put_f64(buf, report.f1);
    put_usize(buf, stats.len());
    for s in stats {
        put_str(buf, &s.layer);
        put_f32(buf, s.min);
        put_f32(buf, s.q25);
        put_f32(buf, s.median);
        put_f32(buf, s.q75);
        put_f32(buf, s.max);
        put_f32(buf, s.mean);
        put_f32(buf, s.suppressed);
    }
}

/// Decode an EVAL payload.
pub fn decode_eval(payload: &[u8]) -> Result<(EvalReport, Vec<ScaleStats>)> {
    let mut rd = Rd::new(payload);
    expect_tag(&mut rd, TAG_EVAL, "EVAL")?;
    let report = EvalReport {
        loss: rd.f64()?,
        accuracy: rd.f64()?,
        f1: rd.f64()?,
    };
    let count = rd.usize_()?;
    if count > rd.remaining() {
        return Err(anyhow!(
            "implausible scale-stats count {count} for {} remaining bytes",
            rd.remaining()
        ));
    }
    let mut stats = Vec::with_capacity(count);
    for _ in 0..count {
        stats.push(ScaleStats {
            layer: rd.str_()?,
            min: rd.f32()?,
            q25: rd.f32()?,
            median: rd.f32()?,
            q75: rd.f32()?,
            max: rd.f32()?,
            mean: rd.f32()?,
            suppressed: rd.f32()?,
        });
    }
    rd.done()?;
    Ok((report, stats))
}

/// Encode a FAILED message (fatal shard error) into `buf`.
pub fn encode_failed(buf: &mut Vec<u8>, shard: usize, msg: &str) {
    buf.clear();
    buf.push(TAG_FAILED);
    put_usize(buf, shard);
    put_str(buf, msg);
}

/// Decode a FAILED payload.
pub fn decode_failed(payload: &[u8]) -> Result<(usize, String)> {
    let mut rd = Rd::new(payload);
    expect_tag(&mut rd, TAG_FAILED, "FAILED")?;
    let shard = rd.usize_()?;
    let msg = rd.str_()?;
    rd.done()?;
    Ok((shard, msg))
}

/// Message-frame kinds (first payload byte).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum MsgTag {
    /// READY handshake.
    Ready,
    /// ROUND_DONE lane delivery.
    RoundDone,
    /// EVAL report.
    Eval,
    /// FAILED fatal error.
    Failed,
    /// Collected session-plane client states.
    State,
    /// Liveness-ping echo (supervisor lease renewal).
    Heartbeat,
}

/// Classify a message payload by tag.
pub fn msg_tag(payload: &[u8]) -> Result<MsgTag> {
    match payload.first() {
        Some(&TAG_READY) => Ok(MsgTag::Ready),
        Some(&TAG_ROUND_DONE) => Ok(MsgTag::RoundDone),
        Some(&TAG_EVAL) => Ok(MsgTag::Eval),
        Some(&TAG_FAILED) => Ok(MsgTag::Failed),
        Some(&TAG_STATE_MSG) => Ok(MsgTag::State),
        Some(&TAG_HEARTBEAT_MSG) => Ok(MsgTag::Heartbeat),
        Some(&other) => Err(anyhow!("unknown message tag {other:#04x}")),
        None => Err(anyhow!("empty message frame")),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fl::ExperimentConfig;

    fn sample_cfg() -> ExperimentConfig {
        let mut cfg = ExperimentConfig::quick("tiny_cnn", TaskKind::XrayLike, Protocol::Stc);
        cfg.dirichlet_alpha = Some(0.37);
        cfg.target_accuracy = Some(0.91);
        cfg.residuals_override = Some(true);
        cfg.pipelined = true;
        cfg.compute_shards = 3;
        cfg.transport = TransportKind::Tcp;
        cfg.sparsify = SparsifyMode::TopK { rate: 0.96 };
        cfg.participation = 0.625;
        cfg.seed = u64::MAX - 7;
        cfg.session = Some(SessionConfig {
            dir: "ckpt/run-a".into(),
            every: 3,
            retain: 7,
            crash_after: Some(5),
        });
        cfg.policy = RoundPolicy {
            heartbeat: std::time::Duration::from_millis(250),
            round_deadline: std::time::Duration::from_secs(30),
            retry_budget: 4,
            backoff: std::time::Duration::from_micros(7500),
            join_timeout: std::time::Duration::from_secs(9),
            on_loss: OnShardLoss::Degrade,
        };
        cfg.tree_children = 2;
        cfg.resident_clients = 5;
        cfg
    }

    fn cfg_fingerprint(cfg: &ExperimentConfig) -> String {
        format!("{cfg:?}")
    }

    #[test]
    fn config_round_trips_exactly() {
        let cfg = sample_cfg();
        let mut buf = Vec::new();
        encode_config(&mut buf, &cfg);
        let back = decode_config(&buf).unwrap();
        assert_eq!(cfg_fingerprint(&cfg), cfg_fingerprint(&back));
    }

    #[test]
    fn init_round_trips_with_both_compute_specs() {
        let cfg = sample_cfg();
        let mut buf = Vec::new();
        encode_init(&mut buf, 2, 3, &cfg, &ComputeSpec::Real);
        assert_eq!(cmd_tag(&buf).unwrap(), CmdTag::Init);
        let init = decode_init(&buf).unwrap();
        assert_eq!((init.shard, init.shards), (2, 3));
        assert!(matches!(init.compute, ComputeSpec::Real));
        assert_eq!(cfg_fingerprint(&init.cfg), cfg_fingerprint(&cfg));

        let m = crate::model::params::tests_support::manifest_conv_dense();
        encode_init(&mut buf, 0, 1, &cfg, &ComputeSpec::Synthetic { manifest: m.clone() });
        let init = decode_init(&buf).unwrap();
        match init.compute {
            ComputeSpec::Synthetic { manifest } => assert_eq!(*manifest, *m),
            ComputeSpec::Real => panic!("lost the synthetic manifest"),
        }
    }

    #[test]
    fn init_rejects_bad_version_and_assignment() {
        let cfg = sample_cfg();
        let mut buf = Vec::new();
        encode_init(&mut buf, 0, 2, &cfg, &ComputeSpec::Real);
        buf[1] = PROTOCOL_VERSION + 1;
        assert!(format!("{}", decode_init(&buf).unwrap_err()).contains("version"));
        encode_init(&mut buf, 5, 2, &cfg, &ComputeSpec::Real);
        assert!(decode_init(&buf).is_err(), "shard ≥ shards must be rejected");
    }

    #[test]
    fn heartbeat_pair_round_trips() {
        let mut buf = Vec::new();
        encode_heartbeat_cmd(&mut buf, 0xDEAD_BEEF_0042);
        assert_eq!(cmd_tag(&buf).unwrap(), CmdTag::Heartbeat);
        assert_eq!(decode_heartbeat_cmd(&buf).unwrap(), 0xDEAD_BEEF_0042);
        // a trailing byte is a desync, not noise
        buf.push(0);
        assert!(decode_heartbeat_cmd(&buf).is_err());

        encode_heartbeat_msg(&mut buf, 3, 17);
        assert_eq!(msg_tag(&buf).unwrap(), MsgTag::Heartbeat);
        assert_eq!(decode_heartbeat_msg(&buf).unwrap(), (3, 17));
    }

    #[test]
    fn round_and_stop_round_trip() {
        let mut buf = Vec::new();
        let slots = vec![(0usize, 4usize), (3, 1), (5, 9)];
        encode_round(&mut buf, &slots);
        assert_eq!(cmd_tag(&buf).unwrap(), CmdTag::Round);
        assert_eq!(decode_round(&buf).unwrap(), slots);
        encode_round(&mut buf, &[]);
        assert!(decode_round(&buf).unwrap().is_empty());
        encode_stop(&mut buf);
        assert_eq!(cmd_tag(&buf).unwrap(), CmdTag::Stop);
    }

    #[test]
    fn apply_round_trips_through_a_recycled_buffer() {
        let m = crate::model::params::tests_support::manifest_conv_dense();
        let mut d = Delta::zeros(m.clone());
        d.tensors[0][4] = -0.25;
        d.tensors[1][1] = 1.5e-6;
        let mut buf = Vec::new();
        encode_apply(&mut buf, &d, true);
        assert_eq!(cmd_tag(&buf).unwrap(), CmdTag::Apply);
        let mut out = Delta::zeros(m);
        out.tensors[0][0] = 9.0; // stale garbage must be overwritten
        let mut scratch = CodecScratch::default();
        let eval = decode_apply_into(&buf, &mut out, None, &mut scratch).unwrap();
        assert!(eval);
        assert_eq!(out, d);
    }

    #[test]
    fn apply_stream_decodes_to_the_servers_dequantized_broadcast() {
        let m = crate::model::params::tests_support::manifest_conv_dense();
        let mut raw = Delta::zeros(m.clone());
        let mut rng = crate::data::XorShiftRng::new(40);
        for t in raw.tensors.iter_mut() {
            for x in t.iter_mut() {
                *x = rng.normal() * 2e-3;
            }
        }
        let codec = UpdateCodec::quant_only();
        let idx: Vec<usize> = (0..m.tensors.len()).collect();
        // What the server produces: the stream plus the dequantized deq.
        let (stream, deq, _) = codec.encode(raw, &idx);

        let mut buf = Vec::new();
        encode_apply_stream(&mut buf, &stream, false);
        assert_eq!(cmd_tag(&buf).unwrap(), CmdTag::Apply);
        let mut out = Delta::zeros(m);
        out.tensors[0][0] = 7.0; // stale garbage must be overwritten
        let mut scratch = CodecScratch::default();
        let eval = decode_apply_into(&buf, &mut out, Some(&codec), &mut scratch).unwrap();
        assert!(!eval);
        assert_eq!(out, deq, "decoded stream must equal the server broadcast");

        // A stream APPLY without a codec is a protocol error, not a
        // silent misread.
        let err = decode_apply_into(&buf, &mut out, None, &mut scratch).unwrap_err();
        assert!(format!("{err}").contains("downstream"));
    }

    fn sample_client_state(id: usize) -> ClientState {
        ClientState {
            id,
            rng: 0xDEAD_BEEF_0BAD_F00D,
            sched_global: 17,
            sched_period: 3,
            train_order: vec![4, 0, 2, 9, 1],
            residual: Some(vec![vec![0.25, -0.5, 1e-7], vec![]]),
            wopt: OptSnapshot {
                m: vec![vec![0.1, 0.2]],
                v: vec![vec![0.3, 0.4]],
                t: 12.0,
            },
            sopt: OptSnapshot {
                m: vec![vec![-1.0]],
                v: vec![vec![2.0]],
                t: 5.0,
            },
        }
    }

    #[test]
    fn state_cmd_and_msg_round_trip() {
        let m = crate::model::params::tests_support::manifest_conv_dense();
        let mut params = ParamSet::new(
            m.clone(),
            m.tensors.iter().map(|t| vec![0.0; t.numel()]).collect(),
        )
        .unwrap();
        params.tensors[0][2] = -3.5;
        params.tensors[1][3] = 1e-6;
        let cmd = StateCmd {
            collect: true,
            install: Some(StateInstall {
                shard: 1,
                shards: 3,
                rounds_done: 42,
                params: params.clone(),
                clients: vec![sample_client_state(4), sample_client_state(7)],
            }),
        };
        let mut buf = Vec::new();
        encode_state_cmd(&mut buf, &cmd);
        assert_eq!(cmd_tag(&buf).unwrap(), CmdTag::State);
        let back = decode_state_cmd(&buf, &m).unwrap();
        assert!(back.collect);
        let inst = back.install.expect("install lost");
        assert_eq!((inst.shard, inst.shards, inst.rounds_done), (1, 3, 42));
        assert_eq!(inst.params, params, "param bits must survive");
        assert_eq!(inst.clients.len(), 2);
        assert_eq!(inst.clients[0], sample_client_state(4));
        assert_eq!(inst.clients[1], sample_client_state(7));

        // collect-only command
        let cmd = StateCmd {
            collect: true,
            install: None,
        };
        encode_state_cmd(&mut buf, &cmd);
        let back = decode_state_cmd(&buf, &m).unwrap();
        assert!(back.collect && back.install.is_none());

        // message leg
        let states = vec![sample_client_state(0)];
        encode_state_msg(&mut buf, 2, &states);
        assert_eq!(msg_tag(&buf).unwrap(), MsgTag::State);
        let (shard, got) = decode_state_msg(&buf).unwrap();
        assert_eq!(shard, 2);
        assert_eq!(got, states);

        // truncations error, never panic
        for cut in 1..buf.len() {
            assert!(decode_state_msg(&buf[..cut]).is_err());
        }
    }

    #[test]
    fn eval_and_failed_round_trip() {
        let report = EvalReport {
            loss: 0.125,
            accuracy: 0.75,
            f1: 0.5,
        };
        let stats = vec![ScaleStats {
            layer: "conv1".into(),
            min: -1.0,
            q25: 0.1,
            median: 0.5,
            q75: 0.9,
            max: 2.0,
            mean: 0.55,
            suppressed: 0.125,
        }];
        let mut buf = Vec::new();
        encode_eval(&mut buf, &report, &stats);
        assert_eq!(msg_tag(&buf).unwrap(), MsgTag::Eval);
        let (r, s) = decode_eval(&buf).unwrap();
        assert_eq!(
            (r.loss, r.accuracy, r.f1),
            (report.loss, report.accuracy, report.f1)
        );
        assert_eq!(s.len(), 1);
        assert_eq!(s[0].layer, "conv1");
        assert_eq!(s[0].suppressed, 0.125);

        encode_failed(&mut buf, 7, "shard exploded: details");
        assert_eq!(msg_tag(&buf).unwrap(), MsgTag::Failed);
        assert_eq!(
            decode_failed(&buf).unwrap(),
            (7, "shard exploded: details".to_string())
        );
    }

    #[test]
    fn trailing_bytes_rejected() {
        let mut buf = Vec::new();
        encode_round(&mut buf, &[(1, 2)]);
        buf.push(0xAB);
        assert!(format!("{}", decode_round(&buf).unwrap_err()).contains("trailing"));
    }

    #[test]
    fn empty_and_unknown_tags_rejected() {
        assert!(cmd_tag(&[]).is_err());
        assert!(msg_tag(&[]).is_err());
        assert!(cmd_tag(&[0xEE]).is_err());
        assert!(msg_tag(&[0x01]).is_err(), "command tag is not a message tag");
    }
}
