//! Wire transport plane for sharded deployments.
//!
//! The sharded coordinator (see `coordinator`) historically moved
//! `RoundLane`s between shard threads over an in-process mpsc channel —
//! the "up to 377×" transfer-savings story never touched a real byte
//! boundary. This module is that boundary:
//!
//! * [`frame`] — length-prefix + FNV-checksum frame codec (the unit of
//!   transmission; corrupt/truncated/oversized frames error, never
//!   panic).
//! * [`wire`] — serialization of every coordinator⇄shard message
//!   (`ShardCmd`/`ShardMsg` images, lane bitstreams, the experiment
//!   config and model manifest for the process-join handshake).
//! * [`Transport`] — how framed bytes move. Two impls, zero new
//!   dependencies: [`LoopbackTransport`] (in-process byte pipes; the
//!   serialization path without a socket) and [`TcpTransport`]
//!   (`std::net` on localhost; shards may be other OS processes).
//!
//! Both impls stream through the *same* frame codec, so for a fixed
//! config they move byte-identical traffic and measure identical
//! [`crate::metrics::WireStats`] — transfer bytes are counted at the
//! frame layer as they cross, not estimated from bitstream lengths.

pub mod frame;
pub mod wire;

use std::io::{Read, Write};
use std::net::{TcpStream, ToSocketAddrs};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{mpsc, Arc};
use std::time::Duration;

use anyhow::{anyhow, Result};

use crate::metrics::MsgKind;
use crate::obs::Telemetry;
use crate::supervise::{Backoff, Clock};

/// Per-[`MsgKind`] frame byte counters shared between one transport
/// endpoint and the coordinator's accounting/telemetry planes. Bytes
/// include frame overhead (length prefix + checksum) and are attributed
/// from each payload's leading tag byte.
pub struct KindCounters {
    by_kind: [AtomicU64; MsgKind::COUNT],
}

impl KindCounters {
    /// Fresh all-zero counters.
    pub fn new() -> Self {
        Self {
            by_kind: std::array::from_fn(|_| AtomicU64::new(0)),
        }
    }

    /// Attribute `bytes` to `kind`.
    pub fn add(&self, kind: MsgKind, bytes: u64) {
        self.by_kind[kind.index()].fetch_add(bytes, Ordering::Relaxed);
    }

    /// Point-in-time copy of every kind's byte count, indexed by
    /// [`MsgKind::index`].
    pub fn snapshot(&self) -> [u64; MsgKind::COUNT] {
        std::array::from_fn(|i| self.by_kind[i].load(Ordering::Relaxed))
    }

    /// Sum over all kinds.
    pub fn total(&self) -> u64 {
        self.by_kind.iter().map(|c| c.load(Ordering::Relaxed)).sum()
    }
}

impl Default for KindCounters {
    fn default() -> Self {
        Self::new()
    }
}

/// Sending half of an opened transport: frames go out, bytes are
/// counted per message kind. `Send` so the coordinator can keep it
/// while the receiving half lives on a reader thread.
pub struct FrameSink {
    io: Box<dyn Write + Send>,
    sent: Arc<KindCounters>,
    obs: Option<Arc<Telemetry>>,
}

impl FrameSink {
    fn new(io: Box<dyn Write + Send>) -> Self {
        Self {
            io,
            sent: Arc::new(KindCounters::new()),
            obs: None,
        }
    }

    /// Attach a telemetry handle: subsequent sends record
    /// `net.send.<kind>` spans (bytes + latency). Counting is always
    /// on; spans are opt-in because only coordinator-side endpoints
    /// belong to the coordinator's trace.
    pub fn set_telemetry(&mut self, obs: Arc<Telemetry>) {
        self.obs = Some(obs);
    }

    /// Frame `payload`, write it out and flush (one message = one frame
    /// = one flush; commands are latency-bound, not throughput-bound).
    pub fn send(&mut self, payload: &[u8]) -> Result<()> {
        let kind = wire::kind_of(payload);
        let start = self.obs.as_ref().map(|t| t.now_ns());
        frame::write_frame(&mut self.io, payload)?;
        self.io
            .flush()
            .map_err(|e| anyhow!("frame flush failed: {e}"))?;
        let bytes = frame::frame_len(payload.len()) as u64;
        self.sent.add(kind, bytes);
        if let (Some(t), Some(t0)) = (&self.obs, start) {
            t.span(crate::obs::track::NET, crate::obs::net_send_name(kind), t0, -1, bytes as i64);
        }
        Ok(())
    }

    /// Shared handle to the per-kind bytes-sent counters (frame
    /// overhead included). Survives the sink moving to another thread.
    pub fn counter(&self) -> Arc<KindCounters> {
        self.sent.clone()
    }
}

/// Receiving half of an opened transport.
pub struct FrameSource {
    io: Box<dyn Read + Send>,
    received: Arc<KindCounters>,
    max_payload: usize,
    obs: Option<Arc<Telemetry>>,
}

impl FrameSource {
    fn new(io: Box<dyn Read + Send>) -> Self {
        // Peer-facing sources bound the unverified length field well
        // below the writer's absolute cap; see `frame::MAX_FRAME_LEN`.
        Self {
            io,
            received: Arc::new(KindCounters::new()),
            max_payload: frame::MAX_FRAME_LEN,
            obs: None,
        }
    }

    /// Attach a telemetry handle: subsequent receives record
    /// `net.recv.<kind>` spans (bytes + wait latency).
    pub fn set_telemetry(&mut self, obs: Arc<Telemetry>) {
        self.obs = Some(obs);
    }

    /// Read the next frame's payload into `buf`. `Ok(true)` on a frame,
    /// `Ok(false)` on a clean close between frames, `Err` on anything
    /// torn or corrupt (see [`frame::read_frame`]).
    pub fn recv(&mut self, buf: &mut Vec<u8>) -> Result<bool> {
        let start = self.obs.as_ref().map(|t| t.now_ns());
        let got = frame::read_frame(&mut self.io, buf, self.max_payload)?;
        if got {
            let kind = wire::kind_of(buf);
            let bytes = frame::frame_len(buf.len()) as u64;
            self.received.add(kind, bytes);
            if let (Some(t), Some(t0)) = (&self.obs, start) {
                t.span(
                    crate::obs::track::NET,
                    crate::obs::net_recv_name(kind),
                    t0,
                    -1,
                    bytes as i64,
                );
            }
        }
        Ok(got)
    }

    /// Shared handle to the per-kind bytes-received counters.
    pub fn counter(&self) -> Arc<KindCounters> {
        self.received.clone()
    }
}

/// One bidirectional shard connection, before it is split into its
/// framed halves. Implementations carry no protocol knowledge — they
/// move frames; `net::wire` gives the frames meaning.
pub trait Transport: Send {
    /// Split into (sink, source). Consumes the transport: after this the
    /// two halves may live on different threads.
    fn open(self: Box<Self>) -> Result<(FrameSink, FrameSource)>;

    /// Short human-readable kind tag (for errors and logs).
    fn kind(&self) -> &'static str;
}

// ---------------------------------------------------------------------------
// TCP
// ---------------------------------------------------------------------------

/// [`Transport`] over a `std::net::TcpStream`. The stream is duplicated
/// (`try_clone`) so the read and write halves can live on different
/// threads; writes are buffered per frame, `TCP_NODELAY` is set because
/// round commands are small and latency-bound.
pub struct TcpTransport {
    stream: TcpStream,
}

impl TcpTransport {
    /// Wrap an accepted/connected stream.
    pub fn new(stream: TcpStream) -> Self {
        Self { stream }
    }

    /// Connect to a listening coordinator (or shard) address.
    pub fn connect(addr: impl ToSocketAddrs + std::fmt::Debug) -> Result<Self> {
        let stream = TcpStream::connect(&addr)
            .map_err(|e| anyhow!("tcp connect to {addr:?} failed: {e}"))?;
        Ok(Self { stream })
    }

    /// Connect with bounded retry and exponential backoff: a worker
    /// racing the coordinator's listener keeps trying instead of dying
    /// at startup. Waits go through `clock` so tests never sleep on
    /// wall time; `backoff` supplies the (seeded, jittered) delays.
    pub fn connect_retry(
        addr: impl ToSocketAddrs + std::fmt::Debug,
        attempts: usize,
        backoff: &mut Backoff,
        clock: &dyn Clock,
    ) -> Result<Self> {
        let attempts = attempts.max(1);
        let mut last = String::new();
        for attempt in 0..attempts {
            match TcpStream::connect(&addr) {
                Ok(stream) => return Ok(Self { stream }),
                Err(e) => last = e.to_string(),
            }
            if attempt + 1 < attempts {
                clock.sleep(backoff.next_delay());
            }
        }
        Err(anyhow!(
            "tcp connect to {addr:?} failed after {attempts} attempts: {last}"
        ))
    }

    /// Arm a read deadline on the receiving half: once opened, a
    /// blocking `recv` that sees no bytes for `deadline` errors out
    /// instead of hanging forever — the transport-level backstop of
    /// the supervisor's liveness lease. `None` disarms (the default).
    pub fn set_read_deadline(&self, deadline: Option<Duration>) -> Result<()> {
        self.stream
            .set_read_timeout(deadline.filter(|d| !d.is_zero()))
            .map_err(|e| anyhow!("tcp read deadline failed to arm: {e}"))
    }
}

impl Transport for TcpTransport {
    fn open(self: Box<Self>) -> Result<(FrameSink, FrameSource)> {
        // Best-effort: NODELAY failing is not worth killing the link.
        let _ = self.stream.set_nodelay(true);
        let read_half = self
            .stream
            .try_clone()
            .map_err(|e| anyhow!("tcp stream clone failed: {e}"))?;
        Ok((
            FrameSink::new(Box::new(std::io::BufWriter::new(self.stream))),
            FrameSource::new(Box::new(std::io::BufReader::new(read_half))),
        ))
    }

    fn kind(&self) -> &'static str {
        "tcp"
    }
}

// ---------------------------------------------------------------------------
// Loopback
// ---------------------------------------------------------------------------

/// Write half of an in-process byte pipe: every `write` ships its bytes
/// as one chunk over an mpsc channel. A dropped [`PipeReader`] surfaces
/// as a broken-pipe error, mirroring a closed socket.
struct PipeWriter {
    tx: mpsc::Sender<Vec<u8>>,
}

impl Write for PipeWriter {
    fn write(&mut self, buf: &[u8]) -> std::io::Result<usize> {
        self.tx.send(buf.to_vec()).map_err(|_| {
            std::io::Error::new(std::io::ErrorKind::BrokenPipe, "loopback peer closed")
        })?;
        Ok(buf.len())
    }

    fn flush(&mut self) -> std::io::Result<()> {
        Ok(())
    }
}

/// Read half of an in-process byte pipe. Chunk boundaries are invisible
/// to callers (a partial chunk is buffered), so the frame codec sees
/// the same byte-stream semantics a socket gives it. A dropped
/// [`PipeWriter`] reads as clean EOF, mirroring a closed socket.
struct PipeReader {
    rx: mpsc::Receiver<Vec<u8>>,
    pending: Vec<u8>,
    pos: usize,
}

impl Read for PipeReader {
    fn read(&mut self, buf: &mut [u8]) -> std::io::Result<usize> {
        if self.pos >= self.pending.len() {
            match self.rx.recv() {
                Ok(chunk) => {
                    self.pending = chunk;
                    self.pos = 0;
                }
                Err(_) => return Ok(0), // all writers dropped: EOF
            }
        }
        let n = buf.len().min(self.pending.len() - self.pos);
        buf[..n].copy_from_slice(&self.pending[self.pos..self.pos + n]);
        self.pos += n;
        Ok(n)
    }
}

/// In-process [`Transport`]: a pair of byte pipes speaking the full
/// frame protocol without a socket. Use [`loopback_pair`] to create the
/// two connected endpoints.
pub struct LoopbackTransport {
    tx: mpsc::Sender<Vec<u8>>,
    rx: mpsc::Receiver<Vec<u8>>,
}

/// Two connected [`LoopbackTransport`] endpoints: what one sends the
/// other receives, byte for byte, through the same frame codec the TCP
/// transport uses.
pub fn loopback_pair() -> (LoopbackTransport, LoopbackTransport) {
    let (a_tx, b_rx) = mpsc::channel();
    let (b_tx, a_rx) = mpsc::channel();
    (
        LoopbackTransport { tx: a_tx, rx: a_rx },
        LoopbackTransport { tx: b_tx, rx: b_rx },
    )
}

impl Transport for LoopbackTransport {
    fn open(self: Box<Self>) -> Result<(FrameSink, FrameSource)> {
        Ok((
            FrameSink::new(Box::new(PipeWriter { tx: self.tx })),
            FrameSource::new(Box::new(PipeReader {
                rx: self.rx,
                pending: Vec::new(),
                pos: 0,
            })),
        ))
    }

    fn kind(&self) -> &'static str {
        "loopback"
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn loopback_moves_frames_and_counts_bytes() {
        let (a, b) = loopback_pair();
        let (mut a_tx, mut a_rx) = Box::new(a).open().unwrap();
        let (mut b_tx, mut b_rx) = Box::new(b).open().unwrap();
        a_tx.send(b"ping").unwrap();
        b_tx.send(b"pong!").unwrap();
        let mut buf = Vec::new();
        assert!(b_rx.recv(&mut buf).unwrap());
        assert_eq!(buf, b"ping");
        assert!(a_rx.recv(&mut buf).unwrap());
        assert_eq!(buf, b"pong!");
        assert_eq!(a_tx.counter().total(), frame::frame_len(4) as u64);
        assert_eq!(b_rx.counter().total(), frame::frame_len(4) as u64);
        // Leading byte 'p' is no protocol tag: attributed to Other.
        let snap = a_tx.counter().snapshot();
        assert_eq!(snap[MsgKind::Other.index()], frame::frame_len(4) as u64);
        assert_eq!(snap[MsgKind::Round.index()], 0);
    }

    #[test]
    fn loopback_dropped_peer_is_clean_eof_or_broken_pipe() {
        let (a, b) = loopback_pair();
        let (mut a_tx, _a_rx) = Box::new(a).open().unwrap();
        let (b_tx, mut b_rx) = Box::new(b).open().unwrap();
        a_tx.send(b"last").unwrap();
        drop(a_tx);
        let mut buf = Vec::new();
        assert!(b_rx.recv(&mut buf).unwrap());
        // writer gone: clean EOF between frames
        assert!(!b_rx.recv(&mut buf).unwrap());
        // and writing toward a dropped reader errors
        drop(b_rx);
        let mut b_tx = b_tx;
        drop(_a_rx);
        assert!(b_tx.send(b"into the void").is_err());
    }

    #[test]
    fn connect_retry_succeeds_against_a_live_listener() {
        let listener = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let clock = crate::supervise::ScriptedClock::new(Duration::from_millis(1));
        let mut backoff = Backoff::new(Duration::from_millis(10), Duration::from_secs(1), 1);
        let t = TcpTransport::connect_retry(addr, 5, &mut backoff, &clock).unwrap();
        assert_eq!(t.kind(), "tcp");
        // first attempt connected: no backoff sleeps were taken
        assert!(clock.slept().is_empty());
    }

    #[test]
    fn connect_retry_gives_up_after_its_budget() {
        // Bind then drop to obtain a port that refuses connections.
        let addr = {
            let l = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
            l.local_addr().unwrap()
        };
        let clock = crate::supervise::ScriptedClock::new(Duration::from_millis(1));
        let mut backoff = Backoff::new(Duration::from_millis(10), Duration::from_secs(1), 2);
        let err = TcpTransport::connect_retry(addr, 3, &mut backoff, &clock).unwrap_err();
        assert!(format!("{err}").contains("after 3 attempts"), "got: {err}");
        // two inter-attempt waits, all on the scripted clock
        assert_eq!(clock.slept().len(), 2);
    }

    #[test]
    fn tcp_transport_round_trips_on_localhost() {
        let listener = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let join = std::thread::spawn(move || {
            let (stream, _) = listener.accept().unwrap();
            let (mut tx, mut rx) = Box::new(TcpTransport::new(stream)).open().unwrap();
            let mut buf = Vec::new();
            assert!(rx.recv(&mut buf).unwrap());
            tx.send(&buf).unwrap(); // echo
            buf
        });
        let (mut tx, mut rx) = Box::new(TcpTransport::connect(addr).unwrap()).open().unwrap();
        tx.send(b"over the wire").unwrap();
        let mut buf = Vec::new();
        assert!(rx.recv(&mut buf).unwrap());
        assert_eq!(buf, b"over the wire");
        assert_eq!(join.join().unwrap(), b"over the wire");
    }
}
