//! Length-prefixed, checksummed frame codec — the lowest layer of the
//! shard wire protocol.
//!
//! Every message crosses the transport as one frame:
//!
//! ```text
//! offset  size  field
//! 0       4     magic  b"FSNT"
//! 4       4     payload length, u32 LE
//! 8       8     FNV-1a 64 of the payload, u64 LE
//! 16      len   payload (one `net::wire` message)
//! ```
//!
//! The reader is defensive by construction: a wrong magic, an oversized
//! length, a truncated header/payload or a checksum mismatch all return
//! errors (never panic, never a partial frame in `buf`), and a clean
//! close *between* frames is distinguished from a close *inside* one —
//! the coordinator uses that distinction to tell "shard finished" from
//! "shard died mid-round". Pinned by the fault-injection property tests
//! in `tests/integration_transport.rs`.

use std::io::{Read, Write};

use anyhow::{anyhow, Result};

/// Frame preamble; rejects cross-protocol traffic immediately.
pub const MAGIC: [u8; 4] = *b"FSNT";

/// Fixed frame header size (magic + length + checksum).
pub const HEADER_LEN: usize = 16;

/// Absolute payload-size cap enforced by the writer. Generous (a
/// broadcast delta for a large model is tens of MB) but finite, so a
/// corrupted length field can never drive an unbounded allocation.
pub const MAX_PAYLOAD: usize = 1 << 30;

/// Default *read-side* payload cap for frames arriving from a peer.
/// The 4-byte length field is trusted before the checksum can be
/// verified, so readers facing a network peer bound it well below the
/// writer's [`MAX_PAYLOAD`]: 256 MiB comfortably covers the largest
/// legitimate message while keeping the damage of a corrupted or
/// hostile header small. Trusted local readers (e.g. snapshot files)
/// may still pass [`MAX_PAYLOAD`].
pub const MAX_FRAME_LEN: usize = 1 << 28;

/// Payload bytes allocated per step while reading a frame body. The
/// buffer grows only as bytes actually arrive, so a corrupt length
/// claiming `max_payload` bytes costs at most one chunk of memory
/// before the truncation is detected.
const READ_CHUNK: usize = 4 << 20;

/// FNV-1a 64 over a byte slice (same constants as `Delta::checksum`).
pub fn fnv1a(bytes: &[u8]) -> u64 {
    const OFFSET: u64 = 0xcbf29ce484222325;
    const PRIME: u64 = 0x100000001b3;
    let mut h = OFFSET;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(PRIME);
    }
    h
}

/// Total on-wire size of a frame with a `payload_len`-byte payload.
pub fn frame_len(payload_len: usize) -> usize {
    HEADER_LEN + payload_len
}

/// Write one frame. The caller flushes (batching several frames per
/// syscall is the transport's choice, not the codec's).
pub fn write_frame(w: &mut impl Write, payload: &[u8]) -> Result<()> {
    if payload.len() > MAX_PAYLOAD {
        return Err(anyhow!(
            "frame payload {} bytes exceeds cap {MAX_PAYLOAD}",
            payload.len()
        ));
    }
    let mut header = [0u8; HEADER_LEN];
    header[..4].copy_from_slice(&MAGIC);
    header[4..8].copy_from_slice(&(payload.len() as u32).to_le_bytes());
    header[8..16].copy_from_slice(&fnv1a(payload).to_le_bytes());
    w.write_all(&header)
        .and_then(|_| w.write_all(payload))
        .map_err(|e| anyhow!("frame write failed: {e}"))
}

/// Read until `dst` is full, reporting how the stream ended if it ends
/// early. `already` is how many bytes of the larger unit were consumed
/// before this call (for the error message's benefit).
fn read_full(r: &mut impl Read, dst: &mut [u8], what: &str, already: usize) -> Result<usize> {
    let mut got = 0usize;
    while got < dst.len() {
        match r.read(&mut dst[got..]) {
            Ok(0) => return Ok(got),
            Ok(n) => got += n,
            Err(e) if e.kind() == std::io::ErrorKind::Interrupted => continue,
            Err(e) => {
                return Err(anyhow!(
                    "frame read failed in {what} after {} bytes: {e}",
                    already + got
                ))
            }
        }
    }
    Ok(got)
}

/// Read one frame's payload into `buf` (cleared and overwritten).
///
/// Returns `Ok(true)` on a valid frame, `Ok(false)` on a clean close at
/// a frame boundary (zero bytes read), and an error for everything
/// else: truncated header/payload, bad magic, length above
/// `max_payload`, or checksum mismatch. On error `buf` contents are
/// unspecified but never observed as a valid message (callers bail).
pub fn read_frame(r: &mut impl Read, buf: &mut Vec<u8>, max_payload: usize) -> Result<bool> {
    let mut header = [0u8; HEADER_LEN];
    let got = read_full(r, &mut header, "header", 0)?;
    if got == 0 {
        return Ok(false);
    }
    if got < HEADER_LEN {
        return Err(anyhow!("connection closed mid-frame ({got} header bytes)"));
    }
    if header[..4] != MAGIC {
        return Err(anyhow!(
            "bad frame magic {:02x?} (protocol mismatch or stream desync)",
            &header[..4]
        ));
    }
    let len = u32::from_le_bytes([header[4], header[5], header[6], header[7]]) as usize;
    if len > max_payload {
        return Err(anyhow!("oversized frame: {len} bytes > cap {max_payload}"));
    }
    let want = u64::from_le_bytes([
        header[8], header[9], header[10], header[11], header[12], header[13], header[14],
        header[15],
    ]);
    buf.clear();
    // Grow the buffer chunkwise as payload bytes actually arrive: the
    // length field is unverified until the checksum passes, so a
    // corrupt header must never be able to demand `len` bytes of
    // memory up front.
    while buf.len() < len {
        let start = buf.len();
        let step = (len - start).min(READ_CHUNK);
        buf.resize(start + step, 0);
        let got = read_full(r, &mut buf[start..], "payload", HEADER_LEN + start)?;
        if got < step {
            return Err(anyhow!(
                "connection closed mid-frame ({} of {len} payload bytes)",
                start + got
            ));
        }
    }
    let have = fnv1a(buf);
    if have != want {
        return Err(anyhow!(
            "frame checksum mismatch: header says {want:#018x}, payload hashes to {have:#018x}"
        ));
    }
    Ok(true)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn frame_bytes(payload: &[u8]) -> Vec<u8> {
        let mut out = Vec::new();
        write_frame(&mut out, payload).unwrap();
        out
    }

    #[test]
    fn round_trip() {
        let payload = b"hello shard".to_vec();
        let wire = frame_bytes(&payload);
        assert_eq!(wire.len(), frame_len(payload.len()));
        let mut r = wire.as_slice();
        let mut buf = Vec::new();
        assert!(read_frame(&mut r, &mut buf, MAX_PAYLOAD).unwrap());
        assert_eq!(buf, payload);
        // stream exhausted: clean EOF at the frame boundary
        assert!(!read_frame(&mut r, &mut buf, MAX_PAYLOAD).unwrap());
    }

    #[test]
    fn empty_payload_round_trips() {
        let wire = frame_bytes(&[]);
        let mut r = wire.as_slice();
        let mut buf = vec![9u8; 4]; // stale contents must be cleared
        assert!(read_frame(&mut r, &mut buf, MAX_PAYLOAD).unwrap());
        assert!(buf.is_empty());
    }

    #[test]
    fn truncation_is_an_error_not_a_partial_frame() {
        let wire = frame_bytes(b"0123456789");
        let mut buf = Vec::new();
        for cut in 1..wire.len() {
            let mut r = &wire[..cut];
            let err = read_frame(&mut r, &mut buf, MAX_PAYLOAD).unwrap_err();
            assert!(
                format!("{err}").contains("mid-frame"),
                "cut at {cut}: unexpected error {err}"
            );
        }
    }

    #[test]
    fn bit_flips_are_detected() {
        let wire = frame_bytes(b"sensitive bits");
        let mut buf = Vec::new();
        for i in 0..wire.len() {
            let mut bad = wire.clone();
            bad[i] ^= 0x40;
            let mut r = bad.as_slice();
            // Every single-bit corruption must surface as *some* error
            // (magic, length/truncation, or checksum) — never a clean
            // frame with wrong bytes.
            assert!(
                read_frame(&mut r, &mut buf, MAX_PAYLOAD).is_err(),
                "flip at byte {i} went undetected"
            );
        }
    }

    #[test]
    fn oversized_length_rejected_before_allocation() {
        let mut wire = frame_bytes(b"x");
        wire[4..8].copy_from_slice(&u32::MAX.to_le_bytes());
        let mut r = wire.as_slice();
        let err = read_frame(&mut r, &mut Vec::new(), MAX_PAYLOAD).unwrap_err();
        assert!(format!("{err}").contains("oversized"));
        // and a caller-tightened cap applies too
        let wire = frame_bytes(&vec![0u8; 64]);
        let mut r = wire.as_slice();
        assert!(read_frame(&mut r, &mut Vec::new(), 16).is_err());
    }

    #[test]
    fn read_side_cap_rejects_what_the_writer_would_allow() {
        // A length legal under MAX_PAYLOAD but above the peer-facing
        // MAX_FRAME_LEN is still refused before any payload read.
        let mut wire = frame_bytes(b"x");
        let claimed = (MAX_FRAME_LEN + 1) as u32;
        wire[4..8].copy_from_slice(&claimed.to_le_bytes());
        let mut r = wire.as_slice();
        let err = read_frame(&mut r, &mut Vec::new(), MAX_FRAME_LEN).unwrap_err();
        assert!(format!("{err}").contains("oversized"));
    }

    #[test]
    fn corrupt_length_cannot_force_a_large_allocation() {
        // Header claims 64 MiB (under the cap) but the stream ends
        // right after the header: the reader must fail on truncation
        // having grown the buffer by at most one chunk, not reserve
        // the full claimed length up front.
        let mut wire = frame_bytes(b"x")[..HEADER_LEN].to_vec();
        let claimed = (64u32) << 20;
        wire[4..8].copy_from_slice(&claimed.to_le_bytes());
        let mut r = wire.as_slice();
        let mut buf = Vec::new();
        let err = read_frame(&mut r, &mut buf, MAX_FRAME_LEN).unwrap_err();
        assert!(format!("{err}").contains("mid-frame"), "got: {err}");
        assert!(
            buf.capacity() <= 8 << 20,
            "buffer ballooned to {} bytes on a corrupt length",
            buf.capacity()
        );
    }

    #[test]
    fn multi_chunk_payload_round_trips() {
        // A payload larger than one read chunk exercises the chunked
        // growth path end to end.
        let payload: Vec<u8> = (0..(READ_CHUNK + READ_CHUNK / 2 + 3))
            .map(|i| (i * 31 + 7) as u8)
            .collect();
        let wire = frame_bytes(&payload);
        let mut r = wire.as_slice();
        let mut buf = Vec::new();
        assert!(read_frame(&mut r, &mut buf, MAX_PAYLOAD).unwrap());
        assert_eq!(buf, payload);
    }

    #[test]
    fn bad_magic_rejected() {
        let mut wire = frame_bytes(b"payload");
        wire[0] = b'X';
        let mut r = wire.as_slice();
        let err = read_frame(&mut r, &mut Vec::new(), MAX_PAYLOAD).unwrap_err();
        assert!(format!("{err}").contains("magic"));
    }
}
