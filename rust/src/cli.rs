//! Minimal flag parser (the offline registry has no clap).
//!
//! Supports `--key value`, `--key=value` and boolean `--flag` arguments,
//! with typed getters and an unknown-flag check.

use std::collections::BTreeMap;

use anyhow::{anyhow, Result};

/// Parsed command-line flags with typed getters.
#[derive(Debug, Default, Clone)]
pub struct Flags {
    values: BTreeMap<String, String>,
    /// Flags the command actually read (for unknown-flag diagnostics).
    known: std::cell::RefCell<std::collections::BTreeSet<String>>,
}

impl Flags {
    /// Parse `args` (without the program/subcommand names). Boolean flags
    /// are stored as "true".
    pub fn parse(args: &[String]) -> Result<Self> {
        let mut values = BTreeMap::new();
        let mut i = 0;
        while i < args.len() {
            let a = &args[i];
            let Some(stripped) = a.strip_prefix("--") else {
                return Err(anyhow!("unexpected positional argument {a:?}"));
            };
            if let Some((k, v)) = stripped.split_once('=') {
                values.insert(k.to_string(), v.to_string());
            } else if i + 1 < args.len() && !args[i + 1].starts_with("--") {
                values.insert(stripped.to_string(), args[i + 1].clone());
                i += 1;
            } else {
                values.insert(stripped.to_string(), "true".to_string());
            }
            i += 1;
        }
        Ok(Self {
            values,
            known: Default::default(),
        })
    }

    fn mark(&self, key: &str) {
        self.known.borrow_mut().insert(key.to_string());
    }

    /// String flag value, if present.
    pub fn str_opt(&self, key: &str) -> Option<String> {
        self.mark(key);
        self.values.get(key).cloned()
    }

    /// String flag value with a default.
    pub fn str_or(&self, key: &str, default: &str) -> String {
        self.str_opt(key).unwrap_or_else(|| default.to_string())
    }

    /// Parsed flag value, if present.
    pub fn get<T: std::str::FromStr>(&self, key: &str) -> Result<Option<T>>
    where
        T::Err: std::fmt::Display,
    {
        self.mark(key);
        match self.values.get(key) {
            None => Ok(None),
            Some(v) => v
                .parse::<T>()
                .map(Some)
                .map_err(|e| anyhow!("--{key} {v:?}: {e}")),
        }
    }

    /// Parsed flag value with a default.
    pub fn get_or<T: std::str::FromStr>(&self, key: &str, default: T) -> Result<T>
    where
        T::Err: std::fmt::Display,
    {
        Ok(self.get(key)?.unwrap_or(default))
    }

    /// Boolean flag (`--flag` or `--flag 1`).
    pub fn flag(&self, key: &str) -> bool {
        self.mark(key);
        matches!(self.values.get(key).map(|s| s.as_str()), Some("true") | Some("1"))
    }

    /// Comma-separated list.
    pub fn list<T: std::str::FromStr>(&self, key: &str) -> Result<Option<Vec<T>>>
    where
        T::Err: std::fmt::Display,
    {
        self.mark(key);
        match self.values.get(key) {
            None => Ok(None),
            Some(v) => v
                .split(',')
                .filter(|s| !s.is_empty())
                .map(|s| s.parse::<T>().map_err(|e| anyhow!("--{key} {s:?}: {e}")))
                .collect::<Result<Vec<T>>>()
                .map(Some),
        }
    }

    /// Comma-separated `a:b` pair list (e.g. `--elastic-resize 2:3,4:1`
    /// → `[(2, 3), (4, 1)]`). Empty segments are skipped; a segment
    /// without exactly one `:` is an error.
    pub fn pairs(&self, key: &str) -> Result<Option<Vec<(usize, usize)>>> {
        self.mark(key);
        match self.values.get(key) {
            None => Ok(None),
            Some(v) => v
                .split(',')
                .filter(|s| !s.is_empty())
                .map(|s| {
                    let (a, b) = s
                        .split_once(':')
                        .ok_or_else(|| anyhow!("--{key} {s:?}: expected ROUND:VALUE"))?;
                    Ok((
                        a.parse::<usize>().map_err(|e| anyhow!("--{key} {a:?}: {e}"))?,
                        b.parse::<usize>().map_err(|e| anyhow!("--{key} {b:?}: {e}"))?,
                    ))
                })
                .collect::<Result<Vec<_>>>()
                .map(Some),
        }
    }

    /// Every flag name actually provided on the command line (for
    /// commands that must reject contradictory combinations, e.g.
    /// `run --resume` with experiment-shape flags).
    pub fn keys(&self) -> Vec<String> {
        self.values.keys().cloned().collect()
    }

    /// Error out on flags no getter ever consulted (catches typos).
    pub fn reject_unknown(&self) -> Result<()> {
        let known = self.known.borrow();
        for k in self.values.keys() {
            if !known.contains(k) {
                return Err(anyhow!("unknown flag --{k}"));
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn args(s: &[&str]) -> Vec<String> {
        s.iter().map(|x| x.to_string()).collect()
    }

    #[test]
    fn parses_kv_and_bools() {
        let f = Flags::parse(&args(&["--a", "1", "--b=x", "--c", "--d", "2.5"])).unwrap();
        assert_eq!(f.get_or::<i64>("a", 0).unwrap(), 1);
        assert_eq!(f.str_or("b", ""), "x");
        assert!(f.flag("c"));
        assert_eq!(f.get_or::<f64>("d", 0.0).unwrap(), 2.5);
        f.reject_unknown().unwrap();
    }

    #[test]
    fn unknown_flag_rejected() {
        let f = Flags::parse(&args(&["--known", "1", "--typo", "2"])).unwrap();
        let _ = f.get_or::<i64>("known", 0).unwrap();
        assert!(f.reject_unknown().is_err());
    }

    #[test]
    fn lists() {
        let f = Flags::parse(&args(&["--clients", "2,4,8"])).unwrap();
        assert_eq!(f.list::<usize>("clients").unwrap().unwrap(), vec![2, 4, 8]);
    }

    #[test]
    fn pairs_parse_round_colon_value_lists() {
        let f = Flags::parse(&args(&["--elastic-resize", "2:3,4:1"])).unwrap();
        assert_eq!(
            f.pairs("elastic-resize").unwrap().unwrap(),
            vec![(2, 3), (4, 1)]
        );
        assert!(f.pairs("absent").unwrap().is_none());
        let f = Flags::parse(&args(&["--elastic-resize", "2-3"])).unwrap();
        assert!(f.pairs("elastic-resize").is_err(), "missing colon accepted");
        let f = Flags::parse(&args(&["--elastic-resize", "a:3"])).unwrap();
        assert!(f.pairs("elastic-resize").is_err(), "non-numeric accepted");
    }

    #[test]
    fn positional_rejected() {
        assert!(Flags::parse(&args(&["oops"])).is_err());
    }

    #[test]
    fn trailing_bool() {
        let f = Flags::parse(&args(&["--x", "--y"])).unwrap();
        assert!(f.flag("x") && f.flag("y"));
    }
}
