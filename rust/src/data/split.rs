//! Client data splits: random non-overlapping partitions (paper Sec. 5.1)
//! and a Dirichlet non-IID option (paper App. C shows rising non-IID-ness
//! with random partitioning; Dirichlet makes the degree controllable).

use super::rng::XorShiftRng;
use super::synthetic::Dataset;

/// Per-client index lists into a [`Dataset`].
#[derive(Debug, Clone)]
pub struct ClientSplit {
    /// Training indices, one list per client.
    pub train: Vec<Vec<usize>>,
    /// Validation indices, one list per client.
    pub val: Vec<Vec<usize>>,
}

/// Random non-overlapping IID-ish split into `clients` parts, each part
/// further divided into train/val by `val_frac` (the paper evaluates
/// scale factors on per-client validation splits).
pub fn iid_split(ds: &Dataset, clients: usize, val_frac: f64, seed: u64) -> ClientSplit {
    let mut idx: Vec<usize> = (0..ds.len()).collect();
    let mut rng = XorShiftRng::new(seed);
    rng.shuffle(&mut idx);
    let per = ds.len() / clients;
    let mut train = Vec::with_capacity(clients);
    let mut val = Vec::with_capacity(clients);
    for c in 0..clients {
        let part = &idx[c * per..(c + 1) * per];
        let nval = ((part.len() as f64) * val_frac).round() as usize;
        val.push(part[..nval].to_vec());
        train.push(part[nval..].to_vec());
    }
    ClientSplit { train, val }
}

/// Label-Dirichlet non-IID split: each client draws a Dirichlet(alpha)
/// class distribution; low alpha → highly skewed clients.
pub fn dirichlet_split(
    ds: &Dataset,
    clients: usize,
    alpha: f64,
    val_frac: f64,
    seed: u64,
) -> ClientSplit {
    let mut rng = XorShiftRng::new(seed);
    // bucket sample indices per class
    let mut buckets: Vec<Vec<usize>> = vec![Vec::new(); ds.classes];
    for (i, s) in ds.samples.iter().enumerate() {
        buckets[s.label].push(i);
    }
    for b in buckets.iter_mut() {
        rng.shuffle(b);
    }
    let mut parts: Vec<Vec<usize>> = vec![Vec::new(); clients];
    for bucket in &buckets {
        let p = rng.dirichlet(alpha, clients);
        // cumulative allocation of this class's samples
        let mut start = 0usize;
        for (c, &frac) in p.iter().enumerate() {
            let n = if c + 1 == clients {
                bucket.len() - start
            } else {
                ((bucket.len() as f64) * frac).round() as usize
            }
            .min(bucket.len() - start);
            parts[c].extend_from_slice(&bucket[start..start + n]);
            start += n;
        }
    }
    let mut train = Vec::with_capacity(clients);
    let mut val = Vec::with_capacity(clients);
    for mut part in parts {
        rng.shuffle(&mut part);
        let nval = ((part.len() as f64) * val_frac).round() as usize;
        val.push(part[..nval].to_vec());
        train.push(part[nval..].to_vec());
    }
    ClientSplit { train, val }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::{TaskKind, TaskSpec};

    fn ds() -> Dataset {
        Dataset::generate(&TaskSpec::new(TaskKind::CifarLike, 8, 1, 1), 400, 0)
    }

    #[test]
    fn iid_split_disjoint_and_covering() {
        let d = ds();
        let s = iid_split(&d, 4, 0.2, 7);
        let mut all: Vec<usize> = Vec::new();
        for c in 0..4 {
            all.extend(&s.train[c]);
            all.extend(&s.val[c]);
            assert!((s.val[c].len() as f64 / 100.0 - 0.2).abs() < 0.02);
        }
        let n = all.len();
        all.sort_unstable();
        all.dedup();
        assert_eq!(all.len(), n, "overlapping client splits");
        assert_eq!(n, 400);
    }

    #[test]
    fn dirichlet_low_alpha_is_skewed() {
        let d = ds();
        let skewed = dirichlet_split(&d, 4, 0.1, 0.0, 3);
        let uniform = dirichlet_split(&d, 4, 100.0, 0.0, 3);
        // measure max class fraction per client, averaged
        let skew = |sp: &ClientSplit| -> f64 {
            let mut total = 0.0;
            for part in &sp.train {
                let mut counts = vec![0usize; d.classes];
                for &i in part {
                    counts[d.samples[i].label] += 1;
                }
                let max = *counts.iter().max().unwrap() as f64;
                total += max / part.len().max(1) as f64;
            }
            total / sp.train.len() as f64
        };
        assert!(skew(&skewed) > skew(&uniform) + 0.1);
    }

    #[test]
    fn dirichlet_split_disjoint() {
        let d = ds();
        let s = dirichlet_split(&d, 3, 0.5, 0.25, 11);
        let mut all: Vec<usize> = Vec::new();
        for c in 0..3 {
            all.extend(&s.train[c]);
            all.extend(&s.val[c]);
        }
        let n = all.len();
        all.sort_unstable();
        all.dedup();
        assert_eq!(all.len(), n);
        assert_eq!(n, 400);
    }
}
