//! Deterministic xorshift64* RNG — no external dependency, identical
//! streams across platforms, so every experiment is exactly repeatable.

/// A seeded xorshift64* pseudo-random generator.
#[derive(Debug, Clone)]
pub struct XorShiftRng {
    state: u64,
}

impl XorShiftRng {
    /// Seeded generator (any seed, including 0, is valid).
    pub fn new(seed: u64) -> Self {
        Self {
            state: seed.wrapping_mul(0x9E37_79B9_7F4A_7C15) | 1,
        }
    }

    /// The raw generator state, for session snapshots. Feeding it back
    /// through [`XorShiftRng::from_state`] resumes the exact stream.
    pub fn state(&self) -> u64 {
        self.state
    }

    /// Rebuild a generator from a state captured by [`XorShiftRng::state`]
    /// (not a seed — seeds go through [`XorShiftRng::new`]). Captured
    /// states restore exactly; only the xorshift fixed point 0 (which
    /// [`XorShiftRng::state`] can never report) is nudged off zero.
    pub fn from_state(state: u64) -> Self {
        Self {
            state: if state == 0 { 1 } else { state },
        }
    }

    /// Next raw 64-bit draw.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let mut x = self.state;
        x ^= x >> 12;
        x ^= x << 25;
        x ^= x >> 27;
        self.state = x;
        x.wrapping_mul(0x2545_F491_4F6C_DD1D)
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn next_f32(&mut self) -> f32 {
        (self.next_u64() >> 40) as f32 / (1u64 << 24) as f32
    }

    /// Uniform integer in [0, n) — exactly uniform, via Lemire's
    /// multiply-shift rejection sampling (`next_u64() % n` has modulo
    /// bias: values below `2^64 mod n` appear one extra time per 2^64
    /// draws, which skews Fisher–Yates shuffles and therefore
    /// participant selection). Returns 0 for `n == 0`.
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        if n == 0 {
            return 0;
        }
        let n = n as u64;
        let mut m = (self.next_u64() as u128) * (n as u128);
        let mut lo = m as u64;
        if lo < n {
            // Reject the draws that would over-represent low residues:
            // `t = 2^64 mod n` is the count of unusable low products.
            let t = n.wrapping_neg() % n;
            while lo < t {
                m = (self.next_u64() as u128) * (n as u128);
                lo = m as u64;
            }
        }
        (m >> 64) as usize
    }

    /// Standard normal via Box-Muller.
    pub fn normal(&mut self) -> f32 {
        let u1 = (self.next_f32() + 1e-7).min(1.0);
        let u2 = self.next_f32();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f32::consts::PI * u2).cos()
    }

    /// Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, v: &mut [T]) {
        for i in (1..v.len()).rev() {
            let j = self.below(i + 1);
            v.swap(i, j);
        }
    }

    /// Sample from a Dirichlet(alpha * ones(k)) via Gamma(alpha) draws
    /// (Marsaglia-Tsang for alpha >= 1; boost trick below 1).
    pub fn dirichlet(&mut self, alpha: f64, k: usize) -> Vec<f64> {
        let mut g: Vec<f64> = (0..k).map(|_| self.gamma(alpha)).collect();
        let s: f64 = g.iter().sum();
        if s <= 0.0 {
            return vec![1.0 / k as f64; k];
        }
        g.iter_mut().for_each(|x| *x /= s);
        g
    }

    fn gamma(&mut self, alpha: f64) -> f64 {
        if alpha < 1.0 {
            let u = self.next_f32() as f64;
            return self.gamma(alpha + 1.0) * u.max(1e-12).powf(1.0 / alpha);
        }
        let d = alpha - 1.0 / 3.0;
        let c = 1.0 / (9.0 * d).sqrt();
        loop {
            let x = self.normal() as f64;
            let v = (1.0 + c * x).powi(3);
            if v <= 0.0 {
                continue;
            }
            let u = self.next_f32() as f64;
            if u < 1.0 - 0.0331 * x.powi(4)
                || u.max(1e-12).ln() < 0.5 * x * x + d * (1.0 - v + v.ln())
            {
                return d * v;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = XorShiftRng::new(42);
        let mut b = XorShiftRng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn state_round_trips_exactly() {
        let mut a = XorShiftRng::new(123);
        for _ in 0..17 {
            a.next_u64();
        }
        let snap = a.state();
        let mut b = XorShiftRng::from_state(snap);
        for _ in 0..50 {
            assert_eq!(a.next_u64(), b.next_u64(), "restored stream diverged");
        }
        // the 0 fixed point (never produced by `state()`) is nudged
        assert_ne!(XorShiftRng::from_state(0).next_u64(), 0);
    }

    #[test]
    fn uniform_mean() {
        let mut r = XorShiftRng::new(7);
        let n = 100_000;
        let mean: f32 = (0..n).map(|_| r.next_f32()).sum::<f32>() / n as f32;
        assert!((mean - 0.5).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn normal_moments() {
        let mut r = XorShiftRng::new(9);
        let n = 100_000;
        let xs: Vec<f32> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f32>() / n as f32;
        let var = xs.iter().map(|x| (x - mean).powi(2)).sum::<f32>() / n as f32;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.05, "var={var}");
    }

    #[test]
    fn dirichlet_sums_to_one() {
        let mut r = XorShiftRng::new(3);
        for &a in &[0.3, 1.0, 5.0] {
            let p = r.dirichlet(a, 10);
            assert!((p.iter().sum::<f64>() - 1.0).abs() < 1e-9);
            assert!(p.iter().all(|&x| x >= 0.0));
        }
    }

    #[test]
    fn below_is_uniform_and_in_range() {
        // Regression for the `next_u64() % n` modulo bias: rejection
        // sampling must keep every residue within a tight tolerance of
        // the expected count (gross bias — e.g. an off-by-one in the
        // rejection threshold folding two residues together — trips
        // this immediately), stay in range for awkward moduli, and
        // remain seed-deterministic.
        for &n in &[2usize, 3, 6, 7, 10, 1000] {
            let mut r = XorShiftRng::new(0xB1A5 + n as u64);
            let draws = 60_000 * n.min(10);
            let mut counts = vec![0usize; n];
            for _ in 0..draws {
                let v = r.below(n);
                assert!(v < n, "below({n}) produced {v}");
                counts[v] += 1;
            }
            let expect = draws as f64 / n as f64;
            // 6σ of a binomial bucket — loose enough to never flake on
            // a fixed seed, tight enough to catch any systematic bias.
            let bound = 6.0 * expect.sqrt();
            for (v, &c) in counts.iter().enumerate() {
                let dev = (c as f64 - expect).abs();
                assert!(
                    dev < bound,
                    "below({n}): residue {v} count {c} deviates {dev:.1} from {expect} (bound {bound:.1})"
                );
            }
        }
        // huge moduli exercise the high-word path (m >> 64)
        let mut r = XorShiftRng::new(17);
        for _ in 0..1000 {
            let v = r.below(usize::MAX);
            let _ = v; // in range by type; must not hang or panic
        }
        // deterministic across identically-seeded generators
        let mut a = XorShiftRng::new(99);
        let mut b = XorShiftRng::new(99);
        for _ in 0..500 {
            assert_eq!(a.below(37), b.below(37));
        }
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = XorShiftRng::new(5);
        let mut v: Vec<usize> = (0..100).collect();
        r.shuffle(&mut v);
        let mut s = v.clone();
        s.sort_unstable();
        assert_eq!(s, (0..100).collect::<Vec<_>>());
        assert_ne!(v, (0..100).collect::<Vec<_>>());
    }
}
