//! Deterministic synthetic vision tasks (the DESIGN.md dataset
//! substitution).
//!
//! Each class owns a fixed random template image; a sample is its class
//! template plus per-sample Gaussian noise, a random spatial shift and a
//! random amplitude jitter. The tasks therefore have a real accuracy
//! signal (a CNN must learn the templates through the noise) while the
//! generator stays fully deterministic and dependency-free.
//!
//! Task presets mirror the paper's three datasets:
//! * `CifarLike`  — 10 balanced classes (CIFAR10 stand-in)
//! * `VocLike`    — 20 classes, mildly imbalanced (Pascal VOC stand-in)
//! * `XrayLike`   — 2 classes, 3:1 imbalance (Chest X-Ray stand-in,
//!                  evaluated with F1 in the harnesses)

use super::rng::XorShiftRng;

/// Which paper dataset a synthetic task stands in for.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TaskKind {
    /// 10 balanced classes (CIFAR10 stand-in).
    CifarLike,
    /// 20 mildly imbalanced classes (Pascal VOC stand-in).
    VocLike,
    /// 2 classes at 3:1 imbalance (Chest X-Ray stand-in).
    XrayLike,
}

impl TaskKind {
    /// Class count of the task.
    pub fn classes(self) -> usize {
        match self {
            TaskKind::CifarLike => 10,
            TaskKind::VocLike => 20,
            TaskKind::XrayLike => 2,
        }
    }

    /// Class prior weights (imbalance patterns).
    fn prior(self) -> Vec<f64> {
        match self {
            TaskKind::CifarLike => vec![1.0; 10],
            TaskKind::VocLike => (0..20).map(|i| 1.0 + 0.5 * (i % 4) as f64).collect(),
            TaskKind::XrayLike => vec![3.0, 1.0], // "pneumonia" vs "normal"-ish skew
        }
    }
}

/// Full description of one synthetic task instance.
#[derive(Debug, Clone)]
pub struct TaskSpec {
    /// Task preset.
    pub kind: TaskKind,
    /// Image height = width.
    pub hw: usize,
    /// Image channels.
    pub channels: usize,
    /// Per-sample Gaussian noise amplitude.
    pub noise: f32,
    /// Maximum per-sample spatial shift in pixels.
    pub max_shift: usize,
    /// Template seed (every client/server sees the same concepts).
    pub seed: u64,
}

impl TaskSpec {
    /// Task spec with the default noise/shift settings.
    pub fn new(kind: TaskKind, hw: usize, channels: usize, seed: u64) -> Self {
        Self {
            kind,
            hw,
            channels,
            noise: 0.6,
            max_shift: hw / 8,
            seed,
        }
    }
}

/// Class templates: spatially *smooth* random images (a coarse Gaussian
/// grid bilinearly upsampled). Smoothness matters: white-noise templates
/// decorrelate completely under the per-sample spatial shift, while
/// low-frequency templates keep a strong learnable signal — closer to
/// natural-image class structure.
pub fn class_templates(spec: &TaskSpec, classes: usize) -> Vec<Vec<f32>> {
    let hw = spec.hw;
    let c = spec.channels;
    let coarse = 4usize;
    let mut trng = XorShiftRng::new(spec.seed);
    (0..classes)
        .map(|_| {
            let grid: Vec<f32> = (0..coarse * coarse * c).map(|_| trng.normal() * 1.5).collect();
            let mut img = vec![0.0f32; hw * hw * c];
            for y in 0..hw {
                for x in 0..hw {
                    let fy = y as f32 / hw as f32 * (coarse - 1) as f32;
                    let fx = x as f32 / hw as f32 * (coarse - 1) as f32;
                    let (y0, x0) = (fy as usize, fx as usize);
                    let (y1, x1) = ((y0 + 1).min(coarse - 1), (x0 + 1).min(coarse - 1));
                    let (dy, dx) = (fy - y0 as f32, fx - x0 as f32);
                    for ch in 0..c {
                        let g = |yy: usize, xx: usize| grid[(yy * coarse + xx) * c + ch];
                        let v = g(y0, x0) * (1.0 - dy) * (1.0 - dx)
                            + g(y0, x1) * (1.0 - dy) * dx
                            + g(y1, x0) * dy * (1.0 - dx)
                            + g(y1, x1) * dy * dx;
                        img[(y * hw + x) * c + ch] = v;
                    }
                }
            }
            img
        })
        .collect()
}

/// One labeled sample.
#[derive(Debug, Clone)]
pub struct Sample {
    /// Flat [H, W, C].
    pub x: Vec<f32>,
    /// Class label.
    pub label: usize,
}

/// A generated dataset (train, validation or test portion).
#[derive(Debug, Clone)]
pub struct Dataset {
    /// The task this dataset was generated from.
    pub spec: TaskSpec,
    /// Class count.
    pub classes: usize,
    /// All samples, in generation order.
    pub samples: Vec<Sample>,
}

impl Dataset {
    /// Generate `n` samples. Streams from `seed ^ salt` so train / val /
    /// test splits are disjoint by construction.
    pub fn generate(spec: &TaskSpec, n: usize, salt: u64) -> Self {
        let classes = spec.kind.classes();
        let hw = spec.hw;
        let c = spec.channels;
        // Templates depend only on the task seed: every client and the
        // server see the same underlying concept.
        let templates = class_templates(spec, classes);
        let prior = spec.kind.prior();
        let psum: f64 = prior.iter().sum();

        let mut rng = XorShiftRng::new(spec.seed ^ salt.wrapping_mul(0x9E3779B97F4A7C15));
        let samples = (0..n)
            .map(|_| {
                // draw class by prior
                let mut u = rng.next_f32() as f64 * psum;
                let mut label = 0;
                for (k, &p) in prior.iter().enumerate() {
                    if u < p {
                        label = k;
                        break;
                    }
                    u -= p;
                }
                let t = &templates[label];
                let dy = rng.below(2 * spec.max_shift + 1) as isize - spec.max_shift as isize;
                let dx = rng.below(2 * spec.max_shift + 1) as isize - spec.max_shift as isize;
                let amp = 0.8 + 0.4 * rng.next_f32();
                let mut x = vec![0.0f32; hw * hw * c];
                for yy in 0..hw {
                    for xx in 0..hw {
                        let sy = yy as isize + dy;
                        let sx = xx as isize + dx;
                        if sy < 0 || sx < 0 || sy >= hw as isize || sx >= hw as isize {
                            continue;
                        }
                        for ch in 0..c {
                            x[(yy * hw + xx) * c + ch] =
                                amp * t[(sy as usize * hw + sx as usize) * c + ch];
                        }
                    }
                }
                for v in x.iter_mut() {
                    *v += spec.noise * rng.normal();
                }
                Sample { x, label }
            })
            .collect();
        Self {
            spec: spec.clone(),
            classes,
            samples,
        }
    }

    /// Flat input length (H·W·C).
    pub fn feature_len(&self) -> usize {
        self.spec.hw * self.spec.hw * self.spec.channels
    }

    /// Sample count.
    pub fn len(&self) -> usize {
        self.samples.len()
    }

    /// Whether the dataset holds no samples.
    pub fn is_empty(&self) -> bool {
        self.samples.is_empty()
    }

    /// All labels, in sample order.
    pub fn labels(&self) -> Vec<usize> {
        self.samples.iter().map(|s| s.label).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_generation() {
        let spec = TaskSpec::new(TaskKind::CifarLike, 16, 3, 1);
        let a = Dataset::generate(&spec, 32, 0);
        let b = Dataset::generate(&spec, 32, 0);
        assert_eq!(a.samples[7].x, b.samples[7].x);
        assert_eq!(a.samples[7].label, b.samples[7].label);
    }

    #[test]
    fn different_salts_differ() {
        let spec = TaskSpec::new(TaskKind::CifarLike, 16, 3, 1);
        let a = Dataset::generate(&spec, 8, 0);
        let b = Dataset::generate(&spec, 8, 1);
        assert_ne!(a.samples[0].x, b.samples[0].x);
    }

    #[test]
    fn xray_imbalance() {
        let spec = TaskSpec::new(TaskKind::XrayLike, 8, 1, 5);
        let ds = Dataset::generate(&spec, 4000, 0);
        let pos = ds.labels().iter().filter(|&&l| l == 0).count();
        let ratio = pos as f64 / ds.len() as f64;
        assert!((ratio - 0.75).abs() < 0.05, "ratio={ratio}");
    }

    #[test]
    fn classes_match_kind() {
        for kind in [TaskKind::CifarLike, TaskKind::VocLike, TaskKind::XrayLike] {
            let spec = TaskSpec::new(kind, 8, 3, 2);
            let ds = Dataset::generate(&spec, 64, 0);
            assert_eq!(ds.classes, kind.classes());
            assert!(ds.labels().iter().all(|&l| l < ds.classes));
        }
    }

    #[test]
    fn templates_are_learnable_signal() {
        // nearest-template classification should beat chance by a lot
        let spec = TaskSpec::new(TaskKind::CifarLike, 16, 3, 3);
        let ds = Dataset::generate(&spec, 200, 0);
        let templates = class_templates(&spec, 10);
        let mut correct = 0;
        for s in &ds.samples {
            let best = templates
                .iter()
                .enumerate()
                .min_by(|(_, a), (_, b)| {
                    let da: f32 = a.iter().zip(&s.x).map(|(u, v)| (u - v).powi(2)).sum();
                    let db: f32 = b.iter().zip(&s.x).map(|(u, v)| (u - v).powi(2)).sum();
                    da.partial_cmp(&db).unwrap()
                })
                .unwrap()
                .0;
            if best == s.label {
                correct += 1;
            }
        }
        assert!(correct > 100, "nearest-template acc {correct}/200");
    }
}
