//! Synthetic dataset substrate (DESIGN.md substitution: the paper's
//! Pascal VOC / CIFAR10 / Chest X-Ray are replaced by deterministic
//! class-template tasks that exercise the same FL dynamics).

mod rng;
mod split;
mod synthetic;

pub use rng::XorShiftRng;
pub use split::{dirichlet_split, iid_split, ClientSplit};
pub use synthetic::{Dataset, Sample, TaskKind, TaskSpec};

/// One minibatch in wire layout: x flat [B,H,W,C], y one-hot flat [B,classes].
#[derive(Debug, Clone)]
pub struct Batch {
    /// Inputs, flat [B, H, W, C].
    pub x: Vec<f32>,
    /// One-hot labels, flat [B, classes].
    pub y: Vec<f32>,
    /// Samples in the batch (B).
    pub size: usize,
}

/// Iterate `data` in batches of exactly `batch` samples (drop last partial
/// batch — step HLOs have a fixed batch dimension).
pub fn batches(ds: &Dataset, order: &[usize], batch: usize) -> Vec<Batch> {
    let feat = ds.feature_len();
    let classes = ds.classes;
    order
        .chunks_exact(batch)
        .map(|chunk| {
            let mut x = Vec::with_capacity(batch * feat);
            let mut y = vec![0.0f32; batch * classes];
            for (bi, &si) in chunk.iter().enumerate() {
                x.extend_from_slice(&ds.samples[si].x);
                y[bi * classes + ds.samples[si].label] = 1.0;
            }
            Batch { x, y, size: batch }
        })
        .collect()
}
