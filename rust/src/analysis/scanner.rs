//! String/comment-aware source scanner for the lint plane.
//!
//! The scanner splits every line of a Rust source file into a **code
//! view** (string/char-literal interiors blanked, comments removed)
//! and a **comment view** (the comment text alone), so rules can match
//! tokens without tripping over occurrences inside literals or prose.
//! On top of the split it derives three per-line facts the rules
//! consume: whether the line sits inside a `#[cfg(test)]` item, whether
//! it sits inside a `// fsfl-lint: hot` fence, and which rules a
//! `// fsfl-lint: allow(rule): why` directive suppresses on it.
//!
//! The scanner is deliberately a line-oriented token pass, not a
//! parser: it understands exactly as much Rust syntax as the rules
//! need (nested block comments, raw/byte strings, char literals vs
//! lifetimes, brace depth) and nothing more, matching the crate's
//! no-dependency style.

use super::Finding;

/// One source line, split into rule-consumable views.
#[derive(Debug)]
pub struct Line {
    /// Source text with comments removed and string/char-literal
    /// interiors blanked (delimiters kept, so `""` still reads as an
    /// expression boundary).
    pub code: String,
    /// Comment text on this line (line + block comments concatenated).
    pub comment: String,
    /// Inside a `#[cfg(test)]` item's braces (or on the attribute).
    pub in_test: bool,
    /// Inside a `// fsfl-lint: hot` … `end-hot` fence.
    pub hot: bool,
    /// Rules suppressed on this line by an `allow(rule): why` directive
    /// (on the same line, or carried from a directive-only line above).
    pub allows: Vec<&'static str>,
}

impl Line {
    /// True if `rule` is suppressed on this line.
    pub fn allows(&self, rule: &str) -> bool {
        self.allows.iter().any(|r| *r == rule)
    }
}

/// A scanned source file: normalized path plus per-line views.
#[derive(Debug)]
pub struct SourceFile {
    /// Crate-relative path with `/` separators (`src/net/wire.rs`,
    /// `tests/integration_transport.rs`).
    pub path: String,
    /// Per-line views, index 0 = line 1.
    pub lines: Vec<Line>,
}

/// Rule names an `allow(...)` directive may target. `directive`
/// findings themselves are not suppressible — a broken escape hatch
/// must never hide itself.
pub const RULES: [&str; 7] = [
    "clock",
    "hot-alloc",
    "panic",
    "safety",
    "wire-tags",
    "wire-version",
    "wire-corpus",
];

/// Lexer state carried across lines.
enum State {
    Code,
    /// Block comment at the contained nesting depth (Rust nests them).
    Block(u32),
    /// String literal (`"…"` / `b"…"`); escapes handled inline.
    Str,
    /// Raw string with its `#` count (`r"…"`, `r##"…"##`, `br#"…"#`).
    RawStr(usize),
}

impl SourceFile {
    /// Scan `src`, returning the file plus any malformed-directive
    /// findings (unknown directive, missing justification, unbalanced
    /// fences). `path` should already be crate-relative.
    pub fn parse(path: &str, src: &str) -> (SourceFile, Vec<Finding>) {
        let mut lines = split_views(src);
        mark_test_regions(&mut lines);
        let findings = apply_directives(path, &mut lines);
        (
            SourceFile {
                path: path.to_string(),
                lines,
            },
            findings,
        )
    }

    /// 1-based line iteration: `(line_no, line)`.
    pub fn numbered(&self) -> impl Iterator<Item = (usize, &Line)> {
        self.lines.iter().enumerate().map(|(i, l)| (i + 1, l))
    }
}

/// Pass 1: split source into per-line code/comment views.
fn split_views(src: &str) -> Vec<Line> {
    let chars: Vec<char> = src.chars().collect();
    let mut out = Vec::new();
    let mut code = String::new();
    let mut comment = String::new();
    let mut state = State::Code;
    let mut i = 0;
    // In the `line` comment state until end of line.
    let mut line_comment = false;
    while i < chars.len() {
        let c = chars[i];
        let next = chars.get(i + 1).copied().unwrap_or('\0');
        if c == '\n' {
            line_comment = false;
            out.push(Line {
                code: std::mem::take(&mut code),
                comment: std::mem::take(&mut comment),
                in_test: false,
                hot: false,
                allows: Vec::new(),
            });
            i += 1;
            continue;
        }
        if line_comment {
            comment.push(c);
            i += 1;
            continue;
        }
        match state {
            State::Code => {
                if c == '/' && next == '/' {
                    line_comment = true;
                    i += 2;
                } else if c == '/' && next == '*' {
                    state = State::Block(1);
                    i += 2;
                } else if c == '"' {
                    code.push('"');
                    state = State::Str;
                    i += 1;
                } else if c == 'b' && next == '"' && !ident_tail(&code) {
                    code.push_str("b\"");
                    state = State::Str;
                    i += 2;
                } else if c == 'r' && (next == '"' || next == '#') && !ident_tail(&code) {
                    // Raw (or raw-byte via the `b` branch above missing —
                    // `br` handled here too) string candidate.
                    let mut j = i + 1;
                    let mut hashes = 0;
                    while chars.get(j) == Some(&'#') {
                        hashes += 1;
                        j += 1;
                    }
                    if chars.get(j) == Some(&'"') {
                        code.push('r');
                        for _ in 0..hashes {
                            code.push('#');
                        }
                        code.push('"');
                        state = State::RawStr(hashes);
                        i = j + 1;
                    } else {
                        code.push(c);
                        i += 1;
                    }
                } else if c == '\'' {
                    // Char literal vs lifetime: 'x' or '\…' is a literal,
                    // anything else ('a in generics) is a lifetime tick.
                    if next == '\\' {
                        // Escaped char literal: blank to the closing quote.
                        code.push_str("' ");
                        i += 2;
                        while i < chars.len() && chars[i] != '\'' && chars[i] != '\n' {
                            i += 1;
                        }
                        if chars.get(i) == Some(&'\'') {
                            code.push('\'');
                            i += 1;
                        }
                    } else if chars.get(i + 2) == Some(&'\'') {
                        code.push_str("' '");
                        i += 3;
                    } else {
                        code.push('\'');
                        i += 1;
                    }
                } else {
                    code.push(c);
                    i += 1;
                }
            }
            State::Block(depth) => {
                if c == '/' && next == '*' {
                    state = State::Block(depth + 1);
                    comment.push_str("/*");
                    i += 2;
                } else if c == '*' && next == '/' {
                    state = if depth == 1 {
                        State::Code
                    } else {
                        comment.push_str("*/");
                        State::Block(depth - 1)
                    };
                    i += 2;
                } else {
                    comment.push(c);
                    i += 1;
                }
            }
            State::Str => {
                if c == '\\' {
                    code.push(' ');
                    // Skip the escaped char unless it is the newline of a
                    // `\`-continued string (the newline must still split
                    // lines, or every number below it drifts).
                    i += if next == '\n' { 1 } else { 2 };
                } else if c == '"' {
                    code.push('"');
                    state = State::Code;
                    i += 1;
                } else {
                    code.push(' ');
                    i += 1;
                }
            }
            State::RawStr(hashes) => {
                if c == '"' {
                    let mut j = i + 1;
                    let mut h = 0;
                    while h < hashes && chars.get(j) == Some(&'#') {
                        h += 1;
                        j += 1;
                    }
                    if h == hashes {
                        code.push('"');
                        for _ in 0..hashes {
                            code.push('#');
                        }
                        state = State::Code;
                        i = j;
                    } else {
                        code.push(' ');
                        i += 1;
                    }
                } else {
                    code.push(' ');
                    i += 1;
                }
            }
        }
    }
    out.push(Line {
        code,
        comment,
        in_test: false,
        hot: false,
        allows: Vec::new(),
    });
    out
}

/// True if the code buffer ends mid-identifier (so a following `b` or
/// `r` is part of a name like `attr` rather than a literal prefix).
fn ident_tail(code: &str) -> bool {
    code.chars()
        .next_back()
        .is_some_and(|c| c.is_ascii_alphanumeric() || c == '_')
}

/// Pass 2: mark lines inside `#[cfg(test)]` items via brace depth. The
/// attribute arms a pending flag consumed by the next `{` at the same
/// nesting level (covering `mod tests`, test fns and test impls); a
/// `;` before any brace disarms it (attribute on a braceless item).
fn mark_test_regions(lines: &mut [Line]) {
    let mut depth = 0usize;
    let mut pending = false;
    let mut test_from: Option<usize> = None;
    for line in lines.iter_mut() {
        if test_from.is_some() {
            line.in_test = true;
        }
        if test_from.is_none() && is_cfg_test_attr(&line.code) {
            pending = true;
            line.in_test = true;
        }
        for c in line.code.chars() {
            match c {
                '{' => {
                    if pending && test_from.is_none() {
                        test_from = Some(depth);
                        pending = false;
                        line.in_test = true;
                    }
                    depth += 1;
                }
                '}' => {
                    depth = depth.saturating_sub(1);
                    if test_from == Some(depth) {
                        test_from = None;
                    }
                }
                _ => {}
            }
        }
        if pending && line.code.contains(';') && !line.code.contains('{') {
            pending = false;
        }
    }
}

/// `#[cfg(test)]` detector, whitespace-tolerant.
fn is_cfg_test_attr(code: &str) -> bool {
    let squashed: String = code.chars().filter(|c| !c.is_whitespace()).collect();
    squashed.contains("#[cfg(test)]")
}

/// Pass 3: interpret `fsfl-lint:` directives, marking hot fences and
/// allow sets, and reporting malformed directives as findings.
fn apply_directives(path: &str, lines: &mut [Line]) -> Vec<Finding> {
    let mut findings = Vec::new();
    let mut hot = false;
    let mut hot_start = 0usize;
    // Allows from directive-only lines, pending their next code line.
    let mut carry: Vec<&'static str> = Vec::new();
    for (idx, line) in lines.iter_mut().enumerate() {
        let no = idx + 1;
        // A directive must be the whole comment (`// fsfl-lint: …`), so
        // prose that merely *mentions* the directive syntax never arms
        // one.
        let body = line
            .comment
            .trim()
            .strip_prefix("fsfl-lint:")
            .map(|rest| rest.trim().to_string());
        let mut this: Vec<&'static str> = Vec::new();
        if let Some(body) = body {
            match body.as_str() {
                "hot" => {
                    if hot {
                        findings.push(Finding::new(
                            path,
                            no,
                            "directive",
                            "nested `fsfl-lint: hot` fence (close the previous one first)",
                        ));
                    }
                    hot = true;
                    hot_start = no;
                }
                "end-hot" => {
                    if !hot {
                        findings.push(Finding::new(
                            path,
                            no,
                            "directive",
                            "`fsfl-lint: end-hot` without an open fence",
                        ));
                    }
                    hot = false;
                }
                other => match parse_allow(other) {
                    Some((Some(rule), true)) => this.push(rule),
                    Some((Some(rule), false)) => findings.push(Finding::new(
                        path,
                        no,
                        "directive",
                        format!("allow({rule}) needs a justification: `allow({rule}): why`"),
                    )),
                    Some((None, _)) => findings.push(Finding::new(
                        path,
                        no,
                        "directive",
                        format!("allow() of unknown rule in `{other}`"),
                    )),
                    None => findings.push(Finding::new(
                        path,
                        no,
                        "directive",
                        format!("unknown directive `fsfl-lint: {other}`"),
                    )),
                },
            }
        }
        line.hot = hot;
        let has_code = !line.code.trim().is_empty();
        if !this.is_empty() {
            if has_code {
                line.allows.append(&mut this);
            } else {
                carry.append(&mut this);
            }
        } else if has_code {
            line.allows.append(&mut carry);
        }
    }
    if hot {
        findings.push(Finding::new(
            path,
            hot_start,
            "directive",
            "unclosed `fsfl-lint: hot` fence",
        ));
    }
    findings
}

/// Parse `allow(rule): why`. Returns `Some((rule, has_justification))`
/// with `rule = None` for an unknown rule name, or `None` if the text
/// is not an allow directive at all.
fn parse_allow(body: &str) -> Option<(Option<&'static str>, bool)> {
    let rest = body.strip_prefix("allow(")?;
    let (name, tail) = rest.split_once(')')?;
    let rule = RULES.iter().find(|r| **r == name.trim()).copied();
    let justified = tail
        .trim_start()
        .strip_prefix(':')
        .is_some_and(|why| !why.trim().is_empty());
    Some((rule, justified))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn scan(src: &str) -> SourceFile {
        SourceFile::parse("src/fixture.rs", src).0
    }

    #[test]
    fn strings_and_comments_leave_the_code_view() {
        let f = scan("let x = \"Instant::now()\"; // Instant::now()\nInstant::now();\n");
        assert!(!f.lines[0].code.contains("Instant::now"));
        assert!(f.lines[0].comment.contains("Instant::now"));
        assert!(f.lines[1].code.contains("Instant::now"));
    }

    #[test]
    fn raw_strings_and_char_literals_blank_correctly() {
        let f = scan("let s = r#\"vec! \"# ; let c = '{'; let l: &'a str = s;\n");
        let code = &f.lines[0].code;
        assert!(!code.contains("vec!"), "raw string leaked: {code}");
        assert!(!code.contains('{'), "char literal leaked: {code}");
        assert!(code.contains("&'a str"), "lifetime mangled: {code}");
    }

    #[test]
    fn escaped_newline_in_string_keeps_line_numbers() {
        let f = scan("let s = \"a\\\nb\";\nsecond_line();\n");
        assert!(f.lines[1].code.contains('b'));
        assert!(f.lines[2].code.contains("second_line"));
    }

    #[test]
    fn nested_block_comments_close_at_the_right_depth() {
        let f = scan("/* outer /* inner */ still comment */ code();\n");
        assert!(f.lines[0].code.contains("code()"));
        assert!(!f.lines[0].code.contains("outer"));
    }

    #[test]
    fn cfg_test_region_covers_mod_body_only() {
        let src = "fn live() {}\n#[cfg(test)]\nmod tests {\n    fn t() {}\n}\nfn after() {}\n";
        let f = scan(src);
        assert!(!f.lines[0].in_test);
        assert!(f.lines[1].in_test && f.lines[2].in_test && f.lines[3].in_test);
        assert!(f.lines[4].in_test);
        assert!(!f.lines[5].in_test);
    }

    #[test]
    fn hot_fence_and_allow_directives_mark_lines() {
        let src = "\
// fsfl-lint: hot
fn hot_fn() {}
// fsfl-lint: end-hot
// fsfl-lint: allow(clock): fixture justification
let t = Instant::now();
";
        let (f, errs) = SourceFile::parse("src/fixture.rs", src);
        assert!(errs.is_empty(), "{errs:?}");
        assert!(f.lines[1].hot);
        assert!(!f.lines[4].hot);
        assert!(f.lines[4].allows("clock"));
    }

    #[test]
    fn malformed_directives_are_findings() {
        let src = "\
// fsfl-lint: allow(clock)
// fsfl-lint: allow(nonsense): why
// fsfl-lint: frobnicate
// fsfl-lint: end-hot
// fsfl-lint: hot
";
        let (_, errs) = SourceFile::parse("src/fixture.rs", src);
        let rules: Vec<_> = errs.iter().map(|e| e.line).collect();
        assert_eq!(rules, vec![1, 2, 3, 4, 5], "{errs:?}");
        assert!(errs.iter().all(|e| e.rule == "directive"));
    }
}
