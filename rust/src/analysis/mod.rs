//! Static-analysis plane: the `fsfl lint` invariant checker.
//!
//! The crate's determinism and performance guarantees rest on
//! source-level invariants no runtime test can fully defend: wall-clock
//! reads stay inside [`crate::supervise`], the steady-state codec path
//! allocates nothing, the wire protocol's tags and version constants
//! never drift from what ARCHITECTURE.md documents, and transport /
//! supervision code returns typed errors instead of panicking. This
//! module turns those prose rules into an enforced gate: a
//! dependency-free, string/comment-aware line scanner
//! ([`scanner::SourceFile`]) feeding a fixed rule set
//! ([`rules::lint_files`]), driven by `fsfl lint` locally and by the CI
//! `analysis` job on every push.
//!
//! Escape hatches are explicit and audited: `// fsfl-lint: allow(rule):
//! why` suppresses one rule on one line and must carry a justification;
//! `// fsfl-lint: hot` / `end-hot` fence the allocation-free regions.
//! See ARCHITECTURE.md's "analysis plane" section for the full rule
//! catalog and extension guide.

use std::path::{Path, PathBuf};

use anyhow::{anyhow, Result};

pub mod rules;
pub mod scanner;

/// One lint violation, addressable as `file:line`.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Finding {
    /// Crate-relative path (or `ARCHITECTURE.md` for doc findings).
    pub file: String,
    /// 1-based line number.
    pub line: usize,
    /// Rule identifier (`clock`, `hot-alloc`, `panic`, `safety`,
    /// `wire-tags`, `wire-version`, `wire-corpus`, `directive`).
    pub rule: &'static str,
    /// Human-readable explanation.
    pub message: String,
}

impl Finding {
    /// Build a finding; `message` may be any string-ish value.
    pub fn new(file: &str, line: usize, rule: &'static str, message: impl Into<String>) -> Self {
        Self {
            file: file.to_string(),
            line,
            rule,
            message: message.into(),
        }
    }
}

impl std::fmt::Display for Finding {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "{}:{}: [{}] {}",
            self.file, self.line, self.rule, self.message
        )
    }
}

/// Result of one lint run: findings (sorted by file/line) plus how
/// much source the run actually covered, so "0 findings" is checkable
/// against "0 files scanned".
#[derive(Debug)]
pub struct LintReport {
    /// All findings, sorted by `(file, line, rule)`.
    pub findings: Vec<Finding>,
    /// Number of `.rs` files scanned.
    pub files_scanned: usize,
}

impl LintReport {
    /// True when the run found nothing.
    pub fn clean(&self) -> bool {
        self.findings.is_empty()
    }

    /// Machine-readable form:
    /// `{"files_scanned":N,"findings":[{file,line,rule,message}…]}`.
    pub fn to_json(&self) -> String {
        let mut out = String::new();
        out.push_str("{\"files_scanned\":");
        out.push_str(&self.files_scanned.to_string());
        out.push_str(",\"findings\":[");
        for (i, f) in self.findings.iter().enumerate() {
            if i > 0 {
                out.push(',');
            }
            out.push_str("{\"file\":\"");
            out.push_str(&json_escape(&f.file));
            out.push_str("\",\"line\":");
            out.push_str(&f.line.to_string());
            out.push_str(",\"rule\":\"");
            out.push_str(&json_escape(f.rule));
            out.push_str("\",\"message\":\"");
            out.push_str(&json_escape(&f.message));
            out.push_str("\"}");
        }
        out.push_str("]}");
        out
    }
}

/// Minimal JSON string escape (control chars, quotes, backslashes).
fn json_escape(s: &str) -> String {
    let mut out = String::with_capacity(s.len());
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out
}

/// The resolved scan layout: where the crate lives and where the
/// architecture doc is expected.
struct Layout {
    /// Directory containing `src/` (and usually `tests/`).
    crate_dir: PathBuf,
    /// ARCHITECTURE.md candidate path (may not exist).
    doc: PathBuf,
}

/// Accept either the repository root (containing `rust/src`) or the
/// crate directory itself (containing `src`), so `fsfl lint` works
/// both from the repo checkout and from CI's `working-directory: rust`.
fn resolve_layout(root: &Path) -> Result<Layout> {
    if root.join("rust/src").is_dir() {
        return Ok(Layout {
            crate_dir: root.join("rust"),
            doc: root.join("ARCHITECTURE.md"),
        });
    }
    if root.join("src").is_dir() {
        let doc = if root.join("ARCHITECTURE.md").is_file() {
            root.join("ARCHITECTURE.md")
        } else {
            root.join("../ARCHITECTURE.md")
        };
        return Ok(Layout {
            crate_dir: root.to_path_buf(),
            doc,
        });
    }
    Err(anyhow!(
        "no Rust sources under {}: expected `src/` or `rust/src/`",
        root.display()
    ))
}

/// Collect `.rs` files under `dir` recursively, sorted for
/// deterministic finding order. A missing `dir` yields an empty list
/// (a crate without `tests/` is fine).
fn rust_files(dir: &Path) -> Result<Vec<PathBuf>> {
    let mut out = Vec::new();
    if !dir.is_dir() {
        return Ok(out);
    }
    let mut stack = vec![dir.to_path_buf()];
    while let Some(d) = stack.pop() {
        let entries =
            std::fs::read_dir(&d).map_err(|e| anyhow!("reading {}: {e}", d.display()))?;
        for entry in entries {
            let path = entry
                .map_err(|e| anyhow!("reading {}: {e}", d.display()))?
                .path();
            if path.is_dir() {
                stack.push(path);
            } else if path.extension().is_some_and(|x| x == "rs") {
                out.push(path);
            }
        }
    }
    out.sort();
    Ok(out)
}

/// Run the full lint over `root` (repository root or crate directory).
/// Scans `src/**` and `tests/**`, applies every rule, and reconciles
/// version constants against ARCHITECTURE.md when present.
pub fn run_lint(root: &Path) -> Result<LintReport> {
    let layout = resolve_layout(root)?;
    let mut files = Vec::new();
    let mut findings = Vec::new();
    for sub in ["src", "tests"] {
        for path in rust_files(&layout.crate_dir.join(sub))? {
            let src = std::fs::read_to_string(&path)
                .map_err(|e| anyhow!("reading {}: {e}", path.display()))?;
            let rel = path
                .strip_prefix(&layout.crate_dir)
                .unwrap_or(&path)
                .to_string_lossy()
                .replace('\\', "/");
            let (file, errs) = scanner::SourceFile::parse(&rel, &src);
            findings.extend(errs);
            files.push(file);
        }
    }
    let doc = std::fs::read_to_string(&layout.doc).ok();
    findings.extend(rules::lint_files(&files, doc.as_deref()));
    findings.sort_by(|a, b| {
        (a.file.as_str(), a.line, a.rule).cmp(&(b.file.as_str(), b.line, b.rule))
    });
    Ok(LintReport {
        findings,
        files_scanned: files.len(),
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn finding_display_is_file_line_rule_message() {
        let f = Finding::new("src/x.rs", 7, "clock", "raw clock read");
        assert_eq!(f.to_string(), "src/x.rs:7: [clock] raw clock read");
    }

    #[test]
    fn report_json_escapes_and_counts() {
        let report = LintReport {
            findings: vec![Finding::new("src/a \"b\".rs", 2, "panic", "line\none")],
            files_scanned: 3,
        };
        assert_eq!(
            report.to_json(),
            "{\"files_scanned\":3,\"findings\":[{\"file\":\"src/a \\\"b\\\".rs\",\
             \"line\":2,\"rule\":\"panic\",\"message\":\"line\\none\"}]}"
        );
        assert!(!report.clean());
    }
}
