//! The lint rules: four source-level invariants the runtime tests
//! cannot see, each matched against the scanner's code view.
//!
//! * `clock` — no `Instant::now()` / `SystemTime::now()` outside
//!   `src/supervise.rs`; everything else reads time through
//!   [`crate::supervise::Clock`], which is what makes scripted-clock
//!   chaos tests and the determinism contract possible.
//! * `hot-alloc` — no allocating constructs inside `// fsfl-lint: hot`
//!   fences; the fences cover the steady-state codec path, twinning the
//!   `benches/fl_round.rs` zero-allocation pin at the source level.
//! * `panic` — no `unwrap()` / `expect()` / `panic!` in non-test code
//!   under `src/net/`, `src/session/`, `src/coordinator/`: transport
//!   and supervision errors must surface as typed results the recovery
//!   plane can act on.
//! * `safety` — every `unsafe` block carries a `// SAFETY:` comment
//!   stating the invariant it relies on.
//!
//! Plus the cross-file **wire consistency** checks (`wire-tags`,
//! `wire-version`, `wire-corpus`): tag bytes unique per direction,
//! version constants agreeing with the numbers ARCHITECTURE.md quotes,
//! and every `ShardCmd`/`ShardMsg` variant exercised by the transport
//! corpus. Cross-file checks only run when their subject files are in
//! the scan set, so the linter stays usable on fixture trees.

use super::scanner::SourceFile;
use super::Finding;

/// Allocating constructs banned inside hot fences.
const HOT_TOKENS: [&str; 7] = [
    "Vec::new",
    "vec!",
    ".to_vec()",
    "format!",
    "String::from",
    ".collect()",
    "Box::new",
];

/// The version constants the wire-version rule reconciles with
/// ARCHITECTURE.md: `(constant, defining file)`.
const VERSIONS: [(&str, &str); 3] = [
    ("PROTOCOL_VERSION", "src/net/wire.rs"),
    ("SNAPSHOT_VERSION", "src/session/mod.rs"),
    ("SCHEMA_VERSION", "src/bench/mod.rs"),
];

/// File whose `enum ShardCmd` / `enum ShardMsg` variants must be
/// exercised by the transport corpus.
const ENUM_FILE: &str = "src/coordinator/mod.rs";
/// The corpus that must mention every wire enum variant.
const CORPUS_FILE: &str = "tests/integration_transport.rs";

/// Run every rule over the scanned files. `doc` is ARCHITECTURE.md's
/// text when present (the wire-version rule reconciles against it).
pub fn lint_files(files: &[SourceFile], doc: Option<&str>) -> Vec<Finding> {
    let mut out = Vec::new();
    for f in files {
        clock_rule(f, &mut out);
        hot_alloc_rule(f, &mut out);
        panic_rule(f, &mut out);
        safety_rule(f, &mut out);
    }
    wire_tags_rule(files, &mut out);
    wire_version_rule(files, doc, &mut out);
    wire_corpus_rule(files, &mut out);
    out
}

/// `clock`: raw monotonic/wall reads are `supervise.rs`'s monopoly.
fn clock_rule(f: &SourceFile, out: &mut Vec<Finding>) {
    if f.path == "src/supervise.rs" || f.path.ends_with("/src/supervise.rs") {
        return;
    }
    for (no, line) in f.numbered() {
        if (line.code.contains("Instant::now") || line.code.contains("SystemTime::now"))
            && !line.allows("clock")
        {
            out.push(Finding::new(
                &f.path,
                no,
                "clock",
                "raw clock read; take time from `supervise::Clock` so scripted \
                 clocks stay in control",
            ));
        }
    }
}

/// `hot-alloc`: allocating constructs inside `fsfl-lint: hot` fences.
fn hot_alloc_rule(f: &SourceFile, out: &mut Vec<Finding>) {
    for (no, line) in f.numbered() {
        if !line.hot || line.allows("hot-alloc") {
            continue;
        }
        for tok in HOT_TOKENS {
            if line.code.contains(tok) {
                out.push(Finding::new(
                    &f.path,
                    no,
                    "hot-alloc",
                    format!("allocating construct `{tok}` inside a hot fence"),
                ));
            }
        }
    }
}

/// `panic`: panicking constructs in non-test transport/supervision code.
fn panic_rule(f: &SourceFile, out: &mut Vec<Finding>) {
    let scope = ["src/net/", "src/session/", "src/coordinator/"]
        .iter()
        .find(|p| f.path.starts_with(**p));
    let Some(scope) = scope else { return };
    let plane = scope.trim_start_matches("src/").trim_end_matches('/');
    for (no, line) in f.numbered() {
        if line.in_test || line.allows("panic") {
            continue;
        }
        for (tok, name) in [
            (".unwrap()", "unwrap()"),
            (".expect(", "expect()"),
            ("panic!", "panic!"),
        ] {
            if line.code.contains(tok) {
                out.push(Finding::new(
                    &f.path,
                    no,
                    "panic",
                    format!("`{name}` in non-test {plane} code; return a typed error"),
                ));
            }
        }
    }
}

/// `safety`: every `unsafe` block carries a `// SAFETY:` comment, on
/// the same line or in the contiguous comment/attribute block above.
fn safety_rule(f: &SourceFile, out: &mut Vec<Finding>) {
    for (no, line) in f.numbered() {
        if !has_word(&line.code, "unsafe") || is_unsafe_item(&line.code) {
            continue;
        }
        if line.allows("safety") {
            continue;
        }
        let mut justified = line.comment.contains("SAFETY:");
        let mut i = no - 1; // index of the line above
        while !justified && i > 0 {
            let above = &f.lines[i - 1];
            let code = above.code.trim();
            if !code.is_empty() && !code.starts_with("#[") {
                break;
            }
            justified = above.comment.contains("SAFETY:");
            i -= 1;
        }
        if !justified {
            out.push(Finding::new(
                &f.path,
                no,
                "safety",
                "`unsafe` block without a `// SAFETY:` comment stating its invariant",
            ));
        }
    }
}

/// `unsafe fn` / `unsafe impl` / `unsafe trait` declarations are API
/// shape, not a block eliding a proof obligation at the use site.
fn is_unsafe_item(code: &str) -> bool {
    let Some(pos) = code.find("unsafe") else {
        return false;
    };
    let after = code[pos + "unsafe".len()..].trim_start();
    after.starts_with("fn ") || after.starts_with("impl ") || after.starts_with("trait ")
}

/// Word-boundary containment (so `unsafe` never matches `unsafety`).
fn has_word(code: &str, word: &str) -> bool {
    let mut from = 0;
    while let Some(pos) = code[from..].find(word) {
        let at = from + pos;
        let before_ok = at == 0
            || !code[..at]
                .chars()
                .next_back()
                .is_some_and(|c| c.is_ascii_alphanumeric() || c == '_');
        let after_ok = !code[at + word.len()..]
            .chars()
            .next()
            .is_some_and(|c| c.is_ascii_alphanumeric() || c == '_');
        if before_ok && after_ok {
            return true;
        }
        from = at + word.len();
    }
    false
}

/// `wire-tags`: tag bytes unique per direction, with directions read
/// from the `cmd_tag` / `msg_tag` classifier match arms.
fn wire_tags_rule(files: &[SourceFile], out: &mut Vec<Finding>) {
    let Some(wire) = files.iter().find(|f| f.path.ends_with("net/wire.rs")) else {
        return;
    };
    // TAG_* constant table: name -> (value, defining line).
    let mut consts: Vec<(String, u64, usize)> = Vec::new();
    for (no, line) in wire.numbered() {
        let code = line.code.trim();
        let Some(rest) = code.strip_prefix("const TAG_") else {
            continue;
        };
        let Some((head, value)) = rest.split_once('=') else {
            continue;
        };
        let name: String = head
            .chars()
            .take_while(|c| c.is_ascii_alphanumeric() || *c == '_')
            .collect();
        if let Some(v) = parse_int(value) {
            consts.push((format!("TAG_{name}"), v, no));
        }
    }
    if consts.is_empty() {
        out.push(Finding::new(
            &wire.path,
            1,
            "wire-tags",
            "no `const TAG_*` definitions found; the tag parser rotted",
        ));
        return;
    }
    // Directions: a line mentioning CmdTag:: (resp. MsgTag::) claims
    // every TAG_* identifier on it for that direction.
    for (marker, dir) in [("CmdTag::", "command"), ("MsgTag::", "message")] {
        let mut seen: Vec<(u64, &str, usize)> = Vec::new();
        for (no, line) in wire.numbered() {
            if !line.code.contains(marker) {
                continue;
            }
            for name in tag_idents(&line.code) {
                let Some((cname, value, _)) = consts.iter().find(|(n, _, _)| *n == name) else {
                    out.push(Finding::new(
                        &wire.path,
                        no,
                        "wire-tags",
                        format!("{dir} classifier references undefined `{name}`"),
                    ));
                    continue;
                };
                if let Some((_, other, _)) = seen.iter().find(|(v, _, _)| v == value) {
                    if *other != *cname {
                        out.push(Finding::new(
                            &wire.path,
                            no,
                            "wire-tags",
                            format!(
                                "{dir} tag byte {value:#04x} is claimed by both \
                                 `{other}` and `{cname}`"
                            ),
                        ));
                    }
                } else {
                    seen.push((*value, cname.as_str(), no));
                }
            }
        }
        if seen.is_empty() {
            out.push(Finding::new(
                &wire.path,
                1,
                "wire-tags",
                format!("no {dir} tags classified via `{marker}`; the direction parser rotted"),
            ));
        }
    }
}

/// All `TAG_*` identifiers on a code line.
fn tag_idents(code: &str) -> Vec<String> {
    let mut out = Vec::new();
    let mut from = 0;
    while let Some(pos) = code[from..].find("TAG_") {
        let at = from + pos;
        let boundary = at == 0
            || !code[..at]
                .chars()
                .next_back()
                .is_some_and(|c| c.is_ascii_alphanumeric() || c == '_');
        let name: String = code[at..]
            .chars()
            .take_while(|c| c.is_ascii_alphanumeric() || *c == '_')
            .collect();
        from = at + name.len().max(4);
        if boundary && name.len() > 4 {
            out.push(name);
        }
    }
    out
}

/// `wire-version`: the version constants in source agree with every
/// number ARCHITECTURE.md quotes next to their names (and the doc must
/// quote each constant that exists in the scan set at least once).
fn wire_version_rule(files: &[SourceFile], doc: Option<&str>, out: &mut Vec<Finding>) {
    for (name, file) in VERSIONS {
        let Some(src) = files.iter().find(|f| f.path == file) else {
            continue;
        };
        let mut defined: Option<(u64, usize)> = None;
        for (no, line) in src.numbered() {
            let code = line.code.trim();
            if code.contains("const ") && code.contains(name) && code.contains('=') {
                if let Some((_, value)) = code.split_once('=') {
                    if let Some(v) = parse_int(value) {
                        defined = Some((v, no));
                        break;
                    }
                }
            }
        }
        let Some((value, def_line)) = defined else {
            out.push(Finding::new(
                file,
                1,
                "wire-version",
                format!("`{name}` constant not found; the version parser rotted"),
            ));
            continue;
        };
        let Some(doc) = doc else {
            out.push(Finding::new(
                file,
                def_line,
                "wire-version",
                format!("ARCHITECTURE.md not found, cannot reconcile `{name}` = {value}"),
            ));
            continue;
        };
        let mut quoted = false;
        for (i, dline) in doc.lines().enumerate() {
            let Some(pos) = dline.find(name) else { continue };
            let Some(n) = first_int(&dline[pos + name.len()..]) else {
                continue;
            };
            quoted = true;
            if n != value {
                out.push(Finding::new(
                    "ARCHITECTURE.md",
                    i + 1,
                    "wire-version",
                    format!("quotes `{name}` = {n} but {file} defines {value}"),
                ));
            }
        }
        if !quoted {
            out.push(Finding::new(
                "ARCHITECTURE.md",
                1,
                "wire-version",
                format!("never quotes `{name}` (source value: {value}); document it"),
            ));
        }
    }
}

/// `wire-corpus`: every `ShardCmd` / `ShardMsg` variant name appears in
/// the transport corpus (snake_case or verbatim), so a new control
/// message cannot ship without corpus coverage.
fn wire_corpus_rule(files: &[SourceFile], out: &mut Vec<Finding>) {
    let Some(enums) = files.iter().find(|f| f.path == ENUM_FILE) else {
        return;
    };
    let Some(corpus) = files.iter().find(|f| f.path == CORPUS_FILE) else {
        out.push(Finding::new(
            ENUM_FILE,
            1,
            "wire-corpus",
            format!("`{CORPUS_FILE}` missing from the scan set"),
        ));
        return;
    };
    let hay: String = corpus
        .lines
        .iter()
        .flat_map(|l| [l.code.as_str(), "\n"])
        .collect::<String>()
        .to_ascii_lowercase();
    for enum_name in ["ShardCmd", "ShardMsg"] {
        let variants = enum_variants(enums, enum_name);
        if variants.is_empty() {
            out.push(Finding::new(
                ENUM_FILE,
                1,
                "wire-corpus",
                format!("`enum {enum_name}` not found; the variant parser rotted"),
            ));
            continue;
        }
        for (name, no) in variants {
            if enums.lines[no - 1].allows("wire-corpus") {
                continue;
            }
            let snake = camel_to_snake(&name);
            if !hay.contains(&snake) && !hay.contains(&name.to_ascii_lowercase()) {
                out.push(Finding::new(
                    ENUM_FILE,
                    no,
                    "wire-corpus",
                    format!("`{enum_name}::{name}` is not exercised by {CORPUS_FILE}"),
                ));
            }
        }
    }
}

/// Variant names of `enum <name>` with their 1-based lines, read off
/// brace depth (payload braces nest deeper than the variant list).
fn enum_variants(f: &SourceFile, name: &str) -> Vec<(String, usize)> {
    let header = format!("enum {name}");
    let mut out = Vec::new();
    let mut depth_in: Option<usize> = None;
    let mut depth = 0usize;
    for (no, line) in f.numbered() {
        let opens_here = depth_in.is_none() && line.code.contains(&header);
        if let Some(enum_depth) = depth_in {
            let code = line.code.trim();
            if depth == enum_depth + 1 {
                if let Some(first) = code.chars().next() {
                    if first.is_ascii_uppercase() {
                        let ident: String = code
                            .chars()
                            .take_while(|c| c.is_ascii_alphanumeric() || *c == '_')
                            .collect();
                        out.push((ident, no));
                    }
                }
            }
        }
        for c in line.code.chars() {
            match c {
                '{' => {
                    if opens_here && depth_in.is_none() {
                        depth_in = Some(depth);
                    }
                    depth += 1;
                }
                '}' => {
                    depth = depth.saturating_sub(1);
                    if depth_in == Some(depth) {
                        return out;
                    }
                }
                _ => {}
            }
        }
    }
    out
}

/// CamelCase → snake_case (`RoundDone` → `round_done`).
fn camel_to_snake(name: &str) -> String {
    let mut out = String::new();
    for (i, c) in name.chars().enumerate() {
        if c.is_ascii_uppercase() {
            if i > 0 {
                out.push('_');
            }
            out.push(c.to_ascii_lowercase());
        } else {
            out.push(c);
        }
    }
    out
}

/// First base-10 or `0x` integer in `s`, if any.
fn first_int(s: &str) -> Option<u64> {
    let bytes = s.as_bytes();
    let mut i = 0;
    while i < bytes.len() {
        if bytes[i].is_ascii_digit() {
            if bytes[i] == b'0' && bytes.get(i + 1).is_some_and(|b| *b == b'x' || *b == b'X') {
                let hex: String = s[i + 2..]
                    .chars()
                    .take_while(|c| c.is_ascii_hexdigit() || *c == '_')
                    .collect();
                return u64::from_str_radix(&hex.replace('_', ""), 16).ok();
            }
            let dec: String = s[i..]
                .chars()
                .take_while(|c| c.is_ascii_digit() || *c == '_')
                .collect();
            return dec.replace('_', "").parse().ok();
        }
        i += 1;
    }
    None
}

/// Parse an integer token like ` 0x11;` or ` 5;`.
fn parse_int(s: &str) -> Option<u64> {
    first_int(s.trim().trim_end_matches(';'))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::analysis::scanner::SourceFile;

    fn lint_one(path: &str, src: &str) -> Vec<Finding> {
        let (f, mut errs) = SourceFile::parse(path, src);
        errs.extend(lint_files(&[f], None));
        errs
    }

    fn rules_of(findings: &[Finding]) -> Vec<&'static str> {
        findings.iter().map(|f| f.rule).collect()
    }

    // -- clock ------------------------------------------------------------

    #[test]
    fn clock_rule_fires_outside_supervise() {
        let bad = lint_one("src/fl/mod.rs", "let t = Instant::now();\n");
        assert_eq!(rules_of(&bad), vec!["clock"]);
        assert_eq!(bad[0].line, 1);
    }

    #[test]
    fn clock_rule_spares_supervise_allows_and_strings() {
        assert!(lint_one("src/supervise.rs", "let t = Instant::now();\n").is_empty());
        assert!(lint_one(
            "src/fl/mod.rs",
            "// fsfl-lint: allow(clock): fixture wall-clock watchdog\n\
             let t = Instant::now();\n"
        )
        .is_empty());
        assert!(lint_one("src/fl/mod.rs", "let s = \"Instant::now()\";\n").is_empty());
    }

    // -- hot-alloc --------------------------------------------------------

    #[test]
    fn hot_alloc_fires_inside_fence_only() {
        let bad = lint_one(
            "src/fl/lane.rs",
            "// fsfl-lint: hot\nlet v = Vec::new();\n// fsfl-lint: end-hot\n",
        );
        assert_eq!(rules_of(&bad), vec!["hot-alloc"]);
        assert_eq!(bad[0].line, 2);
        assert!(lint_one("src/fl/lane.rs", "let v = Vec::new();\n").is_empty());
        assert!(lint_one(
            "src/fl/lane.rs",
            "// fsfl-lint: hot\nbuf.copy_from_slice(src);\n// fsfl-lint: end-hot\n"
        )
        .is_empty());
    }

    // -- panic ------------------------------------------------------------

    #[test]
    fn panic_rule_scopes_to_transport_planes_and_test_code() {
        let bad = lint_one("src/net/frame.rs", "let x = y.unwrap();\n");
        assert_eq!(rules_of(&bad), vec!["panic"]);
        // Same construct outside the scoped planes: clean.
        assert!(lint_one("src/fl/mod.rs", "let x = y.unwrap();\n").is_empty());
        // Inside #[cfg(test)]: clean.
        assert!(lint_one(
            "src/net/frame.rs",
            "#[cfg(test)]\nmod tests {\n    fn t() { y.unwrap(); }\n}\n"
        )
        .is_empty());
        // unwrap_or_else is not unwrap.
        assert!(lint_one("src/net/frame.rs", "let x = y.unwrap_or_else(f);\n").is_empty());
    }

    // -- safety -----------------------------------------------------------

    #[test]
    fn safety_rule_wants_a_safety_comment() {
        let bad = lint_one("src/runtime/step.rs", "let b = unsafe { f(p) };\n");
        assert_eq!(rules_of(&bad), vec!["safety"]);
        assert!(lint_one(
            "src/runtime/step.rs",
            "// SAFETY: p outlives b and the cast preserves size.\n\
             let b = unsafe { f(p) };\n"
        )
        .is_empty());
        // `unsafe fn` declarations are API shape, not use-site proof debt.
        assert!(lint_one("src/runtime/step.rs", "unsafe fn f() {}\n").is_empty());
    }

    // -- wire-tags --------------------------------------------------------

    const TAGS_OK: &str = "\
const TAG_A: u8 = 0x01;
const TAG_B: u8 = 0x02;
fn cmd_tag(p: &[u8]) {
    match p.first() {
        Some(&TAG_A) => Ok(CmdTag::A),
        Some(&TAG_B) => Ok(CmdTag::B),
        _ => Err(()),
    }
}
fn msg_tag(p: &[u8]) {
    match p.first() {
        Some(&TAG_A) => Ok(MsgTag::A),
        _ => Err(()),
    }
}
";

    #[test]
    fn wire_tags_accepts_unique_and_rejects_duplicate_bytes() {
        let (ok, _) = SourceFile::parse("src/net/wire.rs", TAGS_OK);
        assert!(lint_files(&[ok], None).is_empty());

        let dup = TAGS_OK.replace("const TAG_B: u8 = 0x02;", "const TAG_B: u8 = 0x01;");
        let (bad, _) = SourceFile::parse("src/net/wire.rs", &dup);
        let found = lint_files(&[bad], None);
        assert_eq!(rules_of(&found), vec!["wire-tags"], "{found:?}");
        assert!(found[0].message.contains("0x01"));
    }

    // -- wire-version -----------------------------------------------------

    fn session_src() -> SourceFile {
        SourceFile::parse("src/session/mod.rs", "pub const SNAPSHOT_VERSION: u8 = 4;\n").0
    }

    #[test]
    fn wire_version_reconciles_against_doc_quotes() {
        let good = "| `SNAPSHOT_VERSION` | 4 | session snapshot header |\n";
        let findings = lint_files(&[session_src()], Some(good));
        assert!(findings.is_empty(), "{findings:?}");

        let stale = "| `SNAPSHOT_VERSION` | 3 | session snapshot header |\n";
        let findings = lint_files(&[session_src()], Some(stale));
        assert_eq!(rules_of(&findings), vec!["wire-version"], "{findings:?}");
        assert!(findings[0].message.contains("quotes `SNAPSHOT_VERSION` = 3"));
    }

    #[test]
    fn wire_version_requires_a_doc_quote() {
        let findings = lint_files(&[session_src()], Some("no numbers here\n"));
        assert_eq!(rules_of(&findings), vec!["wire-version"]);
        assert!(findings[0].message.contains("never quotes"));
    }

    // -- wire-corpus ------------------------------------------------------

    const ENUMS: &str = "\
enum ShardCmd {
    Round { slots: Vec<usize> },
    Stop,
}
enum ShardMsg {
    RoundDone { shard: usize },
    // fsfl-lint: allow(wire-corpus): fixture-local, never crosses the wire
    LocalOnly { x: u64 },
}
";

    #[test]
    fn wire_corpus_checks_variant_coverage_with_escape() {
        let (enums, errs) = SourceFile::parse("src/coordinator/mod.rs", ENUMS);
        assert!(errs.is_empty(), "{errs:?}");
        let (corpus, _) = SourceFile::parse(
            "tests/integration_transport.rs",
            "fn corpus() { encode_round(); encode_stop(); encode_round_done(); }\n",
        );
        let findings = lint_files(&[enums, corpus], None);
        assert!(findings.is_empty(), "{findings:?}");

        // Drop round_done coverage: the variant surfaces, the escaped
        // LocalOnly still does not.
        let (enums, _) = SourceFile::parse("src/coordinator/mod.rs", ENUMS);
        let (thin, _) = SourceFile::parse(
            "tests/integration_transport.rs",
            "fn corpus() { encode_round(); encode_stop(); }\n",
        );
        let findings = lint_files(&[enums, thin], None);
        assert_eq!(rules_of(&findings), vec!["wire-corpus"], "{findings:?}");
        assert!(findings[0].message.contains("RoundDone"));
    }

    #[test]
    fn helpers_parse_what_the_rules_need() {
        assert_eq!(camel_to_snake("RoundDone"), "round_done");
        assert_eq!(camel_to_snake("Stop"), "stop");
        assert_eq!(first_int("| 5 |"), Some(5));
        assert_eq!(first_int(" = 0x16;"), Some(0x16));
        assert_eq!(first_int("no digits"), None);
        assert!(has_word("unsafe {", "unsafe"));
        assert!(!has_word("unsafety", "unsafe"));
        assert_eq!(tag_idents("Some(&TAG_READY) => Ok(MsgTag::Ready),"), vec!["TAG_READY"]);
    }
}
