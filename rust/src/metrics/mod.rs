//! Experiment metrics: per-round byte accounting, accuracy/F1, sparsity
//! and scale-factor statistics — everything the figure/table harnesses
//! print (Fig. 2–5, Tables 1–2).

use std::io::Write;

/// Scale-factor distribution snapshot for one layer (Fig. 3 series).
#[derive(Debug, Clone, PartialEq)]
pub struct ScaleStats {
    /// Layer name.
    pub layer: String,
    /// Smallest scale value.
    pub min: f32,
    /// 25th percentile.
    pub q25: f32,
    /// Median.
    pub median: f32,
    /// 75th percentile.
    pub q75: f32,
    /// Largest scale value.
    pub max: f32,
    /// Mean scale value.
    pub mean: f32,
    /// Fraction of scales suppressed toward zero (|s| < 0.1).
    pub suppressed: f32,
}

impl ScaleStats {
    /// Summarize one layer's scale values.
    pub fn from_values(layer: &str, values: &[f32]) -> Self {
        let mut v: Vec<f32> = values.to_vec();
        // total_cmp: a diverging run can produce NaN scale values, and
        // `partial_cmp(..).unwrap()` would panic the whole experiment on
        // the first one. Total order sorts NaNs to the ends instead.
        v.sort_by(|a, b| a.total_cmp(b));
        let q = |p: f64| -> f32 {
            if v.is_empty() {
                return 0.0;
            }
            let idx = ((v.len() - 1) as f64 * p).round() as usize;
            v[idx]
        };
        let mean = if v.is_empty() {
            0.0
        } else {
            v.iter().sum::<f32>() / v.len() as f32
        };
        let suppressed = if v.is_empty() {
            0.0
        } else {
            v.iter().filter(|&&x| x.abs() < 0.1).count() as f32 / v.len() as f32
        };
        Self {
            layer: layer.to_string(),
            min: v.first().copied().unwrap_or(0.0),
            q25: q(0.25),
            median: q(0.5),
            q75: q(0.75),
            max: v.last().copied().unwrap_or(0.0),
            mean,
            suppressed,
        }
    }
}

/// Binary-classification confusion counts (for the X-Ray task's F1).
#[derive(Debug, Clone, Copy, Default)]
pub struct Confusion {
    /// True positives.
    pub tp: usize,
    /// False positives.
    pub fp: usize,
    /// False negatives.
    pub fn_: usize,
    /// True negatives.
    pub tn: usize,
}

impl Confusion {
    /// Record one prediction against its label, with `positive` naming
    /// the positive class.
    pub fn add(&mut self, pred: usize, label: usize, positive: usize) {
        match (pred == positive, label == positive) {
            (true, true) => self.tp += 1,
            (true, false) => self.fp += 1,
            (false, true) => self.fn_ += 1,
            (false, false) => self.tn += 1,
        }
    }

    /// Binary F1 score (0.0 when undefined).
    pub fn f1(&self) -> f64 {
        let p = self.tp as f64 / (self.tp + self.fp).max(1) as f64;
        let r = self.tp as f64 / (self.tp + self.fn_).max(1) as f64;
        if p + r == 0.0 {
            0.0
        } else {
            2.0 * p * r / (p + r)
        }
    }

    /// Fraction of correct predictions.
    pub fn accuracy(&self) -> f64 {
        (self.tp + self.tn) as f64 / (self.tp + self.tn + self.fp + self.fn_).max(1) as f64
    }
}

/// One communication round's record.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct RoundMetrics {
    /// Round index t.
    pub round: usize,
    /// Upstream bytes (all clients → server), this round.
    pub up_bytes: usize,
    /// Downstream bytes (server → all clients), this round.
    pub down_bytes: usize,
    /// Central-model test accuracy after aggregation.
    pub accuracy: f64,
    /// Binary F1 (only meaningful for 2-class tasks).
    pub f1: f64,
    /// Central-model mean test loss.
    pub test_loss: f64,
    /// Mean client ΔW sparsity (zeros fraction) this round.
    pub update_sparsity: f64,
    /// Per-client ΔW sparsity (Fig. 4 plots both clients separately).
    pub client_sparsity: Vec<f64>,
    /// Mean fraction of filter rows skipped entirely.
    pub rows_skipped: f64,
    /// Rounds where at least one client kept its scale-factor update.
    pub scale_accepted: usize,
    /// Wall-clock milliseconds: weight training.
    pub train_ms: u128,
    /// Wall-clock milliseconds: scale-factor sub-epochs.
    pub scale_ms: u128,
    /// Per-layer scale statistics (scaled protocols only; Fig. 3).
    pub scale_stats: Vec<ScaleStats>,
}

/// Wire message classification, derived from a frame payload's leading
/// tag byte (see `net::wire`). Command and report variants of the same
/// concept collapse into one kind — direction (sent vs. received)
/// already disambiguates them: the coordinator *sends* `STATE` requests
/// and *receives* `STATE` reports.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum MsgKind {
    /// Session bootstrap (`INIT`).
    Init = 0,
    /// Round fan-out and broadcast payloads (`ROUND`).
    Round = 1,
    /// Aggregated-delta application (`APPLY`).
    Apply = 2,
    /// Orderly shutdown (`STOP`).
    Stop = 3,
    /// Client-state requests and reports (`STATE`/`STATE_MSG`).
    State = 4,
    /// Liveness probes and acks (`HEARTBEAT`/`HEARTBEAT_MSG`).
    Heartbeat = 5,
    /// Worker admission handshake (`READY`).
    Ready = 6,
    /// Per-round lane results (`ROUND_DONE`).
    RoundDone = 7,
    /// Evaluation reports (`EVAL`).
    Eval = 8,
    /// Worker-side failure reports (`FAILED`).
    Failed = 9,
    /// Unrecognized tag byte (forward-compat bucket).
    Other = 10,
}

impl MsgKind {
    /// Number of kinds (array dimension for per-kind accounting).
    pub const COUNT: usize = 11;

    /// Every kind, in index order.
    pub const ALL: [MsgKind; MsgKind::COUNT] = [
        MsgKind::Init,
        MsgKind::Round,
        MsgKind::Apply,
        MsgKind::Stop,
        MsgKind::State,
        MsgKind::Heartbeat,
        MsgKind::Ready,
        MsgKind::RoundDone,
        MsgKind::Eval,
        MsgKind::Failed,
        MsgKind::Other,
    ];

    /// Array index of this kind.
    pub fn index(self) -> usize {
        self as usize
    }

    /// Lowercase label used by metric-line and Prometheus exports.
    pub fn name(self) -> &'static str {
        match self {
            MsgKind::Init => "init",
            MsgKind::Round => "round",
            MsgKind::Apply => "apply",
            MsgKind::Stop => "stop",
            MsgKind::State => "state",
            MsgKind::Heartbeat => "heartbeat",
            MsgKind::Ready => "ready",
            MsgKind::RoundDone => "round_done",
            MsgKind::Eval => "eval",
            MsgKind::Failed => "failed",
            MsgKind::Other => "other",
        }
    }
}

/// Bytes actually moved over a shard transport, **measured at the frame
/// layer** (length prefix, checksum and payload included) rather than
/// estimated from bitstream lengths, attributed per [`MsgKind`] from
/// each frame's leading tag byte. Only populated by wire transports
/// (loopback/TCP); the in-process mpsc fan-in moves no bytes.
///
/// These are coordinator-side totals over the whole run: `sent_by_kind`
/// counts coordinator→shard traffic (round fan-out + broadcasts),
/// `received_by_kind` counts shard→coordinator traffic (lane bitstreams
/// + metrics). The old directional totals survive as the derived
/// [`sent`](WireStats::sent) / [`received`](WireStats::received) views.
/// The framing is deterministic, so for a fixed config the loopback and
/// TCP transports measure identical totals (pinned by
/// `tests/integration_transport.rs`).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct WireStats {
    /// Frame bytes sent coordinator → shards, indexed by
    /// [`MsgKind::index`].
    pub sent_by_kind: [u64; MsgKind::COUNT],
    /// Frame bytes received shards → coordinator, indexed by
    /// [`MsgKind::index`].
    pub received_by_kind: [u64; MsgKind::COUNT],
}

impl WireStats {
    /// Stats carrying only directional totals (attributed to
    /// [`MsgKind::Other`]) — for synthesizing fixtures and tests that
    /// don't care about per-kind attribution.
    pub fn from_totals(sent: u64, received: u64) -> Self {
        let mut s = Self::default();
        s.sent_by_kind[MsgKind::Other.index()] = sent;
        s.received_by_kind[MsgKind::Other.index()] = received;
        s
    }

    /// Total frame bytes sent coordinator → shards (derived view).
    pub fn sent(&self) -> u64 {
        self.sent_by_kind.iter().sum()
    }

    /// Total frame bytes received shards → coordinator (derived view).
    pub fn received(&self) -> u64 {
        self.received_by_kind.iter().sum()
    }

    /// Bytes sent for one message kind.
    pub fn sent_of(&self, kind: MsgKind) -> u64 {
        self.sent_by_kind[kind.index()]
    }

    /// Bytes received for one message kind.
    pub fn received_of(&self, kind: MsgKind) -> u64 {
        self.received_by_kind[kind.index()]
    }

    /// Sum of both directions.
    pub fn total(&self) -> u64 {
        self.sent() + self.received()
    }
}

/// What happened to a shard, as recorded by the supervisor plane.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum ShardEventKind {
    /// The shard's connection died or its lease/deadline expired.
    Death {
        /// Human-readable cause (reader error, lease expiry, …).
        reason: String,
    },
    /// A replacement worker was admitted and rehydrated.
    Respawned {
        /// 1-based respawn attempt that succeeded.
        attempt: usize,
    },
    /// Retry budget exhausted: the shard's clients were folded into
    /// survivors (quorum mode).
    Degraded {
        /// Clients reassigned away from the dead shard, in id order.
        clients: Vec<usize>,
    },
}

/// One supervisor-plane incident: round it happened in, shard it
/// happened to, and what the recovery machine did about it.
///
/// Deliberately *not* part of [`RoundMetrics`]: round records stay
/// byte-identical between a recovered run and an undisturbed one; the
/// incident history rides alongside, like [`WireStats`].
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ShardEvent {
    /// Round index the incident occurred in.
    pub round: usize,
    /// Shard index it concerned.
    pub shard: usize,
    /// What happened.
    pub kind: ShardEventKind,
}

/// Full experiment log: what all figure harnesses consume.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct RunLog {
    /// Experiment name (from the config).
    pub name: String,
    /// One record per completed round.
    pub rounds: Vec<RoundMetrics>,
    /// Measured transport traffic (wire deployments only, `None` for the
    /// in-process paths). Deliberately *not* part of the per-round
    /// metrics: round records stay byte-identical across transports.
    pub wire: Option<WireStats>,
    /// Supervisor-plane incident history (shard deaths, respawns,
    /// degradations). Empty for an undisturbed run; excluded from the
    /// CSV so recovered runs stay byte-identical there too.
    pub events: Vec<ShardEvent>,
}

impl RunLog {
    /// Empty log for a named experiment.
    pub fn new(name: impl Into<String>) -> Self {
        Self {
            name: name.into(),
            rounds: Vec::new(),
            wire: None,
            events: Vec::new(),
        }
    }

    /// Append one round's record.
    pub fn push(&mut self, m: RoundMetrics) {
        self.rounds.push(m);
    }

    /// Cumulative transmitted bytes up to and including round `i`
    /// (`up_only` reproduces Table 2's upstream-only accounting).
    pub fn cumulative_bytes(&self, i: usize, up_only: bool) -> usize {
        self.rounds[..=i]
            .iter()
            .map(|r| r.up_bytes + if up_only { 0 } else { r.down_bytes })
            .sum()
    }

    /// Total transmitted bytes over the whole run.
    pub fn total_bytes(&self, up_only: bool) -> usize {
        if self.rounds.is_empty() {
            0
        } else {
            self.cumulative_bytes(self.rounds.len() - 1, up_only)
        }
    }

    /// Best central-model accuracy over all rounds.
    pub fn best_accuracy(&self) -> f64 {
        self.rounds.iter().map(|r| r.accuracy).fold(0.0, f64::max)
    }

    /// First round reaching `target` accuracy, with cumulative bytes there
    /// (Table 2's `Σ data @ t` readout). None if never reached.
    pub fn reached(&self, target: f64, up_only: bool) -> Option<(usize, usize)> {
        self.rounds
            .iter()
            .position(|r| r.accuracy >= target)
            .map(|i| (self.rounds[i].round, self.cumulative_bytes(i, up_only)))
    }

    /// Compact single-token rendering of the incident history for
    /// machine-readable log lines (the bench plane's metric stream and
    /// the golden-output fixtures): `D{round}s{shard}` for a death,
    /// `R{round}s{shard}a{attempt}` for a respawn,
    /// `G{round}s{shard}c{id+id+…}` for a degradation, joined by `;`;
    /// `-` when the run was undisturbed. Contains no spaces by
    /// construction, so it survives `key=value` line formats.
    pub fn events_compact(&self) -> String {
        if self.events.is_empty() {
            return "-".to_string();
        }
        let toks: Vec<String> = self
            .events
            .iter()
            .map(|e| match &e.kind {
                ShardEventKind::Death { .. } => format!("D{}s{}", e.round, e.shard),
                ShardEventKind::Respawned { attempt } => {
                    format!("R{}s{}a{attempt}", e.round, e.shard)
                }
                ShardEventKind::Degraded { clients } => {
                    let ids: Vec<String> = clients.iter().map(|c| c.to_string()).collect();
                    format!("G{}s{}c{}", e.round, e.shard, ids.join("+"))
                }
            })
            .collect();
        toks.join(";")
    }

    /// Write the per-round records as a CSV file.
    pub fn write_csv(&self, path: impl AsRef<std::path::Path>) -> anyhow::Result<()> {
        let mut f = std::io::BufWriter::new(std::fs::File::create(path)?);
        writeln!(
            f,
            "round,up_bytes,down_bytes,cum_bytes,accuracy,f1,test_loss,update_sparsity,rows_skipped,train_ms,scale_ms"
        )?;
        for (i, r) in self.rounds.iter().enumerate() {
            writeln!(
                f,
                "{},{},{},{},{:.4},{:.4},{:.4},{:.4},{:.4},{},{}",
                r.round,
                r.up_bytes,
                r.down_bytes,
                self.cumulative_bytes(i, false),
                r.accuracy,
                r.f1,
                r.test_loss,
                r.update_sparsity,
                r.rows_skipped,
                r.train_ms,
                r.scale_ms
            )?;
        }
        Ok(())
    }
}

/// Pretty-print helper for byte counts.
pub fn fmt_bytes(b: usize) -> String {
    if b >= 1 << 20 {
        format!("{:.2} MB", b as f64 / (1 << 20) as f64)
    } else if b >= 1 << 10 {
        format!("{:.2} KB", b as f64 / (1 << 10) as f64)
    } else {
        format!("{b} B")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn f1_perfect_and_degenerate() {
        let mut c = Confusion::default();
        c.add(0, 0, 0);
        c.add(1, 1, 0);
        assert_eq!(c.f1(), 1.0);
        let z = Confusion::default();
        assert_eq!(z.f1(), 0.0);
    }

    #[test]
    fn scale_stats_quartiles() {
        let vals: Vec<f32> = (0..101).map(|i| i as f32 / 100.0).collect();
        let s = ScaleStats::from_values("l", &vals);
        assert_eq!(s.min, 0.0);
        assert_eq!(s.max, 1.0);
        assert!((s.median - 0.5).abs() < 1e-6);
        assert!((s.q25 - 0.25).abs() < 1e-6);
        assert!((s.suppressed - 0.1).abs() < 0.02);
    }

    #[test]
    fn scale_stats_survive_nan_values() {
        // Regression: a diverging run produces NaN scales; from_values
        // used partial_cmp().unwrap() and panicked. total_cmp sorts NaN
        // to the ends and the summary stays well-defined for the finite
        // slots.
        let vals = vec![0.5f32, f32::NAN, 0.1, 0.9, f32::NAN];
        let s = ScaleStats::from_values("l", &vals);
        assert_eq!(s.min, 0.1, "finite minimum survives NaN neighbours");
        assert!(s.max.is_nan(), "positive NaN sorts last under total order");
        assert!(s.layer == "l");
        // all-NaN input: still no panic
        let s = ScaleStats::from_values("l", &[f32::NAN, f32::NAN]);
        assert!(s.min.is_nan() && s.max.is_nan());
    }

    #[test]
    fn events_compact_renders_every_kind_and_the_empty_case() {
        let mut log = RunLog::new("t");
        assert_eq!(log.events_compact(), "-");
        log.events = vec![
            ShardEvent {
                round: 3,
                shard: 0,
                kind: ShardEventKind::Death { reason: "lease expired".into() },
            },
            ShardEvent {
                round: 3,
                shard: 0,
                kind: ShardEventKind::Respawned { attempt: 2 },
            },
            ShardEvent {
                round: 3,
                shard: 0,
                kind: ShardEventKind::Degraded { clients: vec![0, 2, 4] },
            },
        ];
        let s = log.events_compact();
        assert_eq!(s, "D3s0;R3s0a2;G3s0c0+2+4");
        assert!(!s.contains(' '), "must survive key=value line formats");
    }

    #[test]
    fn msg_kind_indexing_and_wire_stat_views_agree() {
        // ALL must enumerate every kind exactly once, in index order —
        // the per-kind arrays and every exporter iterate it.
        for (i, k) in MsgKind::ALL.iter().enumerate() {
            assert_eq!(k.index(), i);
        }
        assert_eq!(MsgKind::ALL.len(), MsgKind::COUNT);
        let mut w = WireStats::default();
        w.sent_by_kind[MsgKind::Round.index()] = 100;
        w.sent_by_kind[MsgKind::Apply.index()] = 50;
        w.received_by_kind[MsgKind::RoundDone.index()] = 70;
        assert_eq!(w.sent(), 150);
        assert_eq!(w.received(), 70);
        assert_eq!(w.total(), 220);
        assert_eq!(w.sent_of(MsgKind::Round), 100);
        assert_eq!(w.received_of(MsgKind::Round), 0);
        let t = WireStats::from_totals(9, 11);
        assert_eq!((t.sent(), t.received()), (9, 11));
        assert_eq!(t.sent_of(MsgKind::Other), 9);
    }

    #[test]
    fn runlog_reached() {
        let mut log = RunLog::new("t");
        for i in 0..5 {
            log.push(RoundMetrics {
                round: i,
                up_bytes: 100,
                down_bytes: 50,
                accuracy: 0.1 * i as f64,
                ..Default::default()
            });
        }
        let (round, bytes) = log.reached(0.25, true).unwrap();
        assert_eq!(round, 3);
        assert_eq!(bytes, 400);
        assert_eq!(log.reached(0.9, true), None);
        assert_eq!(log.total_bytes(false), 750);
    }
}
