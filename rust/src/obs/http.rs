//! Minimal Prometheus text-exposition endpoint on `std::net`
//! (`fsfl serve --metrics-addr HOST:PORT`).
//!
//! Hand-rolled on purpose: one nonblocking accept loop on a background
//! thread, a just-enough GET parser, `Connection: close` semantics.
//! The endpoint is read-only over the [`Telemetry`] registry — a
//! scraper can never perturb the run.

use std::io::{Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::Duration;

use anyhow::{anyhow, Result};

use super::Telemetry;

/// Accept-loop poll quantum while idle (no pending connection).
const POLL: Duration = Duration::from_millis(25);

/// Cap on request bytes read before answering (headers are discarded,
/// only the request line matters).
const MAX_REQUEST: usize = 8 * 1024;

/// A running metrics endpoint: background accept thread + stop flag.
/// Shut down explicitly with [`MetricsServer::shutdown`] or implicitly
/// on drop.
pub struct MetricsServer {
    addr: SocketAddr,
    stop: Arc<AtomicBool>,
    handle: Option<std::thread::JoinHandle<()>>,
}

impl MetricsServer {
    /// Bind `addr` (e.g. `127.0.0.1:9464`, port 0 for ephemeral) and
    /// start serving `telemetry`'s registry at `/metrics` (and `/`).
    pub fn bind(addr: &str, telemetry: Arc<Telemetry>) -> Result<Self> {
        let listener = TcpListener::bind(addr)
            .map_err(|e| anyhow!("metrics endpoint failed to bind {addr}: {e}"))?;
        listener
            .set_nonblocking(true)
            .map_err(|e| anyhow!("metrics endpoint nonblocking mode failed: {e}"))?;
        let local = listener
            .local_addr()
            .map_err(|e| anyhow!("metrics endpoint local_addr failed: {e}"))?;
        let stop = Arc::new(AtomicBool::new(false));
        let stop_flag = stop.clone();
        let handle = std::thread::Builder::new()
            .name("fsfl-metrics".into())
            .spawn(move || {
                while !stop_flag.load(Ordering::Relaxed) {
                    match listener.accept() {
                        Ok((stream, _)) => {
                            // Serve inline: scrapes are tiny and rare,
                            // a per-connection thread buys nothing.
                            let _ = handle_conn(stream, &telemetry);
                        }
                        Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                            std::thread::sleep(POLL);
                        }
                        Err(_) => std::thread::sleep(POLL),
                    }
                }
            })
            .map_err(|e| anyhow!("metrics endpoint thread spawn failed: {e}"))?;
        Ok(Self {
            addr: local,
            stop,
            handle: Some(handle),
        })
    }

    /// The bound address (resolves port 0 to the ephemeral port).
    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    /// Stop the accept loop and join the serving thread.
    pub fn shutdown(mut self) {
        self.stop_and_join();
    }

    fn stop_and_join(&mut self) {
        self.stop.store(true, Ordering::Relaxed);
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

impl Drop for MetricsServer {
    fn drop(&mut self) {
        self.stop_and_join();
    }
}

/// Answer one connection: parse the request line, route, respond,
/// close.
fn handle_conn(mut stream: TcpStream, telemetry: &Telemetry) -> Result<()> {
    stream.set_nonblocking(false).ok();
    stream
        .set_read_timeout(Some(Duration::from_secs(2)))
        .ok();
    let mut buf = Vec::with_capacity(512);
    let mut chunk = [0u8; 512];
    // Read until the header terminator (the body, if any, is ignored).
    while !buf.windows(4).any(|w| w == b"\r\n\r\n") && buf.len() < MAX_REQUEST {
        match stream.read(&mut chunk) {
            Ok(0) => break,
            Ok(n) => buf.extend_from_slice(&chunk[..n]),
            Err(_) => break,
        }
    }
    let request = String::from_utf8_lossy(&buf);
    let mut line = request.lines().next().unwrap_or("").split_whitespace();
    let (method, path) = (line.next().unwrap_or(""), line.next().unwrap_or(""));
    let (status, content_type, body) = if method != "GET" {
        (
            "405 Method Not Allowed",
            "text/plain",
            "method not allowed\n".to_string(),
        )
    } else if path == "/metrics" || path == "/" {
        (
            "200 OK",
            "text/plain; version=0.0.4",
            telemetry
                .metrics
                .render_prometheus(telemetry.dropped_spans()),
        )
    } else {
        ("404 Not Found", "text/plain", "not found\n".to_string())
    };
    let response = format!(
        "HTTP/1.1 {status}\r\nContent-Type: {content_type}\r\nContent-Length: {}\r\nConnection: close\r\n\r\n{body}",
        body.len()
    );
    stream
        .write_all(response.as_bytes())
        .map_err(|e| anyhow!("metrics response write failed: {e}"))?;
    stream.flush().ok();
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::supervise::MonotonicClock;

    fn scrape(addr: SocketAddr, request: &str) -> String {
        let mut s = TcpStream::connect(addr).unwrap();
        s.write_all(request.as_bytes()).unwrap();
        let mut out = String::new();
        s.read_to_string(&mut out).unwrap();
        out
    }

    #[test]
    fn serves_metrics_and_404s_unknown_paths() {
        let t = Telemetry::new(Arc::new(MonotonicClock::new()), false);
        t.metrics.rounds_total.store(7, Ordering::Relaxed);
        let server = MetricsServer::bind("127.0.0.1:0", t).unwrap();
        let addr = server.addr();
        let ok = scrape(addr, "GET /metrics HTTP/1.1\r\nHost: x\r\n\r\n");
        assert!(ok.starts_with("HTTP/1.1 200 OK\r\n"), "got: {ok}");
        assert!(ok.contains("text/plain; version=0.0.4"));
        assert!(ok.contains("fsfl_rounds_total 7"));
        let missing = scrape(addr, "GET /nope HTTP/1.1\r\n\r\n");
        assert!(missing.starts_with("HTTP/1.1 404"));
        let bad = scrape(addr, "POST /metrics HTTP/1.1\r\n\r\n");
        assert!(bad.starts_with("HTTP/1.1 405"));
        server.shutdown();
    }
}
