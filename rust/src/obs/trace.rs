//! Ring-buffered span storage for the telemetry plane.
//!
//! Spans are recorded from many threads (the coordinator control loop,
//! mpsc shard threads, codec worker pools, wire reader threads), so the
//! sink is striped: each recording thread is assigned one of a fixed
//! set of stripes on first use and only ever locks that stripe. Every
//! stripe is a `Vec` with its full capacity pre-allocated, so recording
//! a span never allocates on the hot path — when a stripe is full,
//! further spans are counted as dropped instead of growing the buffer.
//!
//! Determinism contract: a span's *identity* is its rendered fields
//! (`ts_ns`, `dur_ns`, `track`, `name`, `round`, `unit`, `bytes`) —
//! which stripe it landed in and in what order is scheduling noise that
//! the exporters erase with a canonical total sort (see
//! [`super::chrome`]). Under a zero-tick
//! [`ScriptedClock`](crate::supervise::ScriptedClock) every timestamp
//! is zero and the span *multiset* is a pure function of the config, so
//! two runs export byte-identical traces.

use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::Mutex;

/// Number of independently-locked span buffers. Recording threads are
/// assigned round-robin, so contention stays negligible for the thread
/// counts the coordinator actually spawns.
const STRIPES: usize = 8;

/// Span capacity of one stripe. Pre-allocated up front; a full stripe
/// drops further spans (counted) rather than allocating.
const STRIPE_CAP: usize = 1 << 14;

/// One completed span (or instant event, when `dur_ns == 0` carries no
/// meaning for the name). Names and tracks are `&'static str` by
/// design: recording a span moves no owned data and allocates nothing.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Span {
    /// Start timestamp, nanoseconds since the run clock's epoch.
    pub ts_ns: u64,
    /// Duration in nanoseconds (0 for instant events).
    pub dur_ns: u64,
    /// Track (exporter lane) this span belongs to; one of
    /// [`crate::obs::track::ALL`].
    pub track: &'static str,
    /// Stage name, e.g. `"codec.encode_w"` or `"net.send.round"`.
    pub name: &'static str,
    /// Round index the span belongs to (-1 when outside any round).
    pub round: i64,
    /// Deterministic sub-unit key: client id for codec stages, shard
    /// index for fan-in/incident spans, -1 when not applicable.
    pub unit: i64,
    /// Byte count attributed to the span (-1 when not applicable).
    pub bytes: i64,
}

/// Striped, fixed-capacity span sink. See the module docs for the
/// recording and determinism contracts.
pub struct TraceSink {
    stripes: Vec<Mutex<Vec<Span>>>,
    dropped: AtomicU64,
}

/// Round-robin stripe assignment for recording threads.
static NEXT_STRIPE: AtomicUsize = AtomicUsize::new(0);

thread_local! {
    static MY_STRIPE: usize = NEXT_STRIPE.fetch_add(1, Ordering::Relaxed) % STRIPES;
}

impl TraceSink {
    /// A sink with every stripe's capacity pre-allocated.
    pub fn new() -> Self {
        Self {
            stripes: (0..STRIPES)
                .map(|_| Mutex::new(Vec::with_capacity(STRIPE_CAP)))
                .collect(),
            dropped: AtomicU64::new(0),
        }
    }

    /// Record one span. Allocation-free: pushes into the recording
    /// thread's pre-allocated stripe, or bumps the dropped counter when
    /// that stripe is full (never blocks on another thread's stripe).
    pub fn record(&self, span: Span) {
        let stripe = MY_STRIPE.with(|&s| s);
        let Ok(mut buf) = self.stripes[stripe].lock() else {
            self.dropped.fetch_add(1, Ordering::Relaxed);
            return;
        };
        if buf.len() < STRIPE_CAP {
            buf.push(span);
        } else {
            self.dropped.fetch_add(1, Ordering::Relaxed);
        }
    }

    /// Move every recorded span out (stripe order, which is *not*
    /// canonical — exporters must sort). The sink is reusable after.
    pub fn drain(&self) -> Vec<Span> {
        let mut out = Vec::new();
        for s in &self.stripes {
            if let Ok(mut buf) = s.lock() {
                out.append(&mut buf);
                // append leaves the allocation in place only for `out`;
                // restore the stripe's no-alloc recording guarantee.
                buf.reserve(STRIPE_CAP);
            }
        }
        out
    }

    /// Spans discarded because their stripe was full.
    pub fn dropped(&self) -> u64 {
        self.dropped.load(Ordering::Relaxed)
    }
}

impl Default for TraceSink {
    fn default() -> Self {
        Self::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn span(name: &'static str, unit: i64) -> Span {
        Span {
            ts_ns: 0,
            dur_ns: 0,
            track: "codec",
            name,
            round: 0,
            unit,
            bytes: -1,
        }
    }

    #[test]
    fn records_and_drains_across_threads() {
        let sink = std::sync::Arc::new(TraceSink::new());
        let handles: Vec<_> = (0..4)
            .map(|i| {
                let s = sink.clone();
                std::thread::spawn(move || {
                    for _ in 0..100 {
                        s.record(span("t", i));
                    }
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
        let spans = sink.drain();
        assert_eq!(spans.len(), 400);
        assert_eq!(sink.dropped(), 0);
        // drained: the sink is empty and reusable
        assert!(sink.drain().is_empty());
        sink.record(span("again", 0));
        assert_eq!(sink.drain().len(), 1);
    }

    #[test]
    fn full_stripe_counts_drops_instead_of_growing() {
        let sink = TraceSink::new();
        // All from one thread → one stripe; overfill it.
        for _ in 0..(STRIPE_CAP + 10) {
            sink.record(span("x", 0));
        }
        assert_eq!(sink.dropped(), 10);
        assert_eq!(sink.drain().len(), STRIPE_CAP);
    }
}
