//! Chrome-trace / Perfetto JSON exporter (`fsfl run --trace-out`).
//!
//! Emits the "JSON Array Format" every Chrome-descended trace viewer
//! reads: one complete-duration (`"ph": "X"`) event per span, one
//! virtual thread (`tid`) per telemetry track, timestamps in
//! microseconds. The document is also valid input for the repo's own
//! strict [`crate::bench::json`] reader — the CI `obs` job gates on
//! that round-trip.
//!
//! **Canonical order.** Span arrival order is scheduling noise (striped
//! sink, worker pools), so the exporter totally sorts the fully
//! rendered span tuples before writing. Two runs that record the same
//! span *multiset* therefore export byte-identical documents — the
//! golden-fixture contract in `tests/integration_obs.rs`.

use super::track;
use super::trace::Span;

/// Stable `tid` for a track name (its position in [`track::ALL`];
/// unknown tracks sort after the known ones).
fn track_tid(t: &str) -> usize {
    track::ALL.iter().position(|&k| k == t).unwrap_or(track::ALL.len())
}

/// Microsecond rendering of a nanosecond count, at fixed nanosecond
/// resolution (three decimals) so formatting never depends on the
/// magnitude.
fn us(ns: u64) -> String {
    format!("{}.{:03}", ns / 1000, ns % 1000)
}

/// Render a complete Chrome-trace JSON document from `spans` (order
/// irrelevant — see the module docs) plus the sink's dropped-span
/// count. One event per line for diffable fixtures.
pub fn render(spans: &[Span], dropped: u64) -> String {
    let mut sorted: Vec<&Span> = spans.iter().collect();
    sorted.sort_by_key(|s| {
        (
            track_tid(s.track),
            s.ts_ns,
            s.dur_ns,
            s.name,
            s.round,
            s.unit,
            s.bytes,
        )
    });
    let mut out = String::with_capacity(256 + sorted.len() * 128);
    out.push_str("{\n\"schema\": \"fsfl-trace\",\n\"v\": 1,\n\"displayTimeUnit\": \"ms\",\n");
    out.push_str(&format!(
        "\"otherData\": {{\"dropped_spans\": {dropped}}},\n\"traceEvents\": [\n"
    ));
    let mut first = true;
    let mut push_event = |line: String, out: &mut String| {
        if !first {
            out.push_str(",\n");
        }
        first = false;
        out.push_str(&line);
    };
    for (tid, name) in track::ALL.iter().enumerate() {
        push_event(
            format!(
                "{{\"ph\": \"M\", \"pid\": 0, \"tid\": {tid}, \"name\": \"thread_name\", \"args\": {{\"name\": \"{name}\"}}}}"
            ),
            &mut out,
        );
    }
    for s in sorted {
        push_event(
            format!(
                "{{\"ph\": \"X\", \"pid\": 0, \"tid\": {}, \"ts\": {}, \"dur\": {}, \"name\": \"{}\", \"args\": {{\"round\": {}, \"unit\": {}, \"bytes\": {}}}}}",
                track_tid(s.track),
                us(s.ts_ns),
                us(s.dur_ns),
                s.name,
                s.round,
                s.unit,
                s.bytes
            ),
            &mut out,
        );
    }
    out.push_str("\n]\n}\n");
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn span(track: &'static str, name: &'static str, ts: u64, unit: i64) -> Span {
        Span {
            ts_ns: ts,
            dur_ns: 500,
            track,
            name,
            round: 1,
            unit,
            bytes: -1,
        }
    }

    #[test]
    fn export_is_order_invariant_and_parses_strictly() {
        let a = vec![
            span(track::CODEC, "codec.encode_w", 2000, 0),
            span(track::COORDINATOR, "round", 0, -1),
            span(track::CODEC, "codec.encode_w", 1000, 1),
        ];
        let mut b = a.clone();
        b.reverse();
        let ra = render(&a, 0);
        let rb = render(&b, 0);
        assert_eq!(ra, rb, "canonical sort must erase arrival order");
        let doc = crate::bench::json::parse(&ra).expect("strict reader must accept the trace");
        assert_eq!(
            doc.get("schema").and_then(|v| v.as_str()),
            Some("fsfl-trace")
        );
        let events = doc.get("traceEvents").and_then(|v| v.as_arr()).unwrap();
        // 5 thread-name metadata events + 3 spans
        assert_eq!(events.len(), 8);
        let x = &events[5]; // first span: coordinator track (tid 0)
        assert_eq!(x.get("name").and_then(|v| v.as_str()), Some("round"));
        assert_eq!(x.get("ts").and_then(|v| v.as_f64()), Some(0.0));
        assert_eq!(x.get("dur").and_then(|v| v.as_f64()), Some(0.5));
        let args = x.get("args").unwrap();
        assert_eq!(args.get("round").and_then(|v| v.as_f64()), Some(1.0));
        assert_eq!(args.get("bytes").and_then(|v| v.as_f64()), Some(-1.0));
    }

    #[test]
    fn microsecond_rendering_is_fixed_resolution() {
        assert_eq!(us(0), "0.000");
        assert_eq!(us(1), "0.001");
        assert_eq!(us(1000), "1.000");
        assert_eq!(us(1_234_567), "1234.567");
    }
}
