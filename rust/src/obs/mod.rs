//! Observability plane: deterministic span tracing, a live metrics
//! registry, and the exporters that serve them.
//!
//! Everything here is dependency-free and strictly *passive*: a
//! [`Telemetry`] handle is runtime-only state (never part of
//! [`ExperimentConfig`](crate::fl::ExperimentConfig), never serialized
//! over the wire), it only ever *reads* the run, and with telemetry off
//! the instrumented hot paths reduce to an `Option` check — zero
//! allocation, zero atomics. With telemetry on, `RunLog.rounds`, wire
//! bytes and CSV output stay byte-identical to a telemetry-off run
//! (pinned by `tests/integration_transport.rs` /
//! `tests/integration_tree.rs`).
//!
//! * [`trace`] — striped, pre-allocated span sink ([`TraceSink`]).
//! * [`registry`] — atomic counters/gauges ([`MetricsRegistry`]).
//! * [`chrome`] — Chrome-trace/Perfetto JSON exporter with a canonical
//!   total sort (byte-stable output).
//! * [`http`] — hand-rolled Prometheus text endpoint on `std::net`
//!   (`fsfl serve --metrics-addr`).
//! * [`summarize`] — browserless trace inspection
//!   (`fsfl trace summarize FILE`).
//!
//! Timestamps come from the run's [`supervise::Clock`](crate::supervise::Clock):
//! under a zero-tick [`ScriptedClock`](crate::supervise::ScriptedClock)
//! every span lands at t=0 and the exported trace is a pure function of
//! the config — rerunning reproduces it byte for byte.

pub mod chrome;
pub mod http;
pub mod registry;
pub mod summarize;
pub mod trace;

use std::sync::atomic::{AtomicI64, Ordering};
use std::sync::Arc;

use crate::metrics::{MsgKind, ShardEvent, ShardEventKind};
use crate::supervise::Clock;

pub use http::MetricsServer;
pub use registry::MetricsRegistry;
pub use trace::{Span, TraceSink};

/// Span track names: the fixed exporter lanes. One track per plane, so
/// a Perfetto view groups rounds, codec stages, wire traffic, session
/// I/O and supervisor incidents into separate swimlanes.
pub mod track {
    /// Coordinator control loop: rounds, fan-in/eval waits, apply.
    pub const COORDINATOR: &str = "coordinator";
    /// Per-client compute + codec stages (train, scale, encode, finish).
    pub const CODEC: &str = "codec";
    /// Frame-layer sends/receives.
    pub const NET: &str = "net";
    /// Session plane: checkpoint writes, cold-state pager traffic.
    pub const SESSION: &str = "session";
    /// Supervisor incidents (deaths, respawns, degradations).
    pub const SUPERVISOR: &str = "supervisor";

    /// Every track, in canonical (exporter tid) order.
    pub const ALL: [&str; 5] = [COORDINATOR, CODEC, NET, SESSION, SUPERVISOR];
}

/// Static `net.send.<kind>` span name for a message kind (span names
/// must be `&'static str` so recording never allocates).
pub fn net_send_name(kind: MsgKind) -> &'static str {
    match kind {
        MsgKind::Init => "net.send.init",
        MsgKind::Round => "net.send.round",
        MsgKind::Apply => "net.send.apply",
        MsgKind::Stop => "net.send.stop",
        MsgKind::State => "net.send.state",
        MsgKind::Heartbeat => "net.send.heartbeat",
        MsgKind::Ready => "net.send.ready",
        MsgKind::RoundDone => "net.send.round_done",
        MsgKind::Eval => "net.send.eval",
        MsgKind::Failed => "net.send.failed",
        MsgKind::Other => "net.send.other",
    }
}

/// Static `net.recv.<kind>` span name for a message kind.
pub fn net_recv_name(kind: MsgKind) -> &'static str {
    match kind {
        MsgKind::Init => "net.recv.init",
        MsgKind::Round => "net.recv.round",
        MsgKind::Apply => "net.recv.apply",
        MsgKind::Stop => "net.recv.stop",
        MsgKind::State => "net.recv.state",
        MsgKind::Heartbeat => "net.recv.heartbeat",
        MsgKind::Ready => "net.recv.ready",
        MsgKind::RoundDone => "net.recv.round_done",
        MsgKind::Eval => "net.recv.eval",
        MsgKind::Failed => "net.recv.failed",
        MsgKind::Other => "net.recv.other",
    }
}

/// Optional telemetry handle, as threaded through the coordinator's
/// runtime plumbing. `None` (the default everywhere) means every
/// instrumentation site is a single branch — no clock reads, no
/// atomics, no allocation.
pub type Obs = Option<Arc<Telemetry>>;

/// One run's telemetry: the clock that timestamps spans, an optional
/// trace sink, the live metrics registry, and the current-round cell
/// that attributes spans recorded off the control thread.
///
/// Shared by `Arc` across the coordinator, mpsc shard threads, codec
/// worker pools and coordinator-side wire endpoints. Rounds are
/// barriered (fan-out → fan-in → apply → eval), so a relaxed
/// read of the round cell from any participating thread is
/// deterministic.
pub struct Telemetry {
    clock: Arc<dyn Clock>,
    trace: Option<TraceSink>,
    /// Live counters/gauges; rendered by [`MetricsServer`].
    pub metrics: MetricsRegistry,
    round: AtomicI64,
    /// High-water mark of `RunLog.events` already folded into the
    /// registry (see [`Telemetry::bridge_events`]).
    bridged: AtomicI64,
}

impl Telemetry {
    /// A telemetry handle on `clock`. `tracing` enables the span sink;
    /// without it only the registry is live (the `--metrics-addr`-only
    /// configuration).
    pub fn new(clock: Arc<dyn Clock>, tracing: bool) -> Arc<Self> {
        Arc::new(Self {
            clock,
            trace: tracing.then(TraceSink::new),
            metrics: MetricsRegistry::default(),
            round: AtomicI64::new(-1),
            bridged: AtomicI64::new(0),
        })
    }

    /// Nanoseconds on the run clock (span timestamp source).
    pub fn now_ns(&self) -> u64 {
        self.clock.now().as_nanos() as u64
    }

    /// Set the round index subsequent spans are attributed to. Called
    /// by the coordinator at the top of each round (and by the
    /// single-thread experiment loop).
    pub fn set_round(&self, round: i64) {
        self.round.store(round, Ordering::Relaxed);
    }

    /// Round index spans are currently attributed to (-1 outside any
    /// round).
    pub fn round(&self) -> i64 {
        self.round.load(Ordering::Relaxed)
    }

    /// Record a span that started at `start_ns` and ends now. No-op
    /// without a trace sink. `unit` is the deterministic sub-key
    /// (client id, shard slot, or -1); `bytes` is the attributed byte
    /// count (or -1).
    pub fn span(&self, track: &'static str, name: &'static str, start_ns: u64, unit: i64, bytes: i64) {
        let Some(sink) = &self.trace else { return };
        let end = self.now_ns();
        sink.record(Span {
            ts_ns: start_ns,
            dur_ns: end.saturating_sub(start_ns),
            track,
            name,
            round: self.round(),
            unit,
            bytes,
        });
    }

    /// Record an instant (zero-duration) event at `round` — used for
    /// supervisor incidents, whose round comes from the event record
    /// rather than the current-round cell.
    pub fn instant_at(&self, track: &'static str, name: &'static str, round: i64, unit: i64) {
        let Some(sink) = &self.trace else { return };
        let now = self.now_ns();
        sink.record(Span {
            ts_ns: now,
            dur_ns: 0,
            track,
            name,
            round,
            unit,
            bytes: -1,
        });
    }

    /// Whether a trace sink is attached (exporters use this to decide
    /// if there is anything to write).
    pub fn tracing(&self) -> bool {
        self.trace.is_some()
    }

    /// Drain all recorded spans (stripe order; exporters sort).
    pub fn drain_spans(&self) -> Vec<Span> {
        self.trace.as_ref().map(TraceSink::drain).unwrap_or_default()
    }

    /// Spans dropped by the sink (full stripes).
    pub fn dropped_spans(&self) -> u64 {
        self.trace.as_ref().map(TraceSink::dropped).unwrap_or(0)
    }

    /// Bridge supervisor incidents from `RunLog.events` into the
    /// registry (death/respawn/degrade counters) and the trace
    /// (instant events on the supervisor track). Idempotent across
    /// calls: only events past the internal high-water mark are
    /// processed, so the coordinator can call this every round and once
    /// more at teardown.
    pub fn bridge_events(&self, events: &[ShardEvent]) {
        let from = self.bridged.load(Ordering::Relaxed).max(0) as usize;
        for e in events.iter().skip(from) {
            let name = match &e.kind {
                ShardEventKind::Death { .. } => {
                    self.metrics.deaths_total.fetch_add(1, Ordering::Relaxed);
                    "incident.death"
                }
                ShardEventKind::Respawned { .. } => {
                    self.metrics.respawns_total.fetch_add(1, Ordering::Relaxed);
                    "incident.respawn"
                }
                ShardEventKind::Degraded { .. } => {
                    self.metrics.degrades_total.fetch_add(1, Ordering::Relaxed);
                    "incident.degrade"
                }
            };
            self.instant_at(track::SUPERVISOR, name, e.round as i64, e.shard as i64);
        }
        self.bridged.store(events.len() as i64, Ordering::Relaxed);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::supervise::ScriptedClock;
    use std::time::Duration;

    #[test]
    fn spans_carry_the_current_round_and_scripted_time() {
        let clock = Arc::new(ScriptedClock::new(Duration::from_millis(1)));
        let t = Telemetry::new(clock.clone(), true);
        t.set_round(3);
        let t0 = t.now_ns();
        clock.advance(Duration::from_millis(2));
        t.span(track::CODEC, "codec.encode_w", t0, 7, 128);
        let spans = t.drain_spans();
        assert_eq!(spans.len(), 1);
        let s = spans[0];
        assert_eq!(s.round, 3);
        assert_eq!(s.unit, 7);
        assert_eq!(s.bytes, 128);
        assert_eq!(s.dur_ns, 2_000_000);
        assert_eq!(s.name, "codec.encode_w");
    }

    #[test]
    fn without_tracing_span_recording_is_a_no_op() {
        let t = Telemetry::new(Arc::new(ScriptedClock::new(Duration::ZERO)), false);
        t.span(track::NET, "net.send.round", 0, -1, 10);
        assert!(!t.tracing());
        assert!(t.drain_spans().is_empty());
        assert_eq!(t.dropped_spans(), 0);
    }

    #[test]
    fn bridge_events_is_incremental_and_idempotent() {
        use crate::metrics::{ShardEvent, ShardEventKind};
        let t = Telemetry::new(Arc::new(ScriptedClock::new(Duration::ZERO)), true);
        let mut events = vec![ShardEvent {
            round: 1,
            shard: 0,
            kind: ShardEventKind::Death { reason: "x".into() },
        }];
        t.bridge_events(&events);
        t.bridge_events(&events); // no double counting
        events.push(ShardEvent {
            round: 1,
            shard: 0,
            kind: ShardEventKind::Respawned { attempt: 1 },
        });
        t.bridge_events(&events);
        assert_eq!(t.metrics.deaths_total.load(Ordering::Relaxed), 1);
        assert_eq!(t.metrics.respawns_total.load(Ordering::Relaxed), 1);
        let spans = t.drain_spans();
        assert_eq!(spans.len(), 2);
        assert!(spans.iter().all(|s| s.track == track::SUPERVISOR));
    }

    #[test]
    fn net_span_names_cover_every_kind() {
        for kind in MsgKind::ALL {
            assert!(net_send_name(kind).starts_with("net.send."));
            assert!(net_recv_name(kind).starts_with("net.recv."));
        }
    }
}
