//! `fsfl trace summarize FILE`: browserless inspection of an exported
//! Chrome trace — per-stage p50/p95/p99 latency and the top-3 widest
//! spans per round, computed with the same nearest-rank
//! [`Hist`](crate::bench::summary::Hist) the bench plane reports with.
//!
//! Reads the trace back through the strict [`crate::bench::json`]
//! parser, so summarizing doubles as schema validation (the CI `obs`
//! job leans on this).

use std::collections::BTreeMap;

use anyhow::{anyhow, Context, Result};

use crate::bench::json::{self, Value};
use crate::bench::summary::Hist;

/// One span as re-read from the exported document.
struct Ev {
    name: String,
    dur_us: f64,
    round: i64,
    unit: i64,
    bytes: i64,
}

fn field_f64(v: &Value, key: &str) -> Result<f64> {
    v.get(key)
        .and_then(Value::as_f64)
        .ok_or_else(|| anyhow!("trace event missing numeric {key:?}"))
}

/// Parse an exported trace document and render the summary text.
pub fn summarize_str(doc: &str) -> Result<String> {
    let root = json::parse(doc).context("trace is not valid JSON")?;
    if root.get("schema").and_then(Value::as_str) != Some("fsfl-trace") {
        return Err(anyhow!("not an fsfl trace (missing schema tag)"));
    }
    let dropped = root
        .get("otherData")
        .and_then(|o| o.get("dropped_spans"))
        .and_then(Value::as_f64)
        .unwrap_or(0.0) as u64;
    let events = root
        .get("traceEvents")
        .and_then(Value::as_arr)
        .ok_or_else(|| anyhow!("trace has no traceEvents array"))?;
    let mut spans = Vec::new();
    for ev in events {
        if ev.get("ph").and_then(Value::as_str) != Some("X") {
            continue;
        }
        let args = ev
            .get("args")
            .ok_or_else(|| anyhow!("span event missing args"))?;
        spans.push(Ev {
            name: ev
                .get("name")
                .and_then(Value::as_str)
                .ok_or_else(|| anyhow!("span event missing name"))?
                .to_string(),
            dur_us: field_f64(ev, "dur")?,
            round: field_f64(args, "round")? as i64,
            unit: field_f64(args, "unit")? as i64,
            bytes: field_f64(args, "bytes")? as i64,
        });
    }

    // Per-stage latency histograms (BTreeMap: stable stage order).
    let mut stages: BTreeMap<&str, Hist> = BTreeMap::new();
    for s in &spans {
        stages.entry(s.name.as_str()).or_default().push(s.dur_us / 1000.0);
    }
    // Widest spans per round (rounds < 0 are out-of-round plumbing).
    let mut rounds: BTreeMap<i64, Vec<&Ev>> = BTreeMap::new();
    for s in spans.iter().filter(|s| s.round >= 0) {
        rounds.entry(s.round).or_default().push(s);
    }

    let mut out = String::new();
    out.push_str(&format!(
        "trace: {} spans, {} stages, {} rounds, {} dropped\n",
        spans.len(),
        stages.len(),
        rounds.len(),
        dropped
    ));
    out.push_str("\nper-stage latency (ms):\n");
    out.push_str(&format!(
        "  {:<28} {:>7} {:>10} {:>10} {:>10}\n",
        "stage", "count", "p50", "p95", "p99"
    ));
    for (name, h) in &stages {
        out.push_str(&format!(
            "  {:<28} {:>7} {:>10.3} {:>10.3} {:>10.3}\n",
            name,
            h.count(),
            h.percentile(50.0).unwrap_or(0.0),
            h.percentile(95.0).unwrap_or(0.0),
            h.percentile(99.0).unwrap_or(0.0)
        ));
    }
    out.push_str("\ntop-3 widest spans per round:\n");
    for (round, mut evs) in rounds {
        // Deterministic widest-first order: duration desc, then name
        // and unit as tie-breaks.
        evs.sort_by(|a, b| {
            b.dur_us
                .total_cmp(&a.dur_us)
                .then_with(|| a.name.cmp(&b.name))
                .then_with(|| a.unit.cmp(&b.unit))
        });
        out.push_str(&format!("  round {round}:\n"));
        for (i, e) in evs.iter().take(3).enumerate() {
            let bytes = if e.bytes >= 0 {
                format!(", {} bytes", e.bytes)
            } else {
                String::new()
            };
            out.push_str(&format!(
                "    {}. {} ({:.3} ms, unit {}{})\n",
                i + 1,
                e.name,
                e.dur_us / 1000.0,
                e.unit,
                bytes
            ));
        }
    }
    Ok(out)
}

/// Read `path` and summarize it (the CLI verb's body).
pub fn summarize_file(path: &std::path::Path) -> Result<String> {
    let doc = std::fs::read_to_string(path)
        .with_context(|| format!("failed to read trace {}", path.display()))?;
    summarize_str(&doc).with_context(|| format!("failed to summarize {}", path.display()))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::obs::{chrome, track, Span};

    #[test]
    fn summarizes_an_exported_trace() {
        let spans = vec![
            Span {
                ts_ns: 0,
                dur_ns: 2_000_000,
                track: track::CODEC,
                name: "codec.encode_w",
                round: 0,
                unit: 3,
                bytes: 100,
            },
            Span {
                ts_ns: 0,
                dur_ns: 5_000_000,
                track: track::COORDINATOR,
                name: "round",
                round: 0,
                unit: -1,
                bytes: -1,
            },
            Span {
                ts_ns: 0,
                dur_ns: 1_000_000,
                track: track::CODEC,
                name: "codec.encode_w",
                round: 1,
                unit: 4,
                bytes: 80,
            },
        ];
        let doc = chrome::render(&spans, 0);
        let s = summarize_str(&doc).unwrap();
        assert!(s.contains("3 spans"), "got: {s}");
        assert!(s.contains("codec.encode_w"));
        assert!(s.contains("round 0:"));
        assert!(s.contains("round 1:"));
        // round 0's widest span is the 5 ms coordinator round
        let round0 = s.split("round 0:").nth(1).unwrap();
        assert!(round0.trim_start().starts_with("1. round (5.000 ms"));
    }

    #[test]
    fn rejects_non_trace_documents() {
        assert!(summarize_str("{\"schema\": \"something-else\"}").is_err());
        assert!(summarize_str("not json").is_err());
    }
}
