//! Live counters/gauges registry and its Prometheus text rendering.
//!
//! The registry is the *live* side of the telemetry plane: every value
//! is an atomic (or a short-lived mutex over histograms) that the
//! coordinator, net layer, and pager update in place, and that the
//! [`super::http`] endpoint renders on demand. Nothing here feeds back
//! into the run — the registry is strictly write-from-run,
//! read-from-scraper, which is what keeps telemetry-on runs
//! byte-identical to telemetry-off runs.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

use crate::bench::summary::Hist;
use crate::metrics::{MsgKind, RoundMetrics, WireStats};
use crate::net::KindCounters;

/// Atomic counters and gauges for one run. Constructed once per
/// [`super::Telemetry`] handle; see the module docs for the
/// write/read split.
#[derive(Default)]
pub struct MetricsRegistry {
    /// Rounds completed so far (counter).
    pub rounds_total: AtomicU64,
    /// Cumulative upstream (client → server) payload bytes (counter).
    pub up_bytes_total: AtomicU64,
    /// Cumulative downstream (server → client) payload bytes (counter).
    pub down_bytes_total: AtomicU64,
    /// Shards the coordinator is still waiting on in the current
    /// fan-in (gauge).
    pub fan_in_pending: AtomicU64,
    /// Clients currently resident in shard memory (gauge; paged runs).
    pub resident_clients: AtomicU64,
    /// Clients currently parked in the cold-state pager (gauge).
    pub paged_clients: AtomicU64,
    /// Shard deaths observed by the supervisor (counter).
    pub deaths_total: AtomicU64,
    /// Successful shard respawns (counter).
    pub respawns_total: AtomicU64,
    /// Quorum degradations (counter).
    pub degrades_total: AtomicU64,
    /// Last round's dense-baseline / actual-upstream compression
    /// ratio, stored as `f64::to_bits` (gauge).
    compression_ratio_bits: AtomicU64,
    /// Model parameter count, set once after init (gauge; also the
    /// dense-baseline input for the compression ratio).
    model_params: AtomicU64,
    /// Per-shard round latency histograms, indexed by shard slot.
    shard_round_ms: Mutex<Vec<Hist>>,
    /// Per-endpoint frame counters registered by the wire transport
    /// (`(sent, received)` per attached worker connection).
    wire: Mutex<Vec<(Arc<KindCounters>, Arc<KindCounters>)>>,
}

impl MetricsRegistry {
    /// Record the model's parameter count (dense baseline for the
    /// compression-ratio gauge).
    pub fn set_model_params(&self, params: usize) {
        self.model_params.store(params as u64, Ordering::Relaxed);
    }

    /// Fold one sealed round into the counters: bumps `rounds_total`,
    /// the up/down byte counters, and refreshes the compression-ratio
    /// gauge (dense f32 baseline over the round's participants vs. the
    /// bytes actually shipped).
    pub fn record_round(&self, m: &RoundMetrics) {
        self.rounds_total.fetch_add(1, Ordering::Relaxed);
        self.up_bytes_total.fetch_add(m.up_bytes as u64, Ordering::Relaxed);
        self.down_bytes_total.fetch_add(m.down_bytes as u64, Ordering::Relaxed);
        let params = self.model_params.load(Ordering::Relaxed);
        let participants = m.client_sparsity.len() as u64;
        if params > 0 && participants > 0 && m.up_bytes > 0 {
            let dense = (params * participants * 4) as f64;
            let ratio = dense / m.up_bytes as f64;
            self.compression_ratio_bits.store(ratio.to_bits(), Ordering::Relaxed);
        }
    }

    /// Last recorded compression ratio (0.0 before any round seals).
    pub fn compression_ratio(&self) -> f64 {
        f64::from_bits(self.compression_ratio_bits.load(Ordering::Relaxed))
    }

    /// Record one shard's fan-out → round-done latency for the current
    /// round, growing the per-shard histogram table as needed.
    pub fn observe_shard_round(&self, shard: usize, ms: f64) {
        let Ok(mut hists) = self.shard_round_ms.lock() else { return };
        while hists.len() <= shard {
            hists.push(Hist::default());
        }
        hists[shard].push(ms);
    }

    /// Register one wire endpoint's `(sent, received)` per-kind frame
    /// counters so the scrape endpoint can render live wire totals.
    pub fn register_wire(&self, sent: Arc<KindCounters>, received: Arc<KindCounters>) {
        if let Ok(mut w) = self.wire.lock() {
            w.push((sent, received));
        }
    }

    /// Sum every registered wire endpoint into one per-kind
    /// [`WireStats`] snapshot (empty stats when no wire transport is
    /// attached, e.g. mpsc runs).
    pub fn wire_snapshot(&self) -> WireStats {
        let mut stats = WireStats::default();
        if let Ok(w) = self.wire.lock() {
            for (sent, received) in w.iter() {
                let s = sent.snapshot();
                let r = received.snapshot();
                for k in 0..MsgKind::COUNT {
                    stats.sent_by_kind[k] += s[k];
                    stats.received_by_kind[k] += r[k];
                }
            }
        }
        stats
    }

    /// Render the registry in Prometheus text exposition format
    /// (`text/plain; version=0.0.4`). Metric order is fixed so
    /// successive scrapes of an idle run are byte-identical.
    pub fn render_prometheus(&self, dropped_spans: u64) -> String {
        let mut out = String::with_capacity(2048);
        let counter = |out: &mut String, name: &str, help: &str, v: u64| {
            out.push_str(&format!(
                "# HELP {name} {help}\n# TYPE {name} counter\n{name} {v}\n"
            ));
        };
        let gauge = |out: &mut String, name: &str, help: &str, v: String| {
            out.push_str(&format!(
                "# HELP {name} {help}\n# TYPE {name} gauge\n{name} {v}\n"
            ));
        };
        counter(
            &mut out,
            "fsfl_rounds_total",
            "Federated rounds completed.",
            self.rounds_total.load(Ordering::Relaxed),
        );
        counter(
            &mut out,
            "fsfl_up_bytes_total",
            "Upstream (client to server) payload bytes.",
            self.up_bytes_total.load(Ordering::Relaxed),
        );
        counter(
            &mut out,
            "fsfl_down_bytes_total",
            "Downstream (server to client) payload bytes.",
            self.down_bytes_total.load(Ordering::Relaxed),
        );
        gauge(
            &mut out,
            "fsfl_compression_ratio",
            "Dense-baseline over shipped upstream bytes, last sealed round.",
            format!("{}", self.compression_ratio()),
        );
        gauge(
            &mut out,
            "fsfl_model_params",
            "Model parameter count.",
            format!("{}", self.model_params.load(Ordering::Relaxed)),
        );
        gauge(
            &mut out,
            "fsfl_fan_in_pending",
            "Shards the coordinator is still waiting on this round.",
            format!("{}", self.fan_in_pending.load(Ordering::Relaxed)),
        );
        gauge(
            &mut out,
            "fsfl_resident_clients",
            "Clients resident in shard memory.",
            format!("{}", self.resident_clients.load(Ordering::Relaxed)),
        );
        gauge(
            &mut out,
            "fsfl_paged_clients",
            "Clients parked in the cold-state pager.",
            format!("{}", self.paged_clients.load(Ordering::Relaxed)),
        );
        counter(
            &mut out,
            "fsfl_shard_deaths_total",
            "Shard deaths observed by the supervisor.",
            self.deaths_total.load(Ordering::Relaxed),
        );
        counter(
            &mut out,
            "fsfl_shard_respawns_total",
            "Successful shard respawns.",
            self.respawns_total.load(Ordering::Relaxed),
        );
        counter(
            &mut out,
            "fsfl_quorum_degrades_total",
            "Quorum degradations.",
            self.degrades_total.load(Ordering::Relaxed),
        );
        counter(
            &mut out,
            "fsfl_trace_dropped_spans_total",
            "Spans dropped because a trace stripe was full.",
            dropped_spans,
        );
        // Per-kind wire bytes, fixed kind order, skipping the two
        // TYPE-only header lines when no wire transport is attached
        // would make scrape shape depend on topology — always render.
        let wire = self.wire_snapshot();
        out.push_str("# HELP fsfl_wire_sent_bytes_total Frame bytes sent by the coordinator, by message kind.\n# TYPE fsfl_wire_sent_bytes_total counter\n");
        for kind in MsgKind::ALL {
            out.push_str(&format!(
                "fsfl_wire_sent_bytes_total{{kind=\"{}\"}} {}\n",
                kind.name(),
                wire.sent_of(kind)
            ));
        }
        out.push_str("# HELP fsfl_wire_received_bytes_total Frame bytes received by the coordinator, by message kind.\n# TYPE fsfl_wire_received_bytes_total counter\n");
        for kind in MsgKind::ALL {
            out.push_str(&format!(
                "fsfl_wire_received_bytes_total{{kind=\"{}\"}} {}\n",
                kind.name(),
                wire.received_of(kind)
            ));
        }
        // Per-shard round latency summaries (nearest-rank percentiles
        // from bench::summary::Hist).
        out.push_str("# HELP fsfl_round_latency_ms Per-shard fan-out to round-done latency quantiles.\n# TYPE fsfl_round_latency_ms gauge\n");
        if let Ok(hists) = self.shard_round_ms.lock() {
            for (shard, h) in hists.iter().enumerate() {
                if h.count() == 0 {
                    continue;
                }
                for (stat, v) in [
                    ("p50", h.percentile(50.0).unwrap_or(0.0)),
                    ("p95", h.percentile(95.0).unwrap_or(0.0)),
                    ("p99", h.percentile(99.0).unwrap_or(0.0)),
                ] {
                    out.push_str(&format!(
                        "fsfl_round_latency_ms{{shard=\"{shard}\",stat=\"{stat}\"}} {v}\n"
                    ));
                }
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn round(up: usize, down: usize, participants: usize) -> RoundMetrics {
        RoundMetrics {
            up_bytes: up,
            down_bytes: down,
            client_sparsity: vec![0.9; participants],
            ..Default::default()
        }
    }

    #[test]
    fn record_round_accumulates_and_derives_compression() {
        let reg = MetricsRegistry::default();
        reg.set_model_params(1000);
        reg.record_round(&round(800, 4000, 2));
        reg.record_round(&round(200, 4000, 2));
        assert_eq!(reg.rounds_total.load(Ordering::Relaxed), 2);
        assert_eq!(reg.up_bytes_total.load(Ordering::Relaxed), 1000);
        assert_eq!(reg.down_bytes_total.load(Ordering::Relaxed), 8000);
        // last round: 1000 params × 2 participants × 4 bytes / 200 = 40×
        assert_eq!(reg.compression_ratio(), 40.0);
    }

    #[test]
    fn prometheus_rendering_is_stable_and_well_formed() {
        let reg = MetricsRegistry::default();
        reg.set_model_params(10);
        reg.record_round(&round(100, 200, 1));
        reg.observe_shard_round(1, 5.0);
        let a = reg.render_prometheus(0);
        let b = reg.render_prometheus(0);
        assert_eq!(a, b, "idle scrapes must be byte-identical");
        assert!(a.contains("fsfl_rounds_total 1"));
        assert!(a.contains("fsfl_up_bytes_total 100"));
        assert!(a.contains("fsfl_wire_sent_bytes_total{kind=\"round\"} 0"));
        assert!(a.contains("fsfl_round_latency_ms{shard=\"1\",stat=\"p50\"} 5"));
        // Every non-comment line is `name{labels}? value`.
        for line in a.lines() {
            if line.starts_with('#') {
                continue;
            }
            let mut parts = line.rsplitn(2, ' ');
            let value = parts.next().unwrap();
            assert!(
                value.parse::<f64>().is_ok(),
                "unparseable sample value in line: {line}"
            );
            assert!(parts.next().is_some(), "missing metric name: {line}");
        }
    }
}
