//! Scoped worker pool for the **codec plane**.
//!
//! An FL round has two very different kinds of work: the *compute plane*
//! (PJRT step execution — thread-affine, stays on the thread that built
//! the XLA client) and the *codec plane* (per-client sparsify → quantize
//! → DeepCABAC encode, and server-side decode — pure CPU code with no
//! XLA dependency). [`WorkerPool`] fans the codec plane out across OS
//! threads with `std::thread::scope`, so borrowed per-client state flows
//! in without `Arc`/channels and without any new dependencies.
//!
//! Determinism contract: work items are processed independently and
//! results land in the slot of the item that produced them, so outputs
//! are **bit-for-bit identical for every pool size** (including 1). The
//! serial/parallel equivalence tests in `tests/integration_parallel.rs`
//! pin this down for the full codec pipeline.

/// A fixed-width scoped worker pool. Threads live only for the duration
/// of one [`WorkerPool::run_mut`]/[`WorkerPool::map`] call; with one
/// worker (or one item) everything runs inline on the caller's thread.
#[derive(Debug, Clone)]
pub struct WorkerPool {
    workers: usize,
}

/// Upper bound for auto-sized pools: codec work saturates memory
/// bandwidth long before it scales past this.
const MAX_AUTO_WORKERS: usize = 16;

impl WorkerPool {
    /// `workers == 0` → auto (available parallelism, capped); otherwise
    /// exactly `workers` threads.
    pub fn new(workers: usize) -> Self {
        let workers = if workers == 0 {
            std::thread::available_parallelism()
                .map(|n| n.get())
                .unwrap_or(1)
                .min(MAX_AUTO_WORKERS)
        } else {
            workers
        };
        Self {
            workers: workers.max(1),
        }
    }

    /// Strictly serial pool (the baseline the equivalence tests compare
    /// every other width against).
    pub fn serial() -> Self {
        Self { workers: 1 }
    }

    pub fn workers(&self) -> usize {
        self.workers
    }

    /// Apply `f` to every item, in place. `f` receives the item's index
    /// in `items`. Items are distributed as contiguous chunks (codec
    /// work is near-uniform per client, so static partitioning beats a
    /// shared queue's synchronization). Panics in `f` propagate to the
    /// caller when the scope joins.
    pub fn run_mut<T, F>(&self, items: &mut [T], f: F)
    where
        T: Send,
        F: Fn(usize, &mut T) + Sync,
    {
        let n = items.len();
        let w = self.workers.min(n);
        if w <= 1 {
            for (i, item) in items.iter_mut().enumerate() {
                f(i, item);
            }
            return;
        }
        let chunk = (n + w - 1) / w;
        std::thread::scope(|s| {
            for (c, slice) in items.chunks_mut(chunk).enumerate() {
                let f = &f;
                s.spawn(move || {
                    for (j, item) in slice.iter_mut().enumerate() {
                        f(c * chunk + j, item);
                    }
                });
            }
        });
    }

    /// Consume `items`, producing one output per item in input order.
    pub fn map<I, O, F>(&self, items: Vec<I>, f: F) -> Vec<O>
    where
        I: Send,
        O: Send,
        F: Fn(usize, I) -> O + Sync,
    {
        let mut slots: Vec<(Option<I>, Option<O>)> =
            items.into_iter().map(|i| (Some(i), None)).collect();
        self.run_mut(&mut slots, |i, slot| {
            let input = slot.0.take().expect("map slot consumed twice");
            slot.1 = Some(f(i, input));
        });
        slots
            .into_iter()
            .map(|s| s.1.expect("map slot not produced"))
            .collect()
    }
}

impl Default for WorkerPool {
    fn default() -> Self {
        Self::new(0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn run_mut_hits_every_item_with_its_index() {
        for workers in [1, 2, 3, 8] {
            let pool = WorkerPool::new(workers);
            let mut items: Vec<usize> = vec![0; 37];
            pool.run_mut(&mut items, |i, x| *x = i * i);
            for (i, &x) in items.iter().enumerate() {
                assert_eq!(x, i * i, "workers={workers}");
            }
        }
    }

    #[test]
    fn map_preserves_order_for_all_widths() {
        let inputs: Vec<u64> = (0..101).collect();
        let serial = WorkerPool::serial().map(inputs.clone(), |_, x| x.wrapping_mul(2654435761));
        for workers in [2, 4, 16] {
            let par = WorkerPool::new(workers).map(inputs.clone(), |_, x| {
                x.wrapping_mul(2654435761)
            });
            assert_eq!(par, serial, "workers={workers}");
        }
    }

    #[test]
    fn empty_and_single_item_are_inline() {
        let pool = WorkerPool::new(8);
        let mut empty: Vec<u32> = Vec::new();
        pool.run_mut(&mut empty, |_, _| unreachable!());
        let out = pool.map(vec![7u32], |i, x| (i, x + 1));
        assert_eq!(out, vec![(0, 8)]);
    }

    #[test]
    fn auto_width_is_sane() {
        let pool = WorkerPool::new(0);
        assert!(pool.workers() >= 1 && pool.workers() <= MAX_AUTO_WORKERS);
        assert_eq!(WorkerPool::serial().workers(), 1);
    }
}
