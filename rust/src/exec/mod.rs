//! Scoped worker pool for the **codec plane**.
//!
//! An FL round has two very different kinds of work: the *compute plane*
//! (PJRT step execution — thread-affine, stays on the thread that built
//! the XLA client) and the *codec plane* (per-client sparsify → quantize
//! → DeepCABAC encode, and server-side decode — pure CPU code with no
//! XLA dependency). [`WorkerPool`] fans the codec plane out across OS
//! threads with `std::thread::scope`, so borrowed per-client state flows
//! in without `Arc`/channels and without any new dependencies.
//!
//! Two execution shapes:
//!
//! * **Barrier** ([`WorkerPool::run_mut`] / [`WorkerPool::map`]) — apply
//!   one function to a whole slice and join. Used by the *staged* round
//!   schedule, where every codec stage runs between two compute stages.
//! * **Pipeline** ([`WorkerPool::pipeline`]) — a scoped submit/take job
//!   queue. The calling thread keeps running (e.g. training the next
//!   client on the compute plane) while submitted jobs execute on the
//!   pool; results are claimed by ticket in any order. This is the
//!   substrate of the *pipelined* round schedule in
//!   [`crate::fl::scheduler`].
//!
//! Determinism contract: work items are processed independently and
//! results land in the slot/ticket of the item that produced them, so
//! outputs are **bit-for-bit identical for every pool size** (including
//! 1) and for both execution shapes. The serial/parallel equivalence
//! tests in `tests/integration_parallel.rs` pin this down for the full
//! codec pipeline.

use std::sync::mpsc;
use std::sync::Mutex;

/// A fixed-width scoped worker pool. Threads live only for the duration
/// of one [`WorkerPool::run_mut`]/[`WorkerPool::map`]/[`WorkerPool::pipeline`]
/// call; for barrier calls with one worker (or one item) everything runs
/// inline on the caller's thread.
///
/// ```
/// use fsfl::exec::WorkerPool;
///
/// let pool = WorkerPool::new(4);
/// let mut rows = vec![vec![1.0f32; 8]; 16];
/// pool.run_mut(&mut rows, |i, row| row.iter_mut().for_each(|x| *x *= i as f32));
/// assert_eq!(rows[3][0], 3.0);
/// let squares = pool.map((0..10u32).collect::<Vec<_>>(), |_, x| x * x);
/// assert_eq!(squares[7], 49);
/// ```
#[derive(Debug, Clone)]
pub struct WorkerPool {
    workers: usize,
}

/// Upper bound for auto-sized pools: codec work saturates memory
/// bandwidth long before it scales past this.
const MAX_AUTO_WORKERS: usize = 16;

impl WorkerPool {
    /// `workers == 0` → auto (available parallelism, capped); otherwise
    /// exactly `workers` threads.
    pub fn new(workers: usize) -> Self {
        let workers = if workers == 0 {
            std::thread::available_parallelism()
                .map(|n| n.get())
                .unwrap_or(1)
                .min(MAX_AUTO_WORKERS)
        } else {
            workers
        };
        Self {
            workers: workers.max(1),
        }
    }

    /// Strictly serial pool (the baseline the equivalence tests compare
    /// every other width against).
    pub fn serial() -> Self {
        Self { workers: 1 }
    }

    /// The pool width actually in use (≥ 1).
    pub fn workers(&self) -> usize {
        self.workers
    }

    /// Apply `f` to every item, in place. `f` receives the item's index
    /// in `items`. Items are distributed as contiguous chunks (codec
    /// work is near-uniform per client, so static partitioning beats a
    /// shared queue's synchronization). Panics in `f` propagate to the
    /// caller when the scope joins.
    pub fn run_mut<T, F>(&self, items: &mut [T], f: F)
    where
        T: Send,
        F: Fn(usize, &mut T) + Sync,
    {
        let n = items.len();
        let w = self.workers.min(n);
        if w <= 1 {
            for (i, item) in items.iter_mut().enumerate() {
                f(i, item);
            }
            return;
        }
        let chunk = (n + w - 1) / w;
        std::thread::scope(|s| {
            for (c, slice) in items.chunks_mut(chunk).enumerate() {
                let f = &f;
                s.spawn(move || {
                    for (j, item) in slice.iter_mut().enumerate() {
                        f(c * chunk + j, item);
                    }
                });
            }
        });
    }

    /// Consume `items`, producing one output per item in input order.
    pub fn map<I, O, F>(&self, items: Vec<I>, f: F) -> Vec<O>
    where
        I: Send,
        O: Send,
        F: Fn(usize, I) -> O + Sync,
    {
        let mut slots: Vec<(Option<I>, Option<O>)> =
            items.into_iter().map(|i| (Some(i), None)).collect();
        self.run_mut(&mut slots, |i, slot| {
            let input = slot.0.take().expect("map slot consumed twice");
            slot.1 = Some(f(i, input));
        });
        slots
            .into_iter()
            .map(|s| s.1.expect("map slot not produced"))
            .collect()
    }

    /// Scoped job pipeline: run `body` on the calling thread with a
    /// [`PipelineHandle`] that can [`submit`](PipelineHandle::submit)
    /// owned work items to the pool and later [`take`](PipelineHandle::take)
    /// each result back by ticket — in any order, while the calling
    /// thread keeps doing its own (e.g. thread-affine compute) work in
    /// between. `worker` runs on the pool threads and must be a pure
    /// function of its item; results are keyed by ticket, so outputs are
    /// identical for every pool width and every completion order.
    ///
    /// Workers exist only for the duration of this call. Jobs still
    /// queued when `body` returns are finished and then discarded. A
    /// panicking `worker` never deadlocks the pipeline: a blocked
    /// [`take`](PipelineHandle::take) panics immediately (via a
    /// worker-died marker sent while the panic unwinds) and the original
    /// panic is re-raised when the scope joins.
    ///
    /// ```
    /// use fsfl::exec::WorkerPool;
    ///
    /// let pool = WorkerPool::new(2);
    /// let sum: u32 = pool.pipeline(
    ///     |x: u32| x + 1,
    ///     |h| {
    ///         let tickets: Vec<usize> = (0..8).map(|x| h.submit(x)).collect();
    ///         tickets.into_iter().map(|t| h.take(t)).sum()
    ///     },
    /// );
    /// assert_eq!(sum, 36);
    /// ```
    pub fn pipeline<T, R, O, W, B>(&self, worker: W, body: B) -> O
    where
        T: Send,
        R: Send,
        W: Fn(T) -> R + Sync,
        B: FnOnce(&mut PipelineHandle<'_, T, R>) -> O,
    {
        let (job_tx, job_rx) = mpsc::channel::<(usize, T)>();
        let (res_tx, res_rx) = mpsc::channel::<PipeMsg<R>>();
        let job_rx = Mutex::new(job_rx);
        std::thread::scope(|s| {
            for _ in 0..self.workers {
                let job_rx = &job_rx;
                let res_tx = res_tx.clone();
                let worker = &worker;
                s.spawn(move || loop {
                    // The guard is released at the end of this statement,
                    // so jobs execute unlocked.
                    let job = job_rx.lock().expect("pipeline: job queue poisoned").recv();
                    match job {
                        Ok((ticket, item)) => {
                            // If `worker` panics, the guard's Drop runs
                            // during unwinding and tells the take() side a
                            // result will never come — without it, other
                            // workers' live senders would keep take()
                            // blocked forever.
                            let mut guard = PanicGuard {
                                tx: &res_tx,
                                armed: true,
                            };
                            let r = worker(item);
                            guard.armed = false;
                            drop(guard);
                            if res_tx.send(PipeMsg::Done(ticket, r)).is_err() {
                                break;
                            }
                        }
                        Err(_) => break, // submit side closed: drain done
                    }
                });
            }
            drop(res_tx);
            let mut handle = PipelineHandle {
                job_tx,
                res_rx: &res_rx,
                buf: Vec::new(),
                claimed: Vec::new(),
                next_ticket: 0,
            };
            body(&mut handle)
            // `handle` (and with it the job sender) drops here, workers
            // drain the queue and exit, then the scope joins them.
        })
    }
}

/// Internal pipeline result-channel protocol.
enum PipeMsg<R> {
    /// A finished job: (ticket, result).
    Done(usize, R),
    /// A worker died mid-job; its ticket will never resolve.
    WorkerPanicked,
}

/// Sends [`PipeMsg::WorkerPanicked`] iff dropped while still armed —
/// i.e. while a worker panic unwinds through a job.
struct PanicGuard<'a, R> {
    tx: &'a mpsc::Sender<PipeMsg<R>>,
    armed: bool,
}

impl<R> Drop for PanicGuard<'_, R> {
    fn drop(&mut self) {
        if self.armed {
            let _ = self.tx.send(PipeMsg::WorkerPanicked);
        }
    }
}

impl Default for WorkerPool {
    /// Auto-sized pool (`WorkerPool::new(0)`).
    fn default() -> Self {
        Self::new(0)
    }
}

/// Submit/take interface of one [`WorkerPool::pipeline`] invocation.
///
/// Tickets are assigned in submission order; results can be claimed in
/// any order (out-of-order completions are buffered internally).
pub struct PipelineHandle<'a, T, R> {
    job_tx: mpsc::Sender<(usize, T)>,
    res_rx: &'a mpsc::Receiver<PipeMsg<R>>,
    /// Completed results whose ticket nobody asked for yet.
    buf: Vec<(usize, R)>,
    /// `claimed[ticket]` — guards take() against double claims (which
    /// would otherwise block forever instead of failing fast).
    claimed: Vec<bool>,
    next_ticket: usize,
}

impl<T, R> PipelineHandle<'_, T, R> {
    /// Enqueue one work item; returns the ticket to [`take`](Self::take)
    /// its result with.
    pub fn submit(&mut self, item: T) -> usize {
        let ticket = self.next_ticket;
        self.next_ticket += 1;
        self.claimed.push(false);
        self.job_tx
            .send((ticket, item))
            .expect("pipeline: workers exited before submit");
        ticket
    }

    /// Block until the result of `ticket` is available and return it.
    ///
    /// Panics if claimed twice, never submitted, or if a worker died
    /// before producing it.
    pub fn take(&mut self, ticket: usize) -> R {
        assert!(
            ticket < self.next_ticket,
            "pipeline: ticket {ticket} was never submitted"
        );
        assert!(
            !self.claimed[ticket],
            "pipeline: ticket {ticket} claimed twice"
        );
        self.claimed[ticket] = true;
        if let Some(pos) = self.buf.iter().position(|(t, _)| *t == ticket) {
            return self.buf.swap_remove(pos).1;
        }
        loop {
            match self.res_rx.recv() {
                Ok(PipeMsg::Done(t, r)) => {
                    if t == ticket {
                        return r;
                    }
                    self.buf.push((t, r));
                }
                Ok(PipeMsg::WorkerPanicked) => {
                    panic!("pipeline: a worker panicked; its result will never arrive")
                }
                Err(_) => panic!("pipeline: workers exited before producing a claimed result"),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn run_mut_hits_every_item_with_its_index() {
        for workers in [1, 2, 3, 8] {
            let pool = WorkerPool::new(workers);
            let mut items: Vec<usize> = vec![0; 37];
            pool.run_mut(&mut items, |i, x| *x = i * i);
            for (i, &x) in items.iter().enumerate() {
                assert_eq!(x, i * i, "workers={workers}");
            }
        }
    }

    #[test]
    fn map_preserves_order_for_all_widths() {
        let inputs: Vec<u64> = (0..101).collect();
        let serial = WorkerPool::serial().map(inputs.clone(), |_, x| x.wrapping_mul(2654435761));
        for workers in [2, 4, 16] {
            let par = WorkerPool::new(workers).map(inputs.clone(), |_, x| {
                x.wrapping_mul(2654435761)
            });
            assert_eq!(par, serial, "workers={workers}");
        }
    }

    #[test]
    fn empty_and_single_item_are_inline() {
        let pool = WorkerPool::new(8);
        let mut empty: Vec<u32> = Vec::new();
        pool.run_mut(&mut empty, |_, _| unreachable!());
        let out = pool.map(vec![7u32], |i, x| (i, x + 1));
        assert_eq!(out, vec![(0, 8)]);
    }

    #[test]
    fn auto_width_is_sane() {
        let pool = WorkerPool::new(0);
        assert!(pool.workers() >= 1 && pool.workers() <= MAX_AUTO_WORKERS);
        assert_eq!(WorkerPool::serial().workers(), 1);
    }

    #[test]
    fn pipeline_results_keyed_by_ticket_for_all_widths() {
        for workers in [1, 2, 3, 8] {
            let pool = WorkerPool::new(workers);
            let out: Vec<u64> = pool.pipeline(
                |x: u64| x.wrapping_mul(2654435761),
                |h| {
                    let tickets: Vec<usize> = (0..200u64).map(|x| h.submit(x)).collect();
                    tickets.into_iter().map(|t| h.take(t)).collect()
                },
            );
            let want: Vec<u64> = (0..200u64).map(|x| x.wrapping_mul(2654435761)).collect();
            assert_eq!(out, want, "workers={workers}");
        }
    }

    #[test]
    fn pipeline_take_out_of_submission_order() {
        let pool = WorkerPool::new(4);
        let (a, b, c) = pool.pipeline(
            |x: u32| x * 10,
            |h| {
                let ta = h.submit(1);
                let tb = h.submit(2);
                let tc = h.submit(3);
                // claim in reverse order: buffered completions must resolve
                let c = h.take(tc);
                let b = h.take(tb);
                let a = h.take(ta);
                (a, b, c)
            },
        );
        assert_eq!((a, b, c), (10, 20, 30));
    }

    #[test]
    fn pipeline_interleaved_submit_take() {
        // The pipelined round shape: submit k, do local work, take k-1.
        let pool = WorkerPool::new(2);
        let out: Vec<usize> = pool.pipeline(
            |x: usize| x + 100,
            |h| {
                let mut results = Vec::new();
                let mut prev: Option<usize> = None;
                for k in 0..20 {
                    let t = h.submit(k);
                    if let Some(p) = prev {
                        results.push(h.take(p));
                    }
                    prev = Some(t);
                }
                results.push(h.take(prev.unwrap()));
                results
            },
        );
        let want: Vec<usize> = (100..120).collect();
        assert_eq!(out, want);
    }

    #[test]
    fn pipeline_discards_unclaimed_results() {
        // body returning early must not deadlock or leak threads
        let pool = WorkerPool::new(3);
        let first = pool.pipeline(
            |x: u32| x * 2,
            |h| {
                for x in 0..50 {
                    h.submit(x);
                }
                h.take(0)
            },
        );
        assert_eq!(first, 0);
    }

    #[test]
    fn pipeline_empty_body_is_fine() {
        let pool = WorkerPool::new(4);
        let out = pool.pipeline(|x: u8| x, |_| 42u8);
        assert_eq!(out, 42);
    }

    #[test]
    fn pipeline_take_rejects_double_claims_and_unknown_tickets() {
        let pool = WorkerPool::new(2);
        let double = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            pool.pipeline(
                |x: u32| x,
                |h| {
                    let t = h.submit(5);
                    let v = h.take(t);
                    let _ = h.take(t); // must panic, not hang
                    v
                },
            )
        }));
        assert!(double.is_err(), "double claim was not rejected");
        let unknown = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            pool.pipeline(|x: u32| x, |h| h.take(7))
        }));
        assert!(unknown.is_err(), "unknown ticket was not rejected");
    }

    #[test]
    fn pipeline_worker_panic_propagates_instead_of_deadlocking() {
        // A panicking worker must fail the blocked take() (and re-raise
        // at the scope join), never hang the calling thread.
        let pool = WorkerPool::new(4);
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            pool.pipeline(
                |x: u32| {
                    if x == 3 {
                        panic!("boom");
                    }
                    x
                },
                |h| {
                    let tickets: Vec<usize> = (0..8).map(|x| h.submit(x)).collect();
                    tickets.into_iter().map(|t| h.take(t)).sum::<u32>()
                },
            )
        }));
        assert!(result.is_err(), "worker panic was swallowed");
    }
}
