//! Benchmark harnesses: regenerate every table and figure of the paper's
//! evaluation section (DESIGN.md experiment index).
//!
//! Each harness prints the same rows/series the paper reports and writes
//! CSVs under `results/`. Two presets: `quick` (tiny model, few rounds —
//! CI-friendly) and `paper` (the thinned paper models, full round counts).

use std::path::Path;

use crate::cli::Flags;
use anyhow::Result;

use crate::compression::SparsifyMode;
use crate::data::TaskKind;
use crate::fl::{ExperimentConfig, LrSchedule, Protocol, ScheduleKind};
use crate::metrics::{fmt_bytes, RunLog};
use crate::runtime::{Optimizer, Runtime};

fn is_quick(preset: &str) -> bool {
    preset != "paper"
}

fn write_lines(path: &Path, header: &str, rows: &[String]) -> Result<()> {
    use std::io::Write;
    let mut f = std::io::BufWriter::new(std::fs::File::create(path)?);
    writeln!(f, "{header}")?;
    for r in rows {
        writeln!(f, "{r}")?;
    }
    Ok(())
}

/// Pick representative shallow / middle / deep layers from a recorded
/// scale-stat layer list (Fig. 3 series selection). An empty list is a
/// descriptive error — a run records no scale stats when the protocol
/// disables scaling or exits before its first round — never a panic.
fn pick_depth_layers(layers: &[String]) -> Result<[String; 3]> {
    if layers.is_empty() {
        return Err(anyhow::anyhow!(
            "no scale-stat layers recorded: cannot pick shallow/middle/deep series \
             (does the protocol run with scaling enabled for at least one round?)"
        ));
    }
    Ok([
        layers[0].clone(),
        layers[layers.len() / 2].clone(),
        layers[layers.len() - 1].clone(),
    ])
}

fn run_and_save(rt: &Runtime, cfg: ExperimentConfig, out: &Path) -> Result<RunLog> {
    let name = cfg.name.clone();
    println!("== {name} ==");
    let mut exp = crate::fl::Experiment::build(rt, cfg)?;
    let log = exp.run_with(crate::coordinator::print_round)?;
    log.write_csv(out.join(format!("{name}.csv")))?;
    Ok(log)
}

// ---------------------------------------------------------------------------
// Fig. 1 — learning-rate schedules
// ---------------------------------------------------------------------------

/// Arguments of the Fig. 1 harness.
#[derive(Debug)]
pub struct Fig1Args {
    /// Main training epochs |T|.
    pub epochs: usize,
    /// Scheduler steps (batches) per epoch.
    pub steps_per_epoch: usize,
    /// Peak learning rate.
    pub base_lr: f32,
}

impl Fig1Args {
    /// Parse from CLI flags.
    pub fn from_flags(f: &Flags) -> anyhow::Result<Self> {
        Ok(Self {
            epochs: f.get_or("epochs", 15)?,
            steps_per_epoch: f.get_or("steps-per-epoch", 20)?,
            base_lr: f.get_or("base-lr", 1e-2)?,
        })
    }
}

/// Fig. 1: the three scale-LR schedules over the whole FL process.
pub fn fig1(out: &Path, a: Fig1Args) -> Result<()> {
    let total = a.epochs * a.steps_per_epoch;
    let mut rows = Vec::new();
    let mut schedules = [
        ("const", LrSchedule::new(ScheduleKind::Const, a.base_lr, total, a.steps_per_epoch)),
        ("linear", LrSchedule::new(ScheduleKind::Linear, a.base_lr, total, a.steps_per_epoch)),
        ("cawr", LrSchedule::new(ScheduleKind::Cawr, a.base_lr, total, a.steps_per_epoch)),
    ];
    for step in 0..total {
        if step % a.steps_per_epoch == 0 {
            schedules.iter_mut().for_each(|(_, s)| s.restart());
        }
        let lrs: Vec<f32> = schedules.iter_mut().map(|(_, s)| s.next_lr()).collect();
        rows.push(format!(
            "{},{:.3},{:.6},{:.6},{:.6}",
            step,
            step as f32 / a.steps_per_epoch as f32,
            lrs[0],
            lrs[1],
            lrs[2]
        ));
    }
    let path = out.join("fig1_schedules.csv");
    write_lines(&path, "step,epoch,const,linear,cawr", &rows)?;
    println!("fig1: {} steps over {} epochs → {}", total, a.epochs, path.display());
    // textual sketch at epoch resolution
    for e in 0..a.epochs {
        let i = e * a.steps_per_epoch;
        let line: Vec<&str> = rows[i].split(',').collect();
        println!("epoch {e:>2}: const {}, linear {}, cawr {}", line[2], line[3], line[4]);
    }
    Ok(())
}

// ---------------------------------------------------------------------------
// Fig. 2 — accuracy vs cumulative transmitted data per configuration
// ---------------------------------------------------------------------------

/// Arguments of the Fig. 2 harness.
#[derive(Debug)]
pub struct Fig2Args {
    /// `quick` (CI-sized) or `paper` preset.
    pub preset: String,
    /// Model variant (paper panels: vgg11_thin, resnet8, mobilenet_tiny,
    /// vgg16_head / vgg16_partial).
    pub variant: Option<String>,
    /// Task (cifar / voc / xray).
    pub task: Option<String>,
    /// Also run the SGD scale-optimizer configs (paper Appendix B).
    pub sgd: bool,
    /// Bidirectional compression (paper's VGG16 Chest X-Ray panel).
    pub bidirectional: bool,
    /// Client count.
    pub clients: usize,
    /// Round-count override.
    pub rounds: Option<usize>,
    /// Master seed.
    pub seed: u64,
}

impl Fig2Args {
    /// Parse from CLI flags.
    pub fn from_flags(f: &Flags) -> anyhow::Result<Self> {
        Ok(Self {
            preset: f.str_or("preset", "quick"),
            variant: f.str_opt("variant"),
            task: f.str_opt("task"),
            sgd: f.flag("sgd"),
            bidirectional: f.flag("bidirectional"),
            clients: f.get_or("clients", 2)?,
            rounds: f.get("rounds")?,
            seed: f.get_or("seed", 0)?,
        })
    }
}

fn task_from(s: &str) -> TaskKind {
    match s {
        "voc" => TaskKind::VocLike,
        "xray" => TaskKind::XrayLike,
        _ => TaskKind::CifarLike,
    }
}

/// Fig. 2: accuracy vs cumulative transmitted data per configuration.
pub fn fig2(artifacts: &Path, out: &Path, a: Fig2Args) -> Result<()> {
    let quick = is_quick(&a.preset);
    let variant = a.variant.clone().unwrap_or_else(|| {
        if quick { "tiny_cnn" } else { "mobilenet_tiny" }.to_string()
    });
    let task = task_from(a.task.as_deref().unwrap_or(if quick { "cifar" } else { "voc" }));
    let rounds = a.rounds.unwrap_or(if quick { 6 } else { 15 });
    let rt = Runtime::cpu()?;

    let opts: Vec<(Optimizer, &str)> = if a.sgd {
        vec![(Optimizer::Adam, "adam"), (Optimizer::Sgd, "sgd")]
    } else {
        vec![(Optimizer::Adam, "adam")]
    };
    let schedules = [
        (ScheduleKind::Const, "none"),
        (ScheduleKind::Linear, "linear"),
        (ScheduleKind::Cawr, "cawr"),
    ];

    let base = |name: String, protocol: Protocol| -> ExperimentConfig {
        let mut c = ExperimentConfig::quick(&variant, task, protocol);
        c.name = name;
        c.artifacts_root = artifacts.to_path_buf();
        c.clients = a.clients;
        c.rounds = rounds;
        c.scale_epochs = if quick { 2 } else { 3 };
        c.train_per_client = if quick { 96 } else { 256 };
        c.val_per_client = if quick { 32 } else { 64 };
        c.test_samples = if quick { 64 } else { 256 };
        c.bidirectional = a.bidirectional;
        c.seed = a.seed;
        c
    };

    let mut summaries = Vec::new();
    let mut logs = Vec::new();
    // baseline: no scaling, no sparsification (quantized + DeepCABAC)
    logs.push(run_and_save(&rt, base(format!("fig2-{variant}-baseline"), Protocol::FedAvgQ), out)?);
    // sparse baseline: Eqs.(2)+(3) only
    logs.push(run_and_save(&rt, base(format!("fig2-{variant}-sparse"), Protocol::SparseOnly), out)?);
    // FSFL configs: optimizer × schedule
    for (opt, oname) in &opts {
        for (sched, sname) in &schedules {
            let mut c = base(
                format!("fig2-{variant}-fsfl-{oname}-{sname}"),
                Protocol::Fsfl,
            );
            c.scale_optimizer = *opt;
            c.schedule = *sched;
            if *opt == Optimizer::Sgd {
                c.scale_lr = 5e-2;
            }
            logs.push(run_and_save(&rt, c, out)?);
        }
    }
    for log in &logs {
        summaries.push(format!(
            "{},{:.4},{},{}",
            log.name,
            log.best_accuracy(),
            log.total_bytes(true),
            log.total_bytes(false)
        ));
    }
    let path = out.join(format!("fig2_{variant}_summary.csv"));
    write_lines(&path, "config,best_acc,up_bytes,total_bytes", &summaries)?;
    println!("\nfig2 summary ({}):", path.display());
    for s in &summaries {
        println!("  {s}");
    }
    Ok(())
}

// ---------------------------------------------------------------------------
// Fig. 3 — scale-factor statistics at three depths
// ---------------------------------------------------------------------------

/// Arguments of the Fig. 3 harness.
#[derive(Debug)]
pub struct Fig3Args {
    /// `quick` (CI-sized) or `paper` preset.
    pub preset: String,
    /// Model-variant override.
    pub variant: Option<String>,
    /// Round-count override.
    pub rounds: Option<usize>,
    /// Master seed.
    pub seed: u64,
}

impl Fig3Args {
    /// Parse from CLI flags.
    pub fn from_flags(f: &Flags) -> anyhow::Result<Self> {
        Ok(Self {
            preset: f.str_or("preset", "quick"),
            variant: f.str_opt("variant"),
            rounds: f.get("rounds")?,
            seed: f.get_or("seed", 0)?,
        })
    }
}

/// Fig. 3: per-layer scale-factor statistics over rounds.
pub fn fig3(artifacts: &Path, out: &Path, a: Fig3Args) -> Result<()> {
    let quick = is_quick(&a.preset);
    let variant = a
        .variant
        .clone()
        .unwrap_or_else(|| if quick { "tiny_cnn" } else { "mobilenet_tiny" }.to_string());
    let task = if variant.starts_with("mobilenet") {
        TaskKind::VocLike
    } else {
        TaskKind::CifarLike
    };
    let rounds = a.rounds.unwrap_or(if quick { 6 } else { 15 });
    let rt = Runtime::cpu()?;
    let mut cfg = ExperimentConfig::quick(&variant, task, Protocol::Fsfl);
    cfg.name = format!("fig3-{variant}");
    cfg.artifacts_root = artifacts.to_path_buf();
    cfg.rounds = rounds;
    cfg.scale_epochs = if quick { 2 } else { 3 };
    cfg.scale_lr = 5e-2; // pronounced amplify/suppress dynamics
    cfg.train_per_client = if quick { 96 } else { 256 };
    cfg.seed = a.seed;

    let mut exp = crate::fl::Experiment::build(&rt, cfg)?;
    let log = exp.run_with(crate::coordinator::print_round)?;

    // pick shallow / deep / output layers with scales
    let layers: Vec<String> = log
        .rounds
        .last()
        .map(|r| r.scale_stats.iter().map(|s| s.layer.clone()).collect())
        .unwrap_or_default();
    let picks = pick_depth_layers(&layers)?;
    let mut rows = Vec::new();
    for r in &log.rounds {
        for s in &r.scale_stats {
            if picks.contains(&s.layer) {
                rows.push(format!(
                    "{},{},{:.4},{:.4},{:.4},{:.4},{:.4},{:.4},{:.4}",
                    r.round, s.layer, s.min, s.q25, s.median, s.q75, s.max, s.mean, s.suppressed
                ));
            }
        }
    }
    let path = out.join(format!("fig3_{variant}_scales.csv"));
    write_lines(&path, "round,layer,min,q25,median,q75,max,mean,suppressed", &rows)?;
    println!("\nfig3: per-round scale stats for layers {picks:?} → {}", path.display());
    if let Some(last) = log.rounds.last() {
        for s in &last.scale_stats {
            if picks.contains(&s.layer) {
                println!(
                    "  final {}: min {:.3} med {:.3} max {:.3} mean {:.3} suppressed {:.1}%",
                    s.layer, s.min, s.median, s.max, s.mean, s.suppressed * 100.0
                );
            }
        }
    }
    Ok(())
}

// ---------------------------------------------------------------------------
// Fig. 4 — ΔW sparsity per epoch, scaled vs unscaled (2 clients)
// ---------------------------------------------------------------------------

/// Arguments of the Fig. 4 harness.
#[derive(Debug)]
pub struct Fig4Args {
    /// `quick` (CI-sized) or `paper` preset.
    pub preset: String,
    /// Model-variant override.
    pub variant: Option<String>,
    /// Round-count override.
    pub rounds: Option<usize>,
    /// Master seed.
    pub seed: u64,
}

impl Fig4Args {
    /// Parse from CLI flags.
    pub fn from_flags(f: &Flags) -> anyhow::Result<Self> {
        Ok(Self {
            preset: f.str_or("preset", "quick"),
            variant: f.str_opt("variant"),
            rounds: f.get("rounds")?,
            seed: f.get_or("seed", 0)?,
        })
    }
}

/// Fig. 4: per-client ΔW sparsity per round, scaled vs unscaled.
pub fn fig4(artifacts: &Path, out: &Path, a: Fig4Args) -> Result<()> {
    let quick = is_quick(&a.preset);
    let variant = a
        .variant
        .clone()
        .unwrap_or_else(|| if quick { "tiny_cnn" } else { "mobilenet_tiny" }.to_string());
    let task = if variant.starts_with("mobilenet") {
        TaskKind::VocLike
    } else {
        TaskKind::CifarLike
    };
    let rounds = a.rounds.unwrap_or(if quick { 6 } else { 15 });
    let rt = Runtime::cpu()?;

    let mk = |protocol: Protocol, name: &str| -> ExperimentConfig {
        let mut c = ExperimentConfig::quick(&variant, task, protocol);
        c.name = format!("fig4-{variant}-{name}");
        c.artifacts_root = artifacts.to_path_buf();
        c.clients = 2;
        c.rounds = rounds;
        c.scale_epochs = if quick { 2 } else { 3 };
        c.train_per_client = if quick { 96 } else { 256 };
        c.seed = a.seed;
        c
    };
    let scaled = run_and_save(&rt, mk(Protocol::Fsfl, "scaled"), out)?;
    let unscaled = run_and_save(&rt, mk(Protocol::SparseOnly, "unscaled"), out)?;

    let mut rows = Vec::new();
    for (rs, ru) in scaled.rounds.iter().zip(&unscaled.rounds) {
        let g = |v: &[f64], i: usize| v.get(i).copied().unwrap_or(f64::NAN);
        rows.push(format!(
            "{},{:.4},{:.4},{:.4},{:.4}",
            rs.round,
            g(&rs.client_sparsity, 0),
            g(&rs.client_sparsity, 1),
            g(&ru.client_sparsity, 0),
            g(&ru.client_sparsity, 1),
        ));
    }
    let path = out.join(format!("fig4_{variant}_sparsity.csv"));
    write_lines(
        &path,
        "round,scaled_c0,scaled_c1,unscaled_c0,unscaled_c1",
        &rows,
    )?;
    println!("\nfig4 → {}", path.display());
    for r in &rows {
        println!("  {r}");
    }
    Ok(())
}

// ---------------------------------------------------------------------------
// Fig. 5 — residuals + client-count scaling (2/4/8)
// ---------------------------------------------------------------------------

/// Arguments of the Fig. 5 harness.
#[derive(Debug)]
pub struct Fig5Args {
    /// `quick` (CI-sized) or `paper` preset.
    pub preset: String,
    /// Model-variant override.
    pub variant: Option<String>,
    /// Client counts to sweep.
    pub clients: Option<Vec<usize>>,
    /// Round-count override.
    pub rounds: Option<usize>,
    /// Master seed.
    pub seed: u64,
}

impl Fig5Args {
    /// Parse from CLI flags.
    pub fn from_flags(f: &Flags) -> anyhow::Result<Self> {
        Ok(Self {
            preset: f.str_or("preset", "quick"),
            variant: f.str_opt("variant"),
            clients: f.list("clients")?,
            rounds: f.get("rounds")?,
            seed: f.get_or("seed", 0)?,
        })
    }
}

/// Fig. 5: error accumulation + client-count scaling.
pub fn fig5(artifacts: &Path, out: &Path, a: Fig5Args) -> Result<()> {
    let quick = is_quick(&a.preset);
    let variant = a
        .variant
        .clone()
        .unwrap_or_else(|| if quick { "tiny_cnn" } else { "resnet8" }.to_string());
    let task = if variant == "resnet8" {
        TaskKind::VocLike
    } else {
        TaskKind::CifarLike
    };
    let clients = a.clients.clone().unwrap_or_else(|| {
        if quick {
            vec![2, 4]
        } else {
            vec![2, 4, 8]
        }
    });
    let rounds = a.rounds.unwrap_or(if quick { 6 } else { 15 });
    let rt = Runtime::cpu()?;

    let mut summary = Vec::new();
    for &n in &clients {
        for (protocol, label) in [(Protocol::Fsfl, "scaled"), (Protocol::SparseOnly, "unscaled")] {
            let mut c = ExperimentConfig::quick(&variant, task, protocol);
            c.name = format!("fig5-{variant}-{label}-c{n}");
            c.artifacts_root = artifacts.to_path_buf();
            c.clients = n;
            c.rounds = rounds;
            c.residuals_override = Some(true); // error accumulation as in Fig. 5
            c.scale_epochs = if quick { 2 } else { 3 };
            c.train_per_client = if quick { 64 } else { 192 };
            c.seed = a.seed;
            let log = run_and_save(&rt, c, out)?;
            summary.push(format!(
                "{n},{label},{:.4},{}",
                log.best_accuracy(),
                log.total_bytes(true)
            ));
        }
    }
    let path = out.join(format!("fig5_{variant}_summary.csv"));
    write_lines(&path, "clients,config,best_acc,up_bytes", &summary)?;
    println!("\nfig5 summary → {}", path.display());
    for s in &summary {
        println!("  {s}");
    }
    Ok(())
}

// ---------------------------------------------------------------------------
// Table 1 — #params_add and t_add per model
// ---------------------------------------------------------------------------

/// Arguments of the Table 1 harness.
#[derive(Debug)]
pub struct Table1Args {
    /// `quick` (CI-sized) or `paper` preset.
    pub preset: String,
    /// Variants to measure (default: everything in artifacts/index.json).
    pub variants: Option<Vec<String>>,
    /// Master seed.
    pub seed: u64,
}

impl Table1Args {
    /// Parse from CLI flags.
    pub fn from_flags(f: &Flags) -> anyhow::Result<Self> {
        Ok(Self {
            preset: f.str_or("preset", "quick"),
            variants: f.list("variants")?,
            seed: f.get_or("seed", 0)?,
        })
    }
}

/// Table 1: `#params_add` and `t_add` per model variant.
pub fn table1(artifacts: &Path, out: &Path, a: Table1Args) -> Result<()> {
    let quick = is_quick(&a.preset);
    let variants = match &a.variants {
        Some(v) => v.clone(),
        None => {
            let text = std::fs::read_to_string(artifacts.join("index.tsv"))?;
            let mut v: Vec<String> = text
                .lines()
                .filter_map(|l| l.split('\t').next())
                .filter(|s| !s.is_empty())
                .map(|s| s.to_string())
                .collect();
            v.sort();
            if quick {
                v.retain(|n| n == "tiny_cnn" || n == "vgg16_partial");
            }
            v
        }
    };
    let rt = Runtime::cpu()?;
    let mut rows = Vec::new();
    println!("\nTable 1: additional parameters and training time");
    println!("{:<22} {:>12} {:>12} {:>8}", "model", "#params", "#params_add", "t_add");
    for variant in &variants {
        let man = crate::model::Manifest::load(artifacts.join(variant).join("manifest.tsv"))?;
        let task = match man.classes {
            2 => TaskKind::XrayLike,
            20 => TaskKind::VocLike,
            _ => TaskKind::CifarLike,
        };
        let mut cfg = ExperimentConfig::quick(variant, task, Protocol::Fsfl);
        cfg.name = format!("table1-{variant}");
        cfg.artifacts_root = artifacts.to_path_buf();
        cfg.rounds = if quick { 2 } else { 3 };
        cfg.scale_epochs = 1; // t_add = one W iteration + one S iteration
        cfg.train_per_client = if quick { 64 } else { 128 };
        cfg.val_per_client = 32;
        cfg.test_samples = 32;
        cfg.seed = a.seed;
        let mut exp = crate::fl::Experiment::build(&rt, cfg)?;
        let log = exp.run()?;
        let train_ms: u128 = log.rounds.iter().map(|r| r.train_ms).sum();
        let scale_ms: u128 = log.rounds.iter().map(|r| r.scale_ms).sum();
        let t_add = (train_ms + scale_ms) as f64 / train_ms.max(1) as f64;
        println!(
            "{:<22} {:>12} {:>12} {:>7.2}x",
            variant, man.param_count, man.scale_count, t_add
        );
        rows.push(format!(
            "{variant},{},{},{:.3}",
            man.param_count, man.scale_count, t_add
        ));
    }
    let path = out.join("table1_overhead.csv");
    write_lines(&path, "model,params,params_add,t_add", &rows)?;
    println!("table1 → {}", path.display());
    Ok(())
}

// ---------------------------------------------------------------------------
// Table 2 — protocol comparison at 2/4/8/16 clients
// ---------------------------------------------------------------------------

/// Arguments of the Table 2 harness.
#[derive(Debug)]
pub struct Table2Args {
    /// `quick` (CI-sized) or `paper` preset.
    pub preset: String,
    /// Model-variant override.
    pub variant: Option<String>,
    /// Client counts to sweep.
    pub clients: Option<Vec<usize>>,
    /// Communication epochs T (paper: 90).
    pub rounds: Option<usize>,
    /// Constant sparsity rate (paper: 0.96).
    pub rate: f32,
    /// Target accuracy; default = best accuracy of the FedAvg run.
    pub target: Option<f64>,
    /// Master seed.
    pub seed: u64,
}

impl Table2Args {
    /// Parse from CLI flags.
    pub fn from_flags(f: &Flags) -> anyhow::Result<Self> {
        Ok(Self {
            preset: f.str_or("preset", "quick"),
            variant: f.str_opt("variant"),
            clients: f.list("clients")?,
            rounds: f.get("rounds")?,
            rate: f.get_or("rate", 0.96)?,
            target: f.get("target")?,
            seed: f.get_or("seed", 0)?,
        })
    }
}

/// Table 2: Σdata-to-target protocol comparison at several client counts.
pub fn table2(artifacts: &Path, out: &Path, a: Table2Args) -> Result<()> {
    let quick = is_quick(&a.preset);
    let variant = a
        .variant
        .clone()
        .unwrap_or_else(|| if quick { "tiny_cnn" } else { "vgg11_thin" }.to_string());
    let clients = a.clients.clone().unwrap_or_else(|| {
        if quick {
            vec![2, 4]
        } else {
            vec![2, 4, 8, 16]
        }
    });
    let rounds = a.rounds.unwrap_or(if quick { 36 } else { 90 });
    let rt = Runtime::cpu()?;

    let mut rows = Vec::new();
    println!("\nTable 2: Σdata to target accuracy / Σdata at T={rounds} (upstream only)");
    for &n in &clients {
        // run FedAvg first: it defines the target accuracy for this column
        let mut results: Vec<(String, RunLog)> = Vec::new();
        for protocol in Protocol::ALL {
            let mut c = ExperimentConfig::quick(&variant, TaskKind::CifarLike, protocol);
            c.name = format!("table2-{variant}-{}-c{n}", protocol.name().replace(['[', ']', ' ', '+'], ""));
            c.artifacts_root = artifacts.to_path_buf();
            c.clients = n;
            c.rounds = rounds;
            c.sparsify = SparsifyMode::TopK { rate: a.rate };
            c.scale_epochs = 2;
            c.train_per_client = if quick { 96 } else { 160 };
            c.val_per_client = if quick { 32 } else { 32 };
            c.test_samples = if quick { 64 } else { 160 };
            c.seed = a.seed;
            let log = run_and_save(&rt, c, out)?;
            results.push((protocol.name().to_string(), log));
        }
        let target = a.target.unwrap_or_else(|| {
            // paper: targets are the accuracies FedAvg reaches; use 95% of
            // FedAvg's best as the per-column target
            (results[0].1.best_accuracy() * 0.95).max(0.11)
        });
        println!("\n-- {n} clients, target acc {target:.3} --");
        println!(
            "{:<18} {:>12} {:>4} {:>12} {:>4} {:>8}",
            "method", "Σdata@target", "t", "Σdata@T", "T", "best"
        );
        for (name, log) in &results {
            let (t_at, bytes_at) = match log.reached(target, true) {
                Some((t, b)) => (format!("{t}"), fmt_bytes(b)),
                None => ("∅".into(), "∅".into()),
            };
            let last_t = log.rounds.last().map(|r| r.round).unwrap_or(0);
            println!(
                "{:<18} {:>12} {:>4} {:>12} {:>4} {:>7.3}",
                name,
                bytes_at,
                t_at,
                fmt_bytes(log.total_bytes(true)),
                last_t,
                log.best_accuracy()
            );
            rows.push(format!(
                "{n},{name},{target:.4},{},{},{},{:.4}",
                log.reached(target, true)
                    .map(|(_, b)| b.to_string())
                    .unwrap_or_default(),
                log.reached(target, true)
                    .map(|(t, _)| t.to_string())
                    .unwrap_or_default(),
                log.total_bytes(true),
                log.best_accuracy()
            ));
        }
    }
    let path = out.join("table2_comparison.csv");
    write_lines(
        &path,
        "clients,method,target,bytes_at_target,t_at_target,bytes_total,best_acc",
        &rows,
    )?;
    println!("\ntable2 → {}", path.display());
    Ok(())
}

// ---------------------------------------------------------------------------
// Appendix C — client data distributions (paper Figs. C.1 / C.2)
// ---------------------------------------------------------------------------

/// Arguments of the Appendix C harness.
#[derive(Debug)]
pub struct AppCArgs {
    /// Task name (cifar / voc / xray).
    pub task: String,
    /// Client count.
    pub clients: usize,
    /// Samples per client.
    pub per_client: usize,
    /// Dirichlet alpha (`None` → random partitioning).
    pub dirichlet: Option<f64>,
    /// Master seed.
    pub seed: u64,
}

impl AppCArgs {
    /// Parse from CLI flags.
    pub fn from_flags(f: &Flags) -> anyhow::Result<Self> {
        Ok(Self {
            task: f.str_or("task", "voc"),
            clients: f.get_or("clients", 8)?,
            per_client: f.get_or("per-client", 200)?,
            dirichlet: f.get("dirichlet")?,
            seed: f.get_or("seed", 0)?,
        })
    }
}

/// Reproduce the Appendix C distribution figures: per-client label
/// histograms of the train and validation splits (random partitioning as
/// in the paper, or `--dirichlet <alpha>` for controlled non-IID-ness).
pub fn appendix_c(out: &Path, a: AppCArgs) -> Result<()> {
    use crate::data::{dirichlet_split, iid_split, Dataset, TaskSpec};
    let kind = match a.task.as_str() {
        "cifar" => TaskKind::CifarLike,
        "xray" => TaskKind::XrayLike,
        _ => TaskKind::VocLike,
    };
    let spec = TaskSpec::new(kind, 8, 1, a.seed.wrapping_add(1));
    let ds = Dataset::generate(&spec, a.per_client * a.clients, 0);
    let split = match a.dirichlet {
        Some(alpha) => dirichlet_split(&ds, a.clients, alpha, 0.25, a.seed),
        None => iid_split(&ds, a.clients, 0.25, a.seed),
    };
    let classes = ds.classes;
    let hist = |idx: &[usize]| -> Vec<usize> {
        let mut h = vec![0usize; classes];
        for &i in idx {
            h[ds.samples[i].label] += 1;
        }
        h
    };
    let mut rows = Vec::new();
    println!("Appendix C: per-client label histograms ({} clients, {:?})", a.clients, kind);
    for (c, (tr, va)) in split.train.iter().zip(&split.val).enumerate() {
        let ht = hist(tr);
        let hv = hist(va);
        println!("client {c}: train {ht:?}");
        println!("          val   {hv:?}");
        for k in 0..classes {
            rows.push(format!("{c},{k},{},{}", ht[k], hv[k]));
        }
    }
    let path = out.join("appendix_c_distributions.csv");
    write_lines(&path, "client,class,train_count,val_count", &rows)?;
    println!("appendix C → {}", path.display());
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pick_depth_layers_empty_list_is_an_error_not_a_panic() {
        // Regression: fig3 used first()/last().unwrap() on the recorded
        // layer list; an empty list (scaling disabled, or a run that
        // produced no rounds) must be a descriptive error.
        let err = pick_depth_layers(&[]).unwrap_err();
        assert!(
            format!("{err}").contains("no scale-stat layers"),
            "undescriptive: {err}"
        );
    }

    #[test]
    fn pick_depth_layers_selects_shallow_middle_deep() {
        let ls: Vec<String> = ["a", "b", "c", "d", "e"]
            .iter()
            .map(|s| s.to_string())
            .collect();
        let picks = pick_depth_layers(&ls).unwrap();
        assert_eq!(picks, ["a".to_string(), "c".to_string(), "e".to_string()]);
        // a single layer is picked three times rather than panicking
        let one = vec!["only".to_string()];
        let picks = pick_depth_layers(&one).unwrap();
        assert_eq!(picks, ["only".to_string(), "only".to_string(), "only".to_string()]);
    }
}
