//! Deterministic time and retry plumbing for the shard supervisor
//! plane: a fakeable [`Clock`] so heartbeat leases, round deadlines and
//! recovery backoff can be driven by a scripted time source in tests
//! (no chaos test sleeps on wall-clock time), plus the seeded
//! exponential [`Backoff`] shared by shard respawn and the
//! `shard-worker --connect` retry loop.
//!
//! Production code holds an `Arc<dyn Clock>` and never calls
//! `Instant::now()` or `thread::sleep` directly on a supervision path;
//! tests substitute a [`ScriptedClock`] whose `sleep` advances fake
//! time instantly and whose `idle_tick` models the poll quantum of the
//! coordinator's wait loops.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;
use std::time::{Duration, Instant};

/// A monotonic time source the supervisor plane can be driven by.
///
/// Implementations must be cheap to query and safe to share across the
/// coordinator and its reader threads (`Send + Sync`, used behind an
/// `Arc`).
pub trait Clock: Send + Sync {
    /// Monotonic time elapsed since this clock's epoch.
    fn now(&self) -> Duration;

    /// Block (or pretend to block) for `d`. Recovery backoff waits go
    /// through here so a scripted clock can collapse them to zero wall
    /// time while still recording that the wait happened.
    fn sleep(&self, d: Duration);

    /// One poll-loop quantum elapsed with nothing received. The real
    /// clock does nothing (its waits already block on channel/socket
    /// timeouts); a scripted clock advances fake time so lease and
    /// deadline expiry make progress without wall-time sleeps.
    fn idle_tick(&self);
}

/// Production [`Clock`]: monotonic wall time from a fixed epoch.
pub struct MonotonicClock {
    epoch: Instant,
}

impl MonotonicClock {
    /// A clock whose epoch is "now".
    pub fn new() -> Self {
        Self {
            epoch: Instant::now(),
        }
    }
}

impl Default for MonotonicClock {
    fn default() -> Self {
        Self::new()
    }
}

impl Clock for MonotonicClock {
    fn now(&self) -> Duration {
        self.epoch.elapsed()
    }

    fn sleep(&self, d: Duration) {
        if !d.is_zero() {
            std::thread::sleep(d);
        }
    }

    fn idle_tick(&self) {}
}

/// Test [`Clock`]: fake time that only moves when the test (or a
/// supervised wait loop) advances it.
///
/// `sleep(d)` advances fake time by `d` instantly and logs the request;
/// `idle_tick()` advances by the configured tick quantum, standing in
/// for one empty poll-loop pass. Chaos tests assert on lease/deadline
/// behaviour purely through this clock.
pub struct ScriptedClock {
    now_ns: AtomicU64,
    tick: Duration,
    slept: Mutex<Vec<Duration>>,
}

impl ScriptedClock {
    /// A scripted clock starting at t=0 whose idle tick is `tick`.
    pub fn new(tick: Duration) -> Self {
        Self {
            now_ns: AtomicU64::new(0),
            tick,
            slept: Mutex::new(Vec::new()),
        }
    }

    /// Advance fake time by `d` (test-side control).
    pub fn advance(&self, d: Duration) {
        self.now_ns
            .fetch_add(d.as_nanos() as u64, Ordering::SeqCst);
    }

    /// Every duration passed to [`Clock::sleep`] so far, in order.
    pub fn slept(&self) -> Vec<Duration> {
        self.slept.lock().unwrap().clone()
    }
}

impl Clock for ScriptedClock {
    fn now(&self) -> Duration {
        Duration::from_nanos(self.now_ns.load(Ordering::SeqCst))
    }

    fn sleep(&self, d: Duration) {
        self.slept.lock().unwrap().push(d);
        self.advance(d);
    }

    fn idle_tick(&self) {
        self.advance(self.tick);
    }
}

/// splitmix64 step — same generator family the scheduler uses for
/// participant selection, so backoff jitter is reproducible from a
/// seed with zero dependencies.
fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E37_79B9_7F4A_7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

/// Seeded exponential backoff with equal jitter: attempt `k` waits
/// `cap/2 + jitter` where `cap = min(base · 2^k, max)` and `jitter`
/// is drawn uniformly from `[0, cap/2]` by a splitmix64 stream. The
/// same seed always yields the same delay sequence, so recovery
/// timing is as reproducible as everything else in the run.
#[derive(Debug, Clone)]
pub struct Backoff {
    base: Duration,
    max: Duration,
    attempt: u32,
    rng: u64,
}

impl Backoff {
    /// Backoff starting at `base`, capped at `max`, jittered by `seed`.
    pub fn new(base: Duration, max: Duration, seed: u64) -> Self {
        Self {
            base,
            max: max.max(base),
            attempt: 0,
            rng: seed,
        }
    }

    /// Delay to wait before the next attempt (advances the sequence).
    pub fn next_delay(&mut self) -> Duration {
        let cap_ns = self
            .base
            .as_nanos()
            .saturating_mul(1u128 << self.attempt.min(48))
            .min(self.max.as_nanos()) as u64;
        self.attempt = self.attempt.saturating_add(1);
        let half = cap_ns / 2;
        let jitter = if half == 0 {
            0
        } else {
            splitmix64(&mut self.rng) % (half + 1)
        };
        Duration::from_nanos(half + jitter)
    }

    /// Attempts taken so far.
    pub fn attempts(&self) -> u32 {
        self.attempt
    }

    /// Restart the sequence (keeps the current rng position so later
    /// incidents don't replay the first incident's jitter).
    pub fn reset(&mut self) {
        self.attempt = 0;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    #[test]
    fn monotonic_clock_advances() {
        let c = MonotonicClock::new();
        let a = c.now();
        let b = c.now();
        assert!(b >= a);
        c.idle_tick(); // no-op, must not panic
    }

    #[test]
    fn scripted_clock_is_fully_deterministic() {
        let c = ScriptedClock::new(Duration::from_millis(5));
        assert_eq!(c.now(), Duration::ZERO);
        c.advance(Duration::from_millis(100));
        assert_eq!(c.now(), Duration::from_millis(100));
        c.idle_tick();
        assert_eq!(c.now(), Duration::from_millis(105));
        c.sleep(Duration::from_millis(250));
        assert_eq!(c.now(), Duration::from_millis(355));
        assert_eq!(c.slept(), vec![Duration::from_millis(250)]);
    }

    #[test]
    fn scripted_clock_shares_across_threads() {
        let c = Arc::new(ScriptedClock::new(Duration::from_millis(1)));
        let c2 = c.clone();
        let h = std::thread::spawn(move || c2.advance(Duration::from_millis(7)));
        h.join().unwrap();
        assert_eq!(c.now(), Duration::from_millis(7));
    }

    #[test]
    fn backoff_is_seed_deterministic() {
        let mut a = Backoff::new(Duration::from_millis(50), Duration::from_secs(2), 42);
        let mut b = Backoff::new(Duration::from_millis(50), Duration::from_secs(2), 42);
        let sa: Vec<_> = (0..8).map(|_| a.next_delay()).collect();
        let sb: Vec<_> = (0..8).map(|_| b.next_delay()).collect();
        assert_eq!(sa, sb);
        // a different seed jitters differently
        let mut c = Backoff::new(Duration::from_millis(50), Duration::from_secs(2), 43);
        let sc: Vec<_> = (0..8).map(|_| c.next_delay()).collect();
        assert_ne!(sa, sc);
    }

    #[test]
    fn backoff_grows_then_saturates_at_max() {
        let base = Duration::from_millis(100);
        let max = Duration::from_secs(1);
        let mut b = Backoff::new(base, max, 7);
        let delays: Vec<_> = (0..12).map(|_| b.next_delay()).collect();
        // every delay lies in [cap/2, cap] for its attempt's cap
        let mut cap = base;
        for d in &delays {
            assert!(*d >= cap / 2 && *d <= cap, "delay {d:?} outside [{:?}, {cap:?}]", cap / 2);
            cap = (cap * 2).min(max);
        }
        // the tail is capped: never above max
        assert!(delays.iter().all(|d| *d <= max));
        // and the later attempts actually reach the cap's band
        assert!(delays[8] >= max / 2);
    }

    #[test]
    fn backoff_reset_restarts_growth_without_replaying_jitter() {
        let mut b = Backoff::new(Duration::from_millis(50), Duration::from_secs(2), 9);
        let first = b.next_delay();
        b.next_delay();
        b.reset();
        assert_eq!(b.attempts(), 0);
        let again = b.next_delay();
        // same cap band as attempt 0, but a fresh jitter draw
        assert!(again <= Duration::from_millis(50));
        assert_ne!(first, again);
    }

    #[test]
    fn zero_base_backoff_is_zero() {
        let mut b = Backoff::new(Duration::ZERO, Duration::ZERO, 1);
        assert_eq!(b.next_delay(), Duration::ZERO);
        assert_eq!(b.next_delay(), Duration::ZERO);
    }
}
